"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with the full production stack (trainer, checkpoints, resume, straggler log).

Includes a mid-run kill/resume to demonstrate fault tolerance.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax
import numpy as np

from repro.configs.base import ModelConfig, StageCfg
from repro.data import SyntheticLMDataset
from repro.optim import adamw
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer

# ~100M params: 12 layers, d=512, vocab 8192 (llama-style dense).
CFG = ModelConfig(
    name="lm-100m",
    d_model=512,
    vocab=8192,
    n_heads=8,
    n_kv=4,
    d_head=64,
    d_ff=2048,
    stages=(StageCfg(n_layers=12, block="dense"),),
    loss_chunk=128,
    attn_block_q=64,
    attn_block_kv=64,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"model: {n / 1e6:.1f}M params; {args.steps} steps of "
          f"{args.batch}x{args.seq} tokens; ckpt at {ckpt_dir}")

    step = jax.jit(make_train_step(CFG, opt))
    data = SyntheticLMDataset(vocab=CFG.vocab, seq_len=args.seq,
                              global_batch=args.batch, seed=0)

    # phase 1: train to half, then simulate a crash
    half = args.steps // 2
    tr = Trainer(train_step=step, state=state, dataset=data,
                 ckpt_dir=ckpt_dir, ckpt_every=25)
    hist1 = tr.run(half)
    print(f"phase 1: loss {hist1[0]['loss']:.3f} -> {hist1[-1]['loss']:.3f} "
          f"(simulating preemption now)")

    # phase 2: a fresh Trainer (fresh process in real life) resumes
    state2 = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    data2 = SyntheticLMDataset(vocab=CFG.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=0)
    tr2 = Trainer(train_step=step, state=state2, dataset=data2,
                  ckpt_dir=ckpt_dir, ckpt_every=25)
    assert tr2.maybe_resume(), "no checkpoint found"
    print(f"phase 2: resumed at step {int(tr2.state['step'])}")
    hist2 = tr2.run(args.steps)
    print(f"phase 2: loss -> {hist2[-1]['loss']:.3f} at step "
          f"{int(tr2.state['step'])}")
    assert hist2[-1]["loss"] < hist1[0]["loss"] - 0.5, "loss did not improve"
    med = np.median([h["step_time_s"] for h in hist1 + hist2])
    print(f"median step time {med:.3f}s; done (loss fell "
          f"{hist1[0]['loss']:.2f} -> {hist2[-1]['loss']:.2f})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
