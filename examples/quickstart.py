"""Quickstart: the Axon mapper, the simulator, and one training step.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import ArrayShape, Dataflow, GemmShape, runtime_scaleup
from repro.core.axon_sim import simulate_os
from repro.core.mapper import select_asic_mapping, select_tpu_blocking
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw
from repro.train.train_step import init_train_state, make_train_step

# --- 1. the paper's runtime model: Axon halves the fill latency ------------
shape = GemmShape(M=1024, K=84, N=1024)          # TF0-like: small K
arr = ArrayShape(64, 64)
t_sa = runtime_scaleup(shape, arr, Dataflow.OS, axon=False)
t_ax = runtime_scaleup(shape, arr, Dataflow.OS, axon=True)
print(f"[runtime model] 64x64 OS: SA={t_sa} cycles, Axon={t_ax} "
      f"({t_sa / t_ax:.2f}x)")

# --- 2. the cycle-level simulator proves the orchestration is exact --------
rng = np.random.default_rng(0)
A, B = rng.standard_normal((8, 5)), rng.standard_normal((5, 8))
res = simulate_os(A, B, orchestration="axon")
np.testing.assert_allclose(res.out, A @ B, rtol=1e-12)
print(f"[simulator] 8x8 Axon tile: bit-exact GeMM, fill={res.fill_cycles} "
      f"cycles (conventional would be {8 + 8 - 2})")

# --- 3. the mapper as a framework feature: pick dataflow + TPU blocking ----
m = select_asic_mapping(shape, arr, axon=True)
b = select_tpu_blocking(shape)
print(f"[mapper] ASIC: {m.dataflow.value} @ {m.cycles} cycles;  "
      f"TPU: {b.loop_order.value} blocks (bm={b.bm}, bk={b.bk}, bn={b.bn}), "
      f"modeled HBM traffic {b.hbm_traffic_bytes / 1e6:.1f} MB")

# --- 4. one real training step on a reduced architecture -------------------
cfg = get_config("mixtral-8x7b", reduced=True)
opt = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
step = jax.jit(make_train_step(cfg, opt))
data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, global_batch=4)
state, metrics = step(state, data.next())
print(f"[train] {cfg.name}: loss={float(metrics['loss']):.3f} "
      f"aux={float(metrics['aux']):.3f} (MoE load balance)")
print("quickstart OK")
