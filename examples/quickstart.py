"""Quickstart: the Axon mapper, the simulator, the unified operator API,
and one policy-scoped training step.

Run: python examples/quickstart.py   (pip install -e . ; or PYTHONPATH=src)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import axon
from repro.core import ArrayShape, Dataflow, GemmShape, runtime_scaleup
from repro.core import mapper
from repro.core.axon_sim import simulate_os
from repro.core.mapper import select_asic_mapping, select_tpu_blocking
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.train_step import init_train_state, make_train_step

# --- 1. the paper's runtime model: Axon halves the fill latency ------------
shape = GemmShape(M=1024, K=84, N=1024)          # TF0-like: small K
arr = ArrayShape(64, 64)
t_sa = runtime_scaleup(shape, arr, Dataflow.OS, axon=False)
t_ax = runtime_scaleup(shape, arr, Dataflow.OS, axon=True)
print(f"[runtime model] 64x64 OS: SA={t_sa} cycles, Axon={t_ax} "
      f"({t_sa / t_ax:.2f}x)")

# --- 2. the cycle-level simulator proves the orchestration is exact --------
rng = np.random.default_rng(0)
A, B = rng.standard_normal((8, 5)), rng.standard_normal((5, 8))
res = simulate_os(A, B, orchestration="axon")
np.testing.assert_allclose(res.out, A @ B, rtol=1e-12)
print(f"[simulator] 8x8 Axon tile: bit-exact GeMM, fill={res.fill_cycles} "
      f"cycles (conventional would be {8 + 8 - 2})")

# --- 3. the mapper as a framework feature: pick dataflow + TPU blocking ----
m = select_asic_mapping(shape, arr, axon=True)
b = select_tpu_blocking(shape)
print(f"[mapper] ASIC: {m.dataflow.value} @ {m.cycles} cycles;  "
      f"TPU: {b.loop_order.value} blocks (bm={b.bm}, bk={b.bk}, bn={b.bn}), "
      f"modeled HBM traffic {b.hbm_traffic_bytes / 1e6:.1f} MB")

# --- 4. the unified operator API: every contraction, one front door --------
x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 64), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (64, 128), jnp.float32)
y_xla = axon.einsum("bsd,df->bsf", x, w)          # default: XLA off-TPU
with axon.policy(backend="interpret"):             # force the Pallas path
    info = axon.explain("bsd,df->bsf", x, w)
    y_pallas = axon.einsum("bsd,df->bsf", x, w)
np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_xla),
                           rtol=2e-5, atol=1e-5)
print(f"[axon] bsd,df->bsf dispatches to {info['kind']} "
      f"(M={info.get('M')}, K={info.get('K')}, N={info.get('N')}); "
      f"pallas/interpret matches XLA")

# --- 5. a policy-scoped model forward through the new API ------------------
cfg = get_config("mixtral-8x7b", reduced=True)
params = T.init_params(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                      cfg.vocab)}
with axon.policy(backend="interpret", accum_dtype=jnp.float32):
    hidden, _ = T.forward(params, batch, cfg)
hidden_xla, _ = T.forward(params, batch, cfg)      # same weights, XLA backend
np.testing.assert_allclose(np.asarray(hidden), np.asarray(hidden_xla),
                           rtol=5e-2, atol=5e-2)
print(f"[axon] {cfg.name} forward under policy(backend='interpret'): "
      f"hidden {tuple(hidden.shape)}, matches the XLA backend; mapper ran "
      f"{mapper.sweep_calls()} blocking sweeps (cached across layers)")

# --- 6. one real training step on a reduced architecture -------------------
opt = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
step = jax.jit(make_train_step(cfg, opt))
data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, global_batch=4)
state, metrics = step(state, data.next())
print(f"[train] {cfg.name}: loss={float(metrics['loss']):.3f} "
      f"aux={float(metrics['aux']):.3f} (MoE load balance)")
print("quickstart OK")
