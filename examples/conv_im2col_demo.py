"""The paper's im2col story end-to-end on a conv workload.

1. analytical traffic model (Fig. 11): software im2col vs Axon MUX feeders
2. the MUX feeder simulator streaming exact im2col windows
3. the Pallas implicit-im2col kernel (TPU adaptation) vs lax.conv oracle

Run: PYTHONPATH=src python examples/conv_im2col_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.axon_sim import simulate_im2col_feeders
from repro.core.im2col_model import ConvShape, im2col_traffic, lower_to_gemm
from repro.kernels import ref
from repro.kernels.im2col_conv import hbm_traffic_model, im2col_conv

conv = ConvShape(56, 56, 64, 64, 3, stride=1, padding=1, name="resnet50-3x3")
gemm = lower_to_gemm(conv)
t = im2col_traffic(conv, feeder_group=16)
print(f"[model] {conv.name}: GeMM M={gemm.M} K={gemm.K} N={gemm.N}")
print(f"[model] software-im2col streams {t.sw_im2col_elems / 1e6:.1f}M elems; "
      f"Axon feeders fetch {t.axon_elems / 1e6:.1f}M "
      f"({t.reduction * 100:.1f}% reduction)")

ifmap = np.arange(144.0).reshape(12, 12)
sim = simulate_im2col_feeders(ifmap, 3, group=8)
print(f"[sim] 8 feeder PEs: {sim.sram_reads} SRAM reads, {sim.mux_reads} MUX "
      f"reuses (1-in-3 schedule), windows == im2col rows: "
      f"{np.array_equal(sim.windows[0], ifmap[0:3, 0:3].reshape(-1))}")

x = jax.random.normal(jax.random.PRNGKey(0), (1, 28, 28, 16), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 32), jnp.float32) * 0.2
out = im2col_conv(x, w, stride=1, padding=1, block_rows=7, block_cout=32,
                  block_cin=16, interpret=True)
want = ref.conv2d_ref(x, w, stride=1, padding=1)
np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)
hbm = hbm_traffic_model(x.shape, w.shape, stride=1, padding=1)
print(f"[pallas] implicit-im2col conv matches lax.conv "
      f"(max err {float(jnp.abs(out - want).max()):.2e}); modeled HBM cut "
      f"{hbm['reduction'] * 100:.1f}% vs materialized im2col")
