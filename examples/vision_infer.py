"""A real conv backbone through the Axon im2col path, end to end.

1. trace the runnable ResNet50 and reproduce the paper's Axon-vs-SA
   throughput/energy comparison from its executed layer shapes
2. run a reduced ResNet50 forward pass on the Pallas implicit-im2col
   kernels and bit-compare against the XLA backend
3. serve a mixed-arrival image workload through the batched VisionEngine

Run: PYTHONPATH=src python examples/vision_infer.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import axon
from repro.configs import get_vision_config
from repro.vision import models, trace
from repro.vision.engine import ImageRequest, VisionEngine

# -- 1. the paper's comparison, traced from the executable model ------------
full = get_vision_config("resnet50")
rep = trace.paper_report(full)
print(f"[trace] {full.name}: {rep['conv_layers']} conv layers, "
      f"{rep['macs'] / 1e9:.1f} GMACs traced from the runnable model")
print(f"[model] Axon vs conventional SA on 16x16: "
      f"{rep['cycle_speedup']:.3f}x cycles, "
      f"{rep['energy_ratio']:.2f}x DRAM energy "
      f"({rep['traffic_bytes']['reduction'] * 100:.1f}% operand-traffic cut)")

# -- 2. forward pass: Pallas im2col kernels vs XLA --------------------------
cfg = get_vision_config("resnet50", reduced=True)
params = models.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1),
                      (2, *cfg.input_hw, cfg.in_channels), cfg.pdtype)
with axon.policy(backend="pallas"):        # interpret-mode off-TPU
    logits_pallas = models.apply(params, x, cfg)
with axon.policy(backend="xla"):
    logits_xla = models.apply(params, x, cfg)
np.testing.assert_allclose(logits_pallas, logits_xla, rtol=2e-4, atol=2e-4)
print(f"[pallas] {cfg.name} forward matches XLA "
      f"(max err {float(jnp.abs(logits_pallas - logits_xla).max()):.2e})")

# -- 3. mixed-arrival serving through the engine ----------------------------
rng = np.random.default_rng(0)
reqs = [ImageRequest(image=rng.normal(size=(*cfg.input_hw, 3))
                     .astype(np.float32),
                     arrival_s=0.005 * (i // 3)) for i in range(10)]
engine = VisionEngine(params, cfg, batch_slots=4)
engine.warmup()
outs = engine.infer(reqs)
st = engine.last_stats
print(f"[engine] {st['images']} images in {st['steps']} fixed-shape steps: "
      f"{st['img_per_s']:.0f} img/s, p99 latency {st['p99_latency_s']:.3f}s, "
      f"occupancy {st['mean_occupancy'] * 100:.0f}%")
print(f"[engine] top-1 for image 0: class {int(np.argmax(outs[0]))}")
