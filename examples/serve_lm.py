"""Serve a small model with batched requests through the cached decode path.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

cfg = get_config("mixtral-8x7b", reduced=True)   # SWA + MoE decode path
params = T.init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(params, cfg, batch_slots=4, max_len=64, temperature=0.8)

key = jax.random.PRNGKey(1)
reqs = []
for i in range(8):
    key, sub = jax.random.split(key)
    plen = 4 + int(jax.random.randint(sub, (), 0, 5))
    prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
    reqs.append(Request(prompt=[int(t) for t in prompt], max_new_tokens=12))

t0 = time.time()
outs = engine.generate(reqs)
dt = time.time() - t0
n_tok = sum(len(o) for o in outs)
for i, o in enumerate(outs):
    print(f"req{i} ({len(reqs[i].prompt)}-token prompt) -> {o}")
print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s on CPU, "
      f"wave-batched across 4 slots)")
