"""Serve a small model with continuous batching through the cached decode path.

A mixed-length workload (short prompts interleaved with 3x-longer ones) is
exactly where wave batching stalls: every slot waits for the longest request
of its wave.  The continuous engine admits requests into independent slots,
teacher-forces prompts a chunk at a time, and backfills each slot the moment
its request finishes -- compare the two engines' tokens/s below.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, WaveServeEngine

cfg = get_config("mixtral-8x7b", reduced=True)   # SWA + MoE decode path
params = T.init_params(jax.random.PRNGKey(0), cfg)

key = jax.random.PRNGKey(1)
reqs = []
for i in range(8):
    key, sub = jax.random.split(key)
    plen = 4 if i % 2 == 0 else 12               # mixed short/long prompts
    prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
    reqs.append(Request(prompt=[int(t) for t in prompt], max_new_tokens=12))

engine = ServeEngine(params, cfg, batch_slots=4, max_len=64,
                     prefill_chunk=8, temperature=0.8)
engine.generate(reqs[:4])                        # warm the jit caches
outs = engine.generate(reqs)
stats = engine.last_stats

for i, o in enumerate(outs):
    print(f"req{i} ({len(reqs[i].prompt)}-token prompt) -> {o}")
print(f"continuous: {stats['generated_tokens']} tokens in "
      f"{stats['wall_s']:.3f}s ({stats['tokens_per_s']:.1f} tok/s, "
      f"{stats['steps']} steps across 4 slots)")

wave = WaveServeEngine(params, cfg, batch_slots=4, max_len=64,
                       temperature=0.8)
wave.generate(reqs[:4])                          # warm (full-wave shape)
t0 = time.time()
wave_outs = wave.generate(reqs)
dt = time.time() - t0
n_tok = sum(len(o) for o in wave_outs)
print(f"wave baseline: {n_tok} tokens in {dt:.3f}s ({n_tok / dt:.1f} tok/s, "
      f"stalls on the 12-token prompts)")
