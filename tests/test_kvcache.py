"""Paged, quantized KV cache: device primitives, allocator, engine identity.

The load-bearing properties, in order of blast radius:

  1. a paged float engine is TOKEN-IDENTICAL to the dense engine under
     greedy decoding (GQA, SWA, MLA) -- paging is pure data movement;
  2. int8 page payloads (per-token-per-head scales, dequant-on-read) stay
     token-identical on the same archetypes at reduced test scale;
  3. prefix reuse skips prefill steps without changing a single token, and
     the int8-paged cache footprint lands >= 3x below dense f32;
  4. the host allocator's machine-checkable contract
     (``PagePool.invariant_errors``) actually detects seeded corruption --
     a checker that can't see planted bugs guards nothing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import kvcache as KV
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)

# GQA / SWA / MLA -- the three attention archetypes whose cache layouts
# differ (dense KV heads, rolling window, compressed latent + rope key)
ARCHS = ["yi-9b", "mixtral-8x7b", "deepseek-v3-671b"]


def _cfg(arch):
    cfg = get_config(arch, reduced=True)
    return dataclasses.replace(cfg, capacity_factor=64.0)


def _params(cfg):
    return T.init_params(KEY, cfg)


def _requests(cfg, specs, seed=1):
    key = jax.random.PRNGKey(seed)
    reqs = []
    for plen, mnew, eos in specs:
        key, sub = jax.random.split(key)
        prompt = [int(t) for t in jax.random.randint(sub, (plen,), 2,
                                                     cfg.vocab)]
        reqs.append(Request(prompt=prompt, max_new_tokens=mnew, eos_id=eos))
    return reqs


MIXED = [(3, 6, 1), (9, 4, 7), (5, 8, 1), (12, 3, 2), (2, 5, 1), (7, 7, 3)]


# ---------------------------------------------------------------------------
# device primitives
# ---------------------------------------------------------------------------


class TestQuantizeTokens:
    @pytest.mark.parametrize("fmt", ["int8", "fp8"])
    def test_round_trip_error_bounded(self, fmt):
        x = jax.random.normal(KEY, (3, 5, 2, 8), jnp.float32)
        payload, scale = KV.quantize_tokens(x, fmt)
        assert scale.shape == x.shape[:-1]          # one scale per token-head
        back = KV.dequantize_tokens(payload, scale, jnp.float32)
        amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
        err = np.abs(np.asarray(back) - np.asarray(x))
        # int8: half an lsb of the per-row grid; fp8 e4m3: ~2^-3 relative
        bound = amax / 254 if fmt == "int8" else amax * 0.0725
        assert (err <= bound + 1e-7).all()

    def test_zero_rows_survive(self):
        x = jnp.zeros((2, 4, 8), jnp.float32)
        payload, scale = KV.quantize_tokens(x, "int8")
        assert np.isfinite(np.asarray(scale)).all()
        assert not np.asarray(
            KV.dequantize_tokens(payload, scale, jnp.float32)).any()


class TestPageMoves:
    def test_scatter_then_gather_round_trips(self):
        ps, n, P = 4, 3, 7
        pool = jnp.zeros((P, ps, 2, 5), jnp.float32)
        table = jnp.asarray([[6, 2, 4], [1, 0, 3]])     # 2 slots, 3 pages
        vals = jax.random.normal(KEY, (2, 6, 2, 5), jnp.float32)
        idx = jnp.broadcast_to(jnp.arange(2, 8), (2, 6))  # tokens 2..7
        pool = KV.scatter_pages(pool, table, vals, idx, jnp.ones((2, 6), bool))
        seq = KV.gather_pages(pool, table)              # (2, 12, 2, 5)
        np.testing.assert_array_equal(np.asarray(seq[:, 2:8]),
                                      np.asarray(vals))
        assert not np.asarray(seq[:, :2]).any()         # untouched rows zero
        assert not np.asarray(seq[:, 8:]).any()

    def test_invalid_lanes_never_write(self):
        ps = 4
        pool = jnp.zeros((3, ps, 2), jnp.float32)
        table = jnp.asarray([[0, 1, 2]])
        vals = jnp.ones((1, 2, 2))
        idx = jnp.asarray([[1, 5]])
        out = KV.scatter_pages(pool, table, vals, idx,
                               jnp.asarray([[True, False]]))
        assert np.asarray(out[0, 1]).all()              # valid token landed
        assert not np.asarray(out[1]).any()             # masked token dropped

    def test_read_seq_dequantizes(self):
        pcfg = KV.PagedCacheConfig(page_size=4, pages_per_slot=2,
                                   pool_pages=4, fmt="int8")
        cache = KV.init_paged_seq_cache({"k": (2, 8)}, batch=1, pcfg=pcfg)
        table = jnp.asarray([[2, 0]])
        vals = jax.random.normal(KEY, (1, 3, 2, 8), jnp.float32)
        idx = jnp.arange(3)[None, :]
        cache.update(KV.write_seq(cache, "k", table, vals, idx,
                                  jnp.ones((1, 3), bool), "int8"))
        seq = KV.read_seq(cache, "k", table, 2, dtype=jnp.float32)
        assert seq.shape == (1, 8, 2, 8)
        np.testing.assert_allclose(np.asarray(seq[:, :3]), np.asarray(vals),
                                   atol=0.02, rtol=0.02)


# ---------------------------------------------------------------------------
# host allocator
# ---------------------------------------------------------------------------


class TestPagePool:
    def test_admit_release_round_trip(self):
        pool = KV.PagePool(8, 4)
        pages, shared = pool.admit(0, (1, 2, 3, 4, 5), 9, prefix=False)
        assert shared == 0 and len(pages) == 3          # ceil(9/4)
        assert pool.free_pages == 5
        assert pool.invariant_errors() == []
        pool.release(0)
        assert pool.free_pages == 8
        assert (pool.refcount == 0).all()

    def test_prefix_match_capped_below_full_prompt(self):
        # the last prompt token must be re-fed (the finishing prefill step
        # needs logits), so a prompt of exactly k full pages shares k-1
        pool = KV.PagePool(16, 4)
        prompt = tuple(range(100, 108))                 # exactly 2 pages
        pages, _ = pool.admit(0, prompt, 10)
        pool.release(0, prompt=prompt)
        pages2, shared = pool.admit(1, prompt, 10)
        assert shared == 4                              # one page, not two
        assert pages2[0] == pages[0]                    # the registered page
        assert pool.invariant_errors() == []

    def test_shared_pages_are_frozen_fresh_are_writable(self):
        pool = KV.PagePool(16, 4)
        prompt = tuple(range(12))
        pool.admit(0, prompt, 14)
        pool.release(0, prompt=prompt)
        pool.admit(1, prompt, 14)
        pool.admit(2, prompt, 14)                       # concurrent sharer
        assert pool.invariant_errors() == []            # no writable aliasing
        assert pool.slot_pages(1)[0] == pool.slot_pages(2)[0]
        assert pool.slot_pages(1)[-1] != pool.slot_pages(2)[-1]

    def test_eviction_under_pressure(self):
        pool = KV.PagePool(4, 4)
        for i in range(3):
            prompt = tuple(range(i * 10, i * 10 + 4))
            pool.admit(0, prompt, 5)
            pool.release(0, prompt=prompt)
        # free pages < demand: LRU prefix entries must make room
        pool.admit(1, (77, 78, 79), 4 * 4)
        assert pool.evictions > 0
        assert pool.invariant_errors() == []

    def test_exhaustion_raises_and_rolls_back(self):
        pool = KV.PagePool(4, 4)
        pool.admit(0, (1, 2), 16)
        rc = pool.refcount.copy()
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.admit(1, (3, 4), 4)
        np.testing.assert_array_equal(pool.refcount, rc)
        assert pool.invariant_errors() == []

    def test_double_admit_same_slot_rejected(self):
        pool = KV.PagePool(8, 4)
        pool.admit(0, (1, 2), 4)
        with pytest.raises(ValueError, match="already holds"):
            pool.admit(0, (3, 4), 4)


class TestInvariantChecker:
    """The contract checker must DETECT planted corruption, not just pass."""

    def _live_pool(self):
        pool = KV.PagePool(8, 4)
        pool.admit(0, (1, 2, 3, 4, 5), 8, prefix=False)
        assert pool.invariant_errors() == []
        return pool

    def test_detects_refcount_drift(self):
        pool = self._live_pool()
        pool.refcount[pool.slot_pages(0)[0]] += 1
        assert "PGT003" in {c for c, _ in pool.invariant_errors()}

    def test_detects_free_but_referenced(self):
        pool = self._live_pool()
        pool._free.appendleft(pool.slot_pages(0)[0])
        assert "PGT002" in {c for c, _ in pool.invariant_errors()}

    def test_detects_leaked_page(self):
        pool = self._live_pool()
        pool._free.pop()                                # vanish a free page
        assert "PGT004" in {c for c, _ in pool.invariant_errors()}

    def test_detects_writable_aliasing(self):
        pool = self._live_pool()
        # alias slot 0's writable page into a second slot's writable region
        pool._slot_pages[1] = [pool.slot_pages(0)[-1]]
        pool._slot_shared[1] = 0
        assert "PGT001" in {c for c, _ in pool.invariant_errors()}

    def test_detects_writable_while_frozen(self):
        pool = KV.PagePool(8, 4)
        prompt = tuple(range(8))
        pool.admit(0, prompt, 10)
        pool.release(0, prompt=prompt)
        pool.admit(1, prompt, 10)
        # corrupt the share accounting: claim slot 1 shares nothing, making
        # the frozen prefix page look writable
        pool._slot_shared[1] = 0
        assert "PGT001" in {c for c, _ in pool.invariant_errors()}


# ---------------------------------------------------------------------------
# engine identity: paged == dense, quantized pages, prefix reuse, memory
# ---------------------------------------------------------------------------


class TestPagedEngineIdentity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_paged_float_matches_dense(self, arch):
        cfg = _cfg(arch)
        params = _params(cfg)
        reqs = _requests(cfg, MIXED)
        dense = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            prefill_chunk=4).generate(reqs)
        paged = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            prefill_chunk=4, paged=True,
                            page_size=4).generate(reqs)
        assert paged == dense

    @pytest.mark.parametrize("arch", ARCHS)
    def test_paged_int8_matches_dense(self, arch):
        """Quantize-on-write pages keep greedy decoding token-identical at
        reduced test scale (the margin between top-2 logits dwarfs the
        per-token int8 rounding)."""
        cfg = _cfg(arch)
        params = _params(cfg)
        reqs = _requests(cfg, MIXED)
        dense = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            prefill_chunk=4).generate(reqs)
        q = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                        prefill_chunk=4, paged=True, page_size=4,
                        cache_fmt="int8").generate(reqs)
        assert q == dense

    def test_fp8_pages_serve(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(5, 4, 1), (8, 4, 1)])
        outs = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                           paged=True, page_size=4,
                           cache_fmt="fp8").generate(reqs)
        assert all(1 <= len(o) <= 4 for o in outs)

    def test_cache_fmt_requires_paged(self):
        cfg = _cfg("yi-9b")
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(_params(cfg), cfg, cache_fmt="int8")

    def test_pool_pressure_requeues_not_crashes(self):
        # pool holds pages for ~1.5 requests: admissions must serialize
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(6, 4, 1)] * 4)
        eng = ServeEngine(params, cfg, batch_slots=4, max_len=32, paged=True,
                          page_size=4, pool_pages=5, prefix_cache=False)
        outs = eng.generate(reqs)
        solo = ServeEngine(params, cfg, batch_slots=1,
                           max_len=32).generate(reqs)
        assert outs == solo
        assert eng.pool.invariant_errors() == []

    def test_oversized_request_fails_fast_when_pool_idle(self):
        cfg = _cfg("yi-9b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=2, max_len=32,
                          paged=True, page_size=4, pool_pages=2)
        with pytest.raises(RuntimeError, match="exhausted"):
            eng.generate(_requests(cfg, [(6, 4, 1)]))


class TestPrefixReuse:
    def test_repeat_prompt_skips_prefill_steps(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(9, 4, 7), (9, 4, 7)])
        reqs[1].prompt = list(reqs[0].prompt)           # identical prompt
        eng = ServeEngine(params, cfg, batch_slots=1, max_len=32,
                          prefill_chunk=2, paged=True, page_size=4)
        cold = eng.generate([reqs[0]])
        steps_cold = eng.last_stats["steps"]
        warm = eng.generate([reqs[1]])
        steps_warm = eng.last_stats["steps"]
        assert warm == cold                             # tokens untouched
        assert steps_warm < steps_cold                  # prefill skipped
        assert eng.last_stats["prefix_hits"] == 1
        # prompt of 9: two full pages, minus the always-re-fed last token
        assert eng.last_stats["prefix_hit_tokens"] == 8
        assert eng.pool.invariant_errors() == []

    def test_concurrent_shared_prefix_isolated(self):
        # two slots decoding from one frozen prefix page must not cross-talk
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        base = _requests(cfg, [(8, 5, 1)])[0]
        r1 = Request(prompt=list(base.prompt) + [11], max_new_tokens=5,
                     eos_id=1)
        r2 = Request(prompt=list(base.prompt) + [17], max_new_tokens=5,
                     eos_id=1)
        eng = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                          paged=True, page_size=4)
        eng.generate([base])                            # register the prefix
        shared = eng.generate([r1, r2])
        solo = [ServeEngine(params, cfg, batch_slots=1,
                            max_len=32).generate([r])[0] for r in (r1, r2)]
        assert shared == solo

    def test_prefix_auto_disabled_for_unpageable_state(self):
        # SWA's rolling window is not addressable by absolute position
        cfg = _cfg("mixtral-8x7b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=1, max_len=32,
                          paged=True, page_size=4)
        assert eng.prefix_cache is False
        cfg2 = _cfg("yi-9b")
        eng2 = ServeEngine(_params(cfg2), cfg2, batch_slots=1, max_len=32,
                           paged=True, page_size=4)
        assert eng2.prefix_cache is True

    def test_supports_prefix_reuse_predicate(self):
        assert KV.supports_prefix_reuse(_cfg("yi-9b"))
        assert not KV.supports_prefix_reuse(_cfg("mixtral-8x7b"))
        assert not KV.supports_prefix_reuse(_cfg("falcon-mamba-7b"))


class TestCacheMemory:
    def test_int8_pages_at_least_3x_below_dense_f32(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(5, 3, 1)])
        dense = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            cache_dtype=jnp.float32)
        dense.generate(reqs)
        paged = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            paged=True, page_size=4, cache_fmt="int8")
        paged.generate(reqs)
        d = dense.last_stats["cache_bytes_per_slot"]
        p = paged.last_stats["cache_bytes_per_slot"]
        assert d >= 3 * p, f"dense {d} B/slot < 3x paged-int8 {p} B/slot"

    def test_pool_stats_reported(self):
        cfg = _cfg("yi-9b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=2, max_len=32,
                          paged=True, page_size=4)
        eng.generate(_requests(cfg, [(5, 3, 1)]))
        pool = eng.last_stats["pool"]
        assert pool["pages"] == eng.paged.pool_pages
        assert 0.0 <= pool["occupancy"] <= 1.0
        assert pool["free_pages"] + sum(
            1 for r in eng.pool.refcount if r > 0) == pool["pages"]

    def test_summarize_pytree_accounts_everything(self):
        tree = {"a": jnp.zeros((4, 8), jnp.int8),
                "b": {"c": jnp.zeros((2,), jnp.float32)}}
        s = KV.summarize_pytree(tree)
        assert s["total_bytes"] == 32 + 8 == KV.pytree_bytes(tree)
        assert len(s["leaves"]) == 2
