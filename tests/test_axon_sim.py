"""Functional validation of the Axon orchestration (paper Fig. 3/4, §3.2).

The cycle-level simulator must (a) produce bit-exact GeMM results for both
orchestrations, (b) hit the analytical fill/compute cycle counts exactly, and
(c) the im2col MUX feeders must stream exactly the im2col matrix while
touching SRAM only 1-in-n cycles.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.axon_sim import (
    full_tile_cycles,
    simulate_im2col_feeders,
    simulate_os,
    simulate_os_tiled,
)
from repro.core.dataflows import Dataflow, GemmShape
from repro.core.runtime_model import ArrayShape, fill_latency_axon, fill_latency_sa, runtime_scaleup

rng = np.random.default_rng(0)


def _rand(m, k, n):
    return rng.standard_normal((m, k)), rng.standard_normal((k, n))


class TestFunctionalCorrectness:
    @pytest.mark.parametrize("orch", ["sa", "axon"])
    @pytest.mark.parametrize("m,k,n", [
        (3, 3, 3),        # the paper's Fig. 4 toy example shape
        (8, 5, 8),
        (16, 7, 16),
        (4, 9, 12),       # wide: columns without a diagonal PE (Fig. 5)
        (12, 9, 4),       # tall: rows without a diagonal PE
        (1, 4, 6),
        (6, 4, 1),
    ])
    def test_exact_matmul(self, orch, m, k, n):
        A, B = _rand(m, k, n)
        res = simulate_os(A, B, orchestration=orch)
        np.testing.assert_allclose(res.out, A @ B, rtol=1e-12)

    @given(m=st.integers(1, 10), k=st.integers(1, 10), n=st.integers(1, 10),
           orch=st.sampled_from(["sa", "axon"]))
    @settings(max_examples=40, deadline=None)
    def test_exact_matmul_property(self, m, k, n, orch):
        A, B = _rand(m, k, n)
        res = simulate_os(A, B, orchestration=orch)
        np.testing.assert_allclose(res.out, A @ B, rtol=1e-12)


class TestCycleCounts:
    @pytest.mark.parametrize("m,n", [(8, 8), (16, 16), (4, 12), (12, 4)])
    def test_fill_latency_matches_model(self, m, n):
        A, B = _rand(m, 6, n)
        arr = ArrayShape(m, n)
        sa = simulate_os(A, B, orchestration="sa")
        ax = simulate_os(A, B, orchestration="axon")
        assert sa.fill_cycles == fill_latency_sa(arr)
        assert ax.fill_cycles == fill_latency_axon(arr)

    @pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 3, 16), (4, 10, 12)])
    def test_compute_cycles_match_closed_form(self, m, k, n):
        A, B = _rand(m, k, n)
        sa = simulate_os(A, B, orchestration="sa")
        ax = simulate_os(A, B, orchestration="axon")
        # compute portion = fill + K; totals add the R-cycle readout
        assert sa.total_cycles == full_tile_cycles(m, n, k, "sa")
        assert ax.total_cycles == full_tile_cycles(m, n, k, "axon")

    def test_square_fill_exactly_halves(self):
        # 16x16 (the paper's implemented shape): 30 -> 15 cycles.
        A, B = _rand(16, 4, 16)
        sa = simulate_os(A, B, orchestration="sa")
        ax = simulate_os(A, B, orchestration="axon")
        assert sa.fill_cycles == 30
        assert ax.fill_cycles == 15


class TestTiledScaleUp:
    def test_tiled_matches_runtime_model(self):
        m, k, n, r, c = 24, 5, 20, 8, 8
        A, B = _rand(m, k, n)
        shape = GemmShape(m, k, n)
        arr = ArrayShape(r, c)
        for orch, axon in (("sa", False), ("axon", True)):
            res = simulate_os_tiled(A, B, r, c, orchestration=orch)
            np.testing.assert_allclose(res.out, A @ B, rtol=1e-12)
            assert res.total_cycles == runtime_scaleup(shape, arr, Dataflow.OS, axon=axon)


class TestIm2colFeeders:
    @pytest.mark.parametrize("n,group", [(3, 4), (3, 16), (5, 8), (7, 4), (1, 4)])
    def test_streams_equal_im2col(self, n, group):
        H = W = n + group + 2
        ifmap = rng.standard_normal((H, W))
        res = simulate_im2col_feeders(ifmap, n, group=group)
        for w in range(group):
            expect = ifmap[0:n, w:w + n].reshape(-1)
            np.testing.assert_array_equal(res.windows[w], expect)

    @pytest.mark.parametrize("n,group", [(3, 4), (3, 16), (5, 8)])
    def test_sram_reads_1_in_n(self, n, group):
        # feeder 0 reads all n^2; each follower reads n (one per period).
        H = W = n + group + 2
        ifmap = rng.standard_normal((H, W))
        res = simulate_im2col_feeders(ifmap, n, group=group)
        assert res.sram_reads == n * n + (group - 1) * n
        assert res.mux_reads == (group - 1) * (n * n - n)

    def test_fig7_example_50pct_repetition(self):
        # Paper Fig. 7: 3x3 filter, 6x6 ifmap, first OFMAP row = 4 windows:
        # 36 window elements, 18 unique -> 50% repetition; consecutive
        # windows share n(n-1) = 6 elements.
        ifmap = np.arange(36.0).reshape(6, 6)
        res = simulate_im2col_feeders(ifmap, 3, group=4)
        elems = res.windows.reshape(-1)
        assert elems.size == 36
        assert np.unique(elems).size == 18
        for w in range(1, 4):
            shared = np.intersect1d(res.windows[w - 1], res.windows[w])
            assert shared.size == 6
