"""Sub-byte precision conformance net: int4 / fp8 / int8-attention / golden.

The lock-down suite for everything ``repro.quant`` grew below int8:

  * exact pack/unpack round trips for the nibble-packed int4 payload
    (odd channel counts, negative values, both channel axes) -- hypothesis
    fuzz plus pinned examples;
  * differential tests of every new kernel (int4 GeMM/GEMV, fp8 GeMM, int8
    flash attention) against the dequantized float reference with
    scale-derived tolerances -- the ``tests/test_quant.py`` pattern;
  * the ``QuantizedTensor`` scan invariant: ``lax.scan`` slices of a
    stacked quantized weight dequantize BIT-EXACTLY to the unstacked
    per-layer dequant, for int8 and packed int4 (plus per-layer act
    scales);
  * the dispatch eligibility predicate (``axon.quant_route``), with
    routing asserted through registry spies and the mapper cache rather
    than output values alone;
  * golden pins on ``paper_report["precision"]`` so energy-model refactors
    cannot silently move the modeled headline figures;
  * acceptance: ``ServeEngine`` serving a calibrated-activation int8 LM
    end to end with per-layer scales threaded through ``lax.scan``.
"""
import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import axon, quant
from repro.axon import registry
from repro.configs import get_config, get_vision_config
from repro.core.energy_model import operand_bytes
from repro.core.mapper import mapper_cache_clear, sweep_calls
from repro.kernels.flash_attention import (flash_attention_fwd,
                                           int8_flash_attention_fwd)
from repro.kernels.quant_gemm import fp8_gemm, int4_gemm, int4_gemv
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, make_chunk_step
from repro.vision.trace import paper_report

KEY = jax.random.PRNGKey(0)


def _rand(shape, seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


def _int4_tol(qt: quant.QuantizedTensor, K: int) -> dict:
    """Scale-derived tolerance: both paths sum K products of magnitude
    <= 7 * s * |a|; only f32 summation rounding separates them."""
    s_w = float(jnp.max(qt.scale))
    return dict(rtol=1e-4, atol=max(7.0 * s_w * K * 1e-5, 1e-6))


@contextlib.contextmanager
def _spy(*kinds):
    """Wrap registry entries to record (kind, lhs dtype/shape) per dispatch."""
    calls = {k: [] for k in kinds}
    originals = {k: registry.get(k) for k in kinds}

    def wrap(kind, fn):
        def wrapped(*args, **kwargs):
            at = args[0]
            calls[kind].append((jnp.dtype(at.dtype).name, tuple(at.shape)))
            return fn(*args, **kwargs)
        return wrapped

    for k in kinds:
        registry._REGISTRY[k] = wrap(k, originals[k])
    try:
        yield calls
    finally:
        for k in kinds:
            registry._REGISTRY[k] = originals[k]


# ---------------------------------------------------------------------------
# int4 packing: exact round trips
# ---------------------------------------------------------------------------


class TestInt4PackUnpack:
    @pytest.mark.parametrize("shape,axis", [
        ((6, 4), 0), ((6, 4), 1), ((7, 5), 0), ((7, 5), 1),   # odd + both axes
        ((3, 9, 5), 1), ((5, 3), -2), ((4, 7), -1),
    ])
    def test_round_trip_exact(self, shape, axis):
        rng = np.random.default_rng(hash((shape, axis)) % 2**31)
        q = jnp.asarray(rng.integers(-8, 8, shape), jnp.int8)
        packed = quant.pack_int4(q, axis=axis)
        ax = axis if axis >= 0 else len(shape) + axis
        assert packed.shape[ax] == (shape[ax] + 1) // 2
        out = quant.unpack_int4(packed, shape[ax], axis=axis)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(q))

    def test_negative_extremes(self):
        q = jnp.asarray([[-8, 7], [-1, 0], [1, -7]], jnp.int8)
        for axis in (0, 1):
            out = quant.unpack_int4(quant.pack_int4(q, axis=axis),
                                    q.shape[axis], axis=axis)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(q))

    @given(m=st.integers(1, 33), n=st.integers(1, 33), axis=st.sampled_from(
        [0, 1, -1, -2]), seed=st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_round_trip_fuzz(self, m, n, axis, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-8, 8, (m, n)), jnp.int8)
        ax = axis if axis >= 0 else 2 + axis
        out = quant.unpack_int4(quant.pack_int4(q, axis=axis),
                                q.shape[ax], axis=axis)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(q))

    def test_quantize_weight_int4_layout(self):
        w = _rand((33, 24), 0, scale=2.0)
        qt = quant.quantize_weight(w, fmt="int4")
        assert qt.fmt == "int4" and qt.bits == 4
        assert qt.q.shape == (17, 24) and qt.shape == (33, 24)
        assert qt.scale.shape == (1, 24)
        assert int(jnp.max(jnp.abs(quant.unpack_int4(
            qt.q, 33).astype(jnp.int32)))) <= 7
        err = jnp.abs(quant.dequantize(qt) - w)
        assert bool(jnp.all(err <= qt.scale * 0.5 + 1e-6))

    def test_int4_requires_last_channel_axis(self):
        with pytest.raises(ValueError):
            quant.quantize_weight(_rand((8, 8), 1), axis=0, fmt="int4")
        with pytest.raises(ValueError):
            quant.quantize_weight(_rand((8,), 2), fmt="int4")

    def test_bad_fmt_rejected(self):
        with pytest.raises(ValueError):
            quant.quantize_weight(_rand((4, 4), 3), fmt="int2")


# ---------------------------------------------------------------------------
# kernels, direct (interpret mode), vs the dequantized float reference
# ---------------------------------------------------------------------------


class TestSubbyteKernels:
    def test_int4_gemm_matches_dequant_reference(self):
        M, K, N = 17, 33, 29                      # odd K: packed pad nibble
        a = _rand((M, K), 0)
        qt = quant.quantize_weight(_rand((K, N), 1, scale=2.0), fmt="int4")
        got = int4_gemm(a, qt.q, qt.scale.reshape(-1), k_size=K,
                        block=(8, 16, 16), interpret=True)
        want = a @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_int4_tol(qt, K))

    def test_int4_gemv(self):
        K, N = 95, 130                            # odd K again
        x = _rand((2, K), 2)
        qt = quant.quantize_weight(_rand((K, N), 3), fmt="int4")
        got = int4_gemv(x, qt.q, qt.scale.reshape(-1), k_size=K,
                        block_k=32, block_n=64, interpret=True)
        want = x @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_int4_tol(qt, K))

    def test_fp8_gemm_matches_cast_reference(self):
        M, K, N = 17, 40, 24
        a = _rand((M, K), 4)
        qt = quant.quantize_weight(_rand((K, N), 5, scale=3.0), fmt="fp8")
        af8 = jnp.clip(a, -quant.FP8_MAX, quant.FP8_MAX).astype(
            quant.FP8_DTYPE)
        got = fp8_gemm(af8, qt.q, qt.scale.reshape(-1), block=(8, 16, 16),
                       interpret=True)
        want = af8.astype(jnp.float32) @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_fp8_weight_only_float_lhs(self):
        a = _rand((12, 32), 6)
        qt = quant.quantize_weight(_rand((32, 16), 7), fmt="fp8")
        got = fp8_gemm(a, qt.q, qt.scale.reshape(-1), block=(8, 16, 16),
                       interpret=True)
        want = a @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @given(m=st.integers(1, 24), k=st.integers(1, 48), n=st.integers(1, 32),
           seed=st.integers(0, 100))
    @settings(max_examples=12, deadline=None)
    def test_int4_gemm_fuzz(self, m, k, n, seed):
        a = _rand((m, k), seed, scale=2.0)
        qt = quant.quantize_weight(_rand((k, n), seed + 1, scale=3.0),
                                   fmt="int4")
        got = int4_gemm(a, qt.q, qt.scale.reshape(-1), k_size=k,
                        block=(16, 16, 16), interpret=True)
        want = a @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_int4_tol(qt, k))

    @given(m=st.integers(1, 24), k=st.integers(1, 40), n=st.integers(1, 32),
           seed=st.integers(0, 100))
    @settings(max_examples=12, deadline=None)
    def test_fp8_gemm_fuzz(self, m, k, n, seed):
        a = _rand((m, k), seed, scale=2.0)
        qt = quant.quantize_weight(_rand((k, n), seed + 1, scale=3.0),
                                   fmt="fp8")
        af8 = jnp.clip(a, -quant.FP8_MAX, quant.FP8_MAX).astype(
            quant.FP8_DTYPE)
        got = fp8_gemm(af8, qt.q, qt.scale.reshape(-1), block=(16, 16, 16),
                       interpret=True)
        want = af8.astype(jnp.float32) @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the scan invariant
# ---------------------------------------------------------------------------


class TestScanInvariant:
    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_scan_slices_dequantize_bit_exact(self, fmt):
        """lax.scan over a stacked QuantizedTensor: every sliced layer must
        dequantize to EXACTLY the rows the unstacked dequant produces."""
        L, K, N = 3, 33, 24                       # odd K exercises int4 pad
        w = _rand((L, K, N), 0, scale=2.0)
        stacked = quant.quantize_weight(w, reduce_axes=(-2,), fmt=fmt)
        whole = quant.dequantize(stacked)         # (L, K, N)

        def body(carry, qt):
            return carry, quant.dequantize(qt)

        _, sliced = jax.lax.scan(body, 0, stacked)
        assert sliced.shape == (L, K, N)
        np.testing.assert_array_equal(np.asarray(sliced), np.asarray(whole))

    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_scan_slice_matches_unstacked_quantization(self, fmt):
        """Stacked quantization with reduce_axes=(-2,) == quantizing each
        layer alone, through the scan slice."""
        L, K, N = 4, 16, 8
        w = _rand((L, K, N), 1, scale=3.0)
        stacked = quant.quantize_weight(w, reduce_axes=(-2,), fmt=fmt)

        def body(carry, qt):
            return carry, quant.dequantize(qt)

        _, sliced = jax.lax.scan(body, 0, stacked)
        for l in range(L):
            single = quant.dequantize(quant.quantize_weight(w[l], fmt=fmt))
            np.testing.assert_array_equal(np.asarray(sliced[l]),
                                          np.asarray(single))

    def test_scan_slices_per_layer_act_scale(self):
        """A stacked (L, 1, 1) act_scale must arrive in the scan body as the
        layer's own (1, 1) scalar -- the calibrated-serving invariant."""
        L, K, N = 3, 16, 8
        stacked = quant.quantize_weight(_rand((L, K, N), 2),
                                        reduce_axes=(-2,))
        scales = jnp.asarray([0.25, 0.5, 1.0], jnp.float32).reshape(L, 1, 1)
        stacked = dataclasses.replace(stacked, act_scale=scales)

        def body(carry, qt):
            assert qt.act_scale.shape == (1, 1)
            return carry, qt.act_scale.reshape(())

        _, got = jax.lax.scan(body, 0, stacked)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(scales.reshape(-1)))

    def test_scan_slice_helper_matches_scan(self):
        """quant.slice_leading (the calibration driver's slice) == what
        lax.scan hands the body."""
        L, K, N = 3, 33, 8
        stacked = quant.quantize_weight(_rand((L, K, N), 3),
                                        reduce_axes=(-2,), fmt="int4")
        for l in range(L):
            s = quant.slice_leading(stacked, l)
            assert s.fmt == "int4" and s.shape == (K, N)
            np.testing.assert_array_equal(np.asarray(s.q),
                                          np.asarray(stacked.q[l]))
            np.testing.assert_array_equal(
                np.asarray(quant.dequantize(s)),
                np.asarray(quant.dequantize(stacked)[l]))


# ---------------------------------------------------------------------------
# dispatch eligibility predicate + routing introspection
# ---------------------------------------------------------------------------


_PALLAS_INT8 = axon.ExecutionPolicy(backend="pallas", precision="int8")


def _with_act_scale(qt, x):
    amax = float(jnp.abs(x).max())
    return dataclasses.replace(
        qt, act_scale=jnp.full((1,) * qt.ndim, max(amax, 1e-12) / 127.0,
                               jnp.float32))


class TestQuantRoute:
    def test_int8_eligible(self):
        a = _rand((16, 32), 0)
        qt = quant.quantize_weight(_rand((32, 24), 1))
        route, _ = axon.quant_route("mk,kn->mn", a, qt, _PALLAS_INT8)
        assert route == "quant_gemm"

    def test_int4_and_fp8_routes(self):
        a = _rand((16, 32), 2)
        q4 = quant.quantize_weight(_rand((32, 24), 3), fmt="int4")
        q8f = quant.quantize_weight(_rand((32, 24), 4), fmt="fp8")
        assert axon.quant_route("mk,kn->mn", a, q4, _PALLAS_INT8)[0] \
            == "int4_gemm"
        assert axon.quant_route("mk,kn->mn", a, q8f, _PALLAS_INT8)[0] \
            == "fp8_gemm"

    def test_float_policy_falls_back(self):
        a = _rand((16, 32), 5)
        qt = quant.quantize_weight(_rand((32, 24), 6))
        route, reason = axon.quant_route(
            "mk,kn->mn", a, qt, axon.ExecutionPolicy(backend="pallas"))
        assert route == "dequant" and "float" in reason

    def test_xla_backend_falls_back(self):
        a = _rand((16, 32), 7)
        qt = quant.quantize_weight(_rand((32, 24), 8))
        route, reason = axon.quant_route(
            "mk,kn->mn", a, qt,
            axon.ExecutionPolicy(backend="xla", precision="int8"))
        assert route == "dequant" and "xla" in reason

    def test_shared_batch_falls_back(self):
        a = _rand((3, 4, 16), 9)
        qt = quant.quantize_weight(_rand((3, 16, 8), 10), reduce_axes=(-2,))
        route, reason = axon.quant_route("ecd,edf->ecf", a, qt, _PALLAS_INT8)
        assert route == "dequant" and "B > 1" in reason

    def test_scale_on_contraction_axis_falls_back(self):
        qt = quant.quantize_weight(_rand((16, 12), 11), axis=0)
        a = _rand((16, 16), 12)
        route, reason = axon.quant_route("mk,kn->mn", a, qt, _PALLAS_INT8)
        assert route == "dequant" and "n-group" in reason

    def test_int4_non_identity_layout_falls_back(self):
        """Transposed contraction: the packed payload has no kernel layout
        (a hand-built (N, K)-layout int4 tensor must never reach the
        kernel, even with a clean per-channel scale on the n axis)."""
        a = _rand((16, 32), 13)
        N, K = 24, 32
        rng = np.random.default_rng(14)
        vals = jnp.asarray(rng.integers(-7, 8, (N, K)), jnp.int8)
        q4 = quant.QuantizedTensor(
            q=quant.pack_int4(vals, axis=-2),
            scale=jnp.abs(_rand((N, 1), 14)) + 0.1,
            axis=-2, bits=4, pack_size=N)
        route, reason = axon.quant_route("mk,nk->mn", a, q4, _PALLAS_INT8)
        assert route == "dequant" and "int4" in reason
        # ... while int8 takes the transposed layout fine
        q8 = quant.quantize_weight(_rand((24, 32), 15), axis=0)
        assert axon.quant_route("mk,nk->mn", a, q8, _PALLAS_INT8)[0] \
            == "quant_gemm"

    def test_integer_activation_falls_back(self):
        a = jnp.ones((8, 16), jnp.int32)
        qt = quant.quantize_weight(_rand((16, 8), 16))
        route, reason = axon.quant_route("mk,kn->mn", a, qt, _PALLAS_INT8)
        assert route == "dequant" and "non-float" in reason

    # -- routing asserted through the registry, not output values ----------

    def test_eligible_dispatch_invokes_kernel(self):
        a = _rand((16, 32), 17)
        qt = _with_act_scale(quant.quantize_weight(_rand((32, 24), 18)), a)
        with _spy("quant_gemm") as calls:
            with axon.policy(_PALLAS_INT8):
                axon.einsum("mk,kn->mn", a, qt)
        assert len(calls["quant_gemm"]) == 1
        dtype, shape = calls["quant_gemm"][0]
        assert dtype == "int8" and shape == (16, 32)   # activation quantized

    def test_weight_only_dispatch_keeps_float_lhs(self):
        a = _rand((16, 32), 19)
        qt = quant.quantize_weight(_rand((32, 24), 20))
        with _spy("quant_gemm") as calls:
            with axon.policy(_PALLAS_INT8):
                axon.einsum("mk,kn->mn", a, qt)
        dtype, _ = calls["quant_gemm"][0]
        assert dtype == "float32"

    def test_ineligible_dispatch_never_touches_quant_kernels(self):
        a = _rand((3, 4, 16), 21)
        qt = quant.quantize_weight(_rand((3, 16, 8), 22), reduce_axes=(-2,))
        with _spy("quant_gemm", "int4_gemm", "fp8_gemm") as calls:
            with axon.policy(_PALLAS_INT8):
                got = axon.einsum("ecd,edf->ecf", a, qt)
        assert all(not v for v in calls.values())
        want = jnp.einsum("ecd,edf->ecf", a, quant.dequantize(qt))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_int4_dispatch_invokes_int4_kernel(self):
        a = _rand((16, 32), 23)
        qt = quant.quantize_weight(_rand((32, 24), 24), fmt="int4")
        with _spy("int4_gemm", "quant_gemm") as calls:
            with axon.policy(_PALLAS_INT8):
                got = axon.einsum("mk,kn->mn", a, qt)
        assert len(calls["int4_gemm"]) == 1 and not calls["quant_gemm"]
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(a @ quant.dequantize(qt)),
            **_int4_tol(qt, 32))

    def test_int8_gemm_blocks_for_one_byte_traffic(self):
        """The kernel path asks the mapper for 1-byte blocking: after one
        int8 dispatch the (shape, bytes=1) decision is cached while the
        float-path (bytes=4) key is not."""
        from repro.core.dataflows import GemmShape
        from repro.core.mapper import select_tpu_blocking
        a = _rand((16, 64), 25)
        qt = _with_act_scale(quant.quantize_weight(_rand((64, 48), 26)), a)
        mapper_cache_clear()
        with axon.policy(_PALLAS_INT8):
            axon.einsum("mk,kn->mn", a, qt)
        n = sweep_calls()
        assert n >= 1
        select_tpu_blocking(GemmShape(16, 64, 48), bytes_per_elem=1)
        assert sweep_calls() == n            # hit: the int8 path cached it
        select_tpu_blocking(GemmShape(16, 64, 48), bytes_per_elem=4)
        assert sweep_calls() == n + 1        # the float key was never swept

    def test_tracer_guarded_calibration(self):
        """Under jit the calibration tap must observe nothing (tracers carry
        no values) while the dispatch still routes -- introspected, not
        inferred from outputs."""
        a = _rand((4, 16), 27)
        qt = quant.quantize_weight(_rand((16, 8), 28))
        with quant.calibration() as calib:
            jax.jit(lambda x, w: axon.einsum(
                "mk,kn->mn", x, w, policy=_PALLAS_INT8))(a, qt)
            assert calib.n_sites == 0
            axon.einsum("mk,kn->mn", a, qt, policy=_PALLAS_INT8)
            assert calib.n_sites == 1


# ---------------------------------------------------------------------------
# int8 attention
# ---------------------------------------------------------------------------


class TestInt8Attention:
    @pytest.mark.parametrize("b,h,kvh,sq,skv,dh", [
        (1, 4, 4, 1, 64, 16),      # pure decode, MHA
        (2, 8, 2, 4, 48, 16),      # chunked decode, GQA rep=4
        (1, 4, 1, 16, 33, 16),     # prefill chunk, MQA, ragged kv blocks
    ])
    def test_matches_float_flash_on_decode_shapes(self, b, h, kvh, sq, skv,
                                                  dh):
        ks = jax.random.split(jax.random.fold_in(KEY, sq * skv), 3)
        q = jax.random.normal(ks[0], (b, h, sq, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, kvh, skv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, kvh, skv, dh), jnp.float32)
        # decode geometry: queries sit at the END of the kv stream
        qpos = jnp.arange(skv - sq, skv)
        mask = jnp.broadcast_to(
            (jnp.arange(skv)[None, :] <= qpos[:, None])[None], (b, sq, skv))
        got = int8_flash_attention_fwd(q, k, v, mask=mask, block_q=16,
                                       block_kv=16, interpret=True)
        want = flash_attention_fwd(
            jnp.pad(q, ((0, 0), (0, 0), (skv - sq, 0), (0, 0))), k, v,
            causal=True, block_q=16, block_kv=16,
            interpret=True)[:, :, skv - sq:]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.05, atol=0.05)

    def test_causal_default_matches_explicit_mask(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 8, 16), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 8, 16), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 8, 16), jnp.float32)
        mask = jnp.broadcast_to(jnp.tril(jnp.ones((8, 8), bool))[None],
                                (1, 8, 8))
        a = int8_flash_attention_fwd(q, k, v, causal=True, block_q=8,
                                     block_kv=8, interpret=True)
        b = int8_flash_attention_fwd(q, k, v, mask=mask, block_q=8,
                                     block_kv=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cached_attention_int8_route(self):
        """layers.cached_attention under attn_int8 stays close to the float
        path on a decode-shaped per-slot cache (ragged lengths)."""
        from repro.models.layers import cached_attention
        B, T, H, KvH, S, dh = 2, 1, 4, 2, 32, 16
        ks = jax.random.split(jax.random.fold_in(KEY, 7), 5)
        q = jax.random.normal(ks[0], (B, T, H, dh), jnp.float32)
        k_old = jax.random.normal(ks[1], (B, S, KvH, dh), jnp.float32)
        v_old = jax.random.normal(ks[2], (B, S, KvH, dh), jnp.float32)
        k_new = jax.random.normal(ks[3], (B, T, KvH, dh), jnp.float32)
        v_new = jax.random.normal(ks[4], (B, T, KvH, dh), jnp.float32)
        start = jnp.asarray([20, 5], jnp.int32)      # ragged per-slot lengths
        q_pos = start[:, None]
        k_valid = jnp.ones((B, T), bool)
        kwargs = dict(q_pos=q_pos, k_valid=k_valid, start=start)
        ref = cached_attention(q, k_old, v_old, k_new, v_new, **kwargs)
        with axon.policy(backend="pallas", attn_int8=True):
            got = cached_attention(q, k_old, v_old, k_new, v_new, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0.06, atol=0.06)

    def test_stale_cache_entries_do_not_pollute_scales(self):
        """reset_slots leaves old KV contents in place beyond each slot's
        ``start``; the int8 path must exclude them from the per-head abs-max
        or a previous request's outlier coarsens every live token."""
        from repro.models.layers import cached_attention
        B, T, H, KvH, S, dh = 2, 1, 4, 2, 32, 16
        ks = jax.random.split(jax.random.fold_in(KEY, 11), 5)
        q = jax.random.normal(ks[0], (B, T, H, dh), jnp.float32)
        k_old = jax.random.normal(ks[1], (B, S, KvH, dh), jnp.float32)
        v_old = jax.random.normal(ks[2], (B, S, KvH, dh), jnp.float32)
        k_new = jax.random.normal(ks[3], (B, T, KvH, dh), jnp.float32)
        v_new = jax.random.normal(ks[4], (B, T, KvH, dh), jnp.float32)
        start = jnp.asarray([12, 6], jnp.int32)
        # stale garbage from a previous occupant, 100x the live magnitudes
        stale = jnp.arange(S)[None, :, None, None] >= start[:, None, None,
                                                           None]
        k_old = jnp.where(stale, 100.0, k_old)
        v_old = jnp.where(stale, -100.0, v_old)
        kwargs = dict(q_pos=start[:, None], k_valid=jnp.ones((B, T), bool),
                      start=start)
        ref = cached_attention(q, k_old, v_old, k_new, v_new, **kwargs)
        with axon.policy(backend="pallas", attn_int8=True):
            got = cached_attention(q, k_old, v_old, k_new, v_new, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0.06, atol=0.06)

    def test_xla_backend_ignores_attn_int8(self):
        from repro.models.layers import cached_attention
        B, T, H, S, dh = 1, 1, 2, 8, 16
        ks = jax.random.split(KEY, 5)
        args = [jax.random.normal(ks[0], (B, T, H, dh)),
                jax.random.normal(ks[1], (B, S, H, dh)),
                jax.random.normal(ks[2], (B, S, H, dh)),
                jax.random.normal(ks[3], (B, T, H, dh)),
                jax.random.normal(ks[4], (B, T, H, dh))]
        kwargs = dict(q_pos=jnp.asarray([[4]]), k_valid=jnp.ones((1, 1), bool),
                      start=jnp.asarray([4]))
        ref = cached_attention(*args, **kwargs)
        with axon.policy(backend="xla", attn_int8=True):
            got = cached_attention(*args, **kwargs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# golden values: the modeled precision figures cannot move silently
# ---------------------------------------------------------------------------


class TestGoldenPrecision:
    def test_operand_bytes_table(self):
        assert operand_bytes("bf16") == 2
        assert operand_bytes("int8") == 1
        assert operand_bytes("fp8") == 1
        assert operand_bytes("int4") == 0.5
        with pytest.raises(ValueError):
            operand_bytes("int2")

    def test_reduced_resnet50_precision_pinned(self):
        """Golden pins for paper_report()["precision"] on reduced ResNet50.
        If an energy-model refactor moves these, it moved the paper
        figures -- update deliberately or fix the regression."""
        rep = paper_report(get_vision_config("resnet50", reduced=True))
        per = rep["precision"]
        assert set(per) >= {"bf16", "int8", "fp8", "int4", "int8_vs_bf16",
                            "fp8_vs_bf16", "int4_vs_bf16"}
        np.testing.assert_allclose(per["bf16"]["operand_bytes"], 48160.0)
        np.testing.assert_allclose(per["int8"]["operand_bytes"], 24080.0)
        np.testing.assert_allclose(per["fp8"]["operand_bytes"], 24080.0)
        np.testing.assert_allclose(per["int4"]["operand_bytes"], 12040.0)
        np.testing.assert_allclose(per["bf16"]["dram_energy_j"], 5.7792e-06,
                                   rtol=1e-9)
        np.testing.assert_allclose(per["int4"]["dram_energy_j"], 1.4448e-06,
                                   rtol=1e-9)
        for prec, traffic, energy in [("int8", 0.5, 2.0), ("fp8", 0.5, 2.0),
                                      ("int4", 0.25, 4.0)]:
            ratios = per[f"{prec}_vs_bf16"]
            np.testing.assert_allclose(ratios["traffic_ratio"], traffic)
            np.testing.assert_allclose(ratios["energy_ratio"], energy)
            # the reduced model is compute-bound on the paper's 16x16 array:
            # narrower operands cut energy, not the roofline runtime
            np.testing.assert_allclose(ratios["throughput_speedup"], 1.0)
        # runtime invariant under precision in the compute-bound regime
        np.testing.assert_allclose(per["bf16"]["runtime_s"],
                                   per["int4"]["runtime_s"])


# ---------------------------------------------------------------------------
# acceptance: calibrated-activation int8 LM serving through lax.scan
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_ptq():
    cfg = get_config("yi-9b", reduced=True)
    params = T.init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
        rng.integers(2, cfg.vocab, (2, 12)), jnp.int32)} for _ in range(2)]
    qparams = quant.quantize_lm(params, cfg, batches)
    return cfg, params, qparams


class TestCalibratedLMServing:
    def test_per_layer_scales_present(self, lm_ptq):
        _, _, qparams = lm_ptq
        leaves = [l for l in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, quant.QuantizedTensor))
            if isinstance(l, quant.QuantizedTensor)]
        assert leaves and all(l.act_scale is not None for l in leaves)
        stacked = [l for l in leaves if l.q.ndim == 3]
        assert stacked, "expected scan-stacked projection weights"
        for l in stacked:
            L = l.q.shape[0]
            assert l.act_scale.shape == (L,) + (1,) * (l.ndim - 1)
            assert bool(jnp.all(l.act_scale > 0))

    def test_chunk_step_runs_full_int8_inside_scan(self, lm_ptq):
        """The scan-staged decode step must quantize activations (int8 lhs
        reaches quant_gemm) -- per-layer scales are used, not just stored."""
        cfg, _, qparams = lm_ptq
        caches = T.init_caches(cfg, batch=2, max_len=16, dtype=jnp.float32)
        toks = jnp.asarray([[5, 6, 7, 8], [9, 3, 2, 4]], jnp.int32)
        valid = jnp.ones((2, 4), bool)
        step = make_chunk_step(cfg, policy=_PALLAS_INT8)
        with _spy("quant_gemm") as calls:
            # trace (not jit-cached) so registry impls run on tracers
            jax.make_jaxpr(step)(qparams, caches, toks, valid,
                                 jax.random.PRNGKey(0))
        assert any(dtype == "int8" for dtype, _ in calls["quant_gemm"])

    def test_logits_close_to_float(self, lm_ptq):
        cfg, params, qparams = lm_ptq
        batch = {"tokens": jnp.asarray([[5, 6, 7, 8, 9, 3]], jnp.int32)}
        hid_f, _ = T.forward(params, batch, cfg)
        logits_f = T._head_logits(params, hid_f, cfg)
        with axon.policy(_PALLAS_INT8):
            hid_q, _ = jax.jit(
                lambda p, b: T.forward(p, b, cfg))(qparams, batch)
            logits_q = T._head_logits(qparams, hid_q, cfg)
        rel = float(jnp.linalg.norm(logits_q - logits_f)
                    / jnp.linalg.norm(logits_f))
        assert rel < 0.2, rel

    def test_serve_engine_end_to_end(self, lm_ptq):
        cfg, params, qparams = lm_ptq
        reqs = [Request(prompt=[5, 6, 7], max_new_tokens=4, eos_id=1),
                Request(prompt=[9, 3], max_new_tokens=3, eos_id=1)]
        eng_f = ServeEngine(params, cfg, batch_slots=2, max_len=32)
        out_f = eng_f.generate(reqs)
        eng_q = ServeEngine(qparams, cfg, batch_slots=2, max_len=32,
                            policy=axon.ExecutionPolicy(backend="pallas"),
                            quantized=True)
        assert eng_q._step is not None
        out_q = eng_q.generate(reqs)
        assert [len(o) for o in out_q] == [len(o) for o in out_f]
        assert eng_q.last_stats["generated_tokens"] == sum(
            len(o) for o in out_q)

    def test_serve_engine_int4_and_fp8_modes(self):
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        reqs = [Request(prompt=[5, 6], max_new_tokens=2, eos_id=1)]
        for mode in ("int4", "fp8"):
            eng = ServeEngine(params, cfg, batch_slots=1, max_len=16,
                              quantized=mode)
            assert quant.is_quantized(eng.params)
            fmts = {l.fmt for l in jax.tree.leaves(
                eng.params,
                is_leaf=lambda x: isinstance(x, quant.QuantizedTensor))
                if isinstance(l, quant.QuantizedTensor)}
            assert fmts == {mode}
            out = eng.generate(reqs)
            assert len(out[0]) == 2

    def test_pre_quantized_fp8_params_serve_at_fp8(self):
        """Precision follows the weights' storage format: pre-quantized fp8
        params (no quantized= argument) must flip the policy to "fp8",
        identical to constructing with quantized="fp8"."""
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        qp = quant.quantize_lm_weights(params, fmt="fp8")
        eng_pre = ServeEngine(qp, cfg, batch_slots=1, max_len=16)
        eng_arg = ServeEngine(params, cfg, batch_slots=1, max_len=16,
                              quantized="fp8")
        assert eng_pre._step is not None and eng_arg._step is not None
        # both constructions resolve the same serving precision
        for eng in (eng_pre, eng_arg):
            out = eng.generate(
                [Request(prompt=[5, 6], max_new_tokens=2, eos_id=1)])
            assert len(out[0]) == 2

    def test_calibration_layer_slices_are_memoized(self):
        """Repeated batches must reuse one slice per (weight, layer) --
        calibration memory stays O(params), not O(params x batches)."""
        stacked = quant.quantize_weight(_rand((3, 16, 8), 40),
                                        reduce_axes=(-2,))
        with quant.calibration() as calib:
            first = [calib.layer_slice(stacked, l) for l in range(3)]
            second = [calib.layer_slice(stacked, l) for l in range(3)]
        assert all(a is b for a, b in zip(first, second))
        assert len(calib._alias) == 3

    def test_serve_engine_attn_int8_decode(self):
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        reqs = [Request(prompt=[5, 6, 7], max_new_tokens=2, eos_id=1)]
        eng = ServeEngine(params, cfg, batch_slots=1, max_len=16,
                          policy=axon.ExecutionPolicy(backend="pallas"),
                          attn_int8=True)
        out = eng.generate(reqs)
        assert len(out[0]) == 2
        assert all(0 <= t < cfg.vocab for t in out[0])
