"""Mesh-parallel serving: sharding rules and token-identity regressions.

The load-bearing property is BIT identity: an engine sharded over a mesh
must produce exactly the tokens the single-device engine produces, for
every cache layout (dense float, paged int8, int8 flash decode) and for
the decoupled prefill->insert->generate path.  The multi-device cases run
under 8 fake CPU devices (the CI ``mesh`` shard sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and are skipped
when fewer devices are visible, so the tier-1 shards still execute the
single-device rows: sharding-rule units, off-mesh no-ops, mesh(1,1)
identity, and decoupled-vs-inline identity.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_vision_config
from repro.launch.mesh import make_debug_mesh, parse_mesh
from repro.models import transformer as T
from repro.parallel import sharding as shd
from repro.serve.engine import Request, ServeEngine
from repro.vision import models as vmodels
from repro.vision.engine import ImageRequest, VisionEngine

KEY = jax.random.PRNGKey(0)

multi = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI mesh shard) before jax initialises")


def _stub_mesh(**shape):
    """Shape-only stand-in for the pure spec functions (resolve,
    make_cache_spec_fn) -- they read only axis_names and shape, so rules
    for meshes far larger than the test host stay unit-testable."""
    return types.SimpleNamespace(axis_names=tuple(shape), shape=shape)


def _cfg(arch):
    cfg = get_config(arch, reduced=True)
    # lift MoE capacity so chunked prefill and decode route identically
    return dataclasses.replace(cfg, capacity_factor=64.0)


def _engine(params, cfg, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("temperature", 0.0)
    kw.setdefault("seed", 0)
    return ServeEngine(params, cfg, **kw)


def _tokens(params, cfg, reqs, **kw):
    return [list(map(int, o)) for o in _engine(params, cfg, **kw)
            .generate(reqs)]


def _requests(cfg, lens, max_new=5, seed=1):
    key = jax.random.PRNGKey(seed)
    reqs = []
    for plen in lens:
        key, sub = jax.random.split(key)
        prompt = [int(t) for t in jax.random.randint(sub, (plen,), 2,
                                                     cfg.vocab)]
        reqs.append(Request(prompt=prompt, max_new_tokens=max_new,
                            eos_id=-1))
    return reqs


class TestShardingRules:
    def test_resolve_drops_nondivisible_axis(self):
        mesh = _stub_mesh(data=2, model=16)
        # 24 heads don't divide model=16 -> replicated; 32 do -> sharded
        assert shd.resolve(mesh, (None, "model", None), (4, 24, 64)) \
            == jax.sharding.PartitionSpec(None, None, None)
        assert shd.resolve(mesh, (None, "model", None), (4, 32, 64)) \
            == jax.sharding.PartitionSpec(None, "model", None)

    def test_resolve_drops_unknown_axis_names(self):
        mesh = _stub_mesh(data=2, model=4)
        assert shd.resolve(mesh, (("pod", "data"), None), (8, 8)) \
            == jax.sharding.PartitionSpec("data", None)

    def test_fsdp_expansion_single_and_multi_pod(self):
        assert shd.fsdp_axes(_stub_mesh(data=4, model=4)) == ("data",)
        assert shd.fsdp_axes(
            _stub_mesh(pod=2, data=4, model=4)) == ("pod", "data")
        assert shd.batch_axes(_stub_mesh(data=4, model=4)) == ("data",)

    def test_resolve_composite_fsdp_batch_divisibility(self):
        mesh = _stub_mesh(pod=2, data=4, model=4)
        # batch 8 divides pod*data=8 -> composite entry survives whole
        assert shd.resolve(mesh, (("pod", "data"), None), (8, 16)) \
            == jax.sharding.PartitionSpec(("pod", "data"), None)
        # batch 4 does not divide 8 -> replicated
        assert shd.resolve(mesh, (("pod", "data"), None), (4, 16)) \
            == jax.sharding.PartitionSpec(None, None)

    def test_current_mesh_none_off_mesh(self):
        assert shd.current_mesh() is None

    def test_current_mesh_inside_context(self):
        mesh = make_debug_mesh(1, 1)
        with mesh:
            got = shd.current_mesh()
            assert got is not None
            assert dict(got.shape) == {"data": 1, "model": 1}
        assert shd.current_mesh() is None

    def test_constrain_noop_off_mesh(self):
        x = jnp.arange(6.0).reshape(2, 3)
        assert shd.constrain(x, "batch", "model") is x
        assert shd.constrain_priority(x, 1, [1]) is x

    def test_make_debug_mesh_requires_devices(self):
        need = 4 * jax.device_count()
        with pytest.raises(ValueError, match="devices"):
            make_debug_mesh(need, 1)

    def test_parse_mesh(self):
        assert parse_mesh(None) is None
        assert parse_mesh("") is None
        assert dict(parse_mesh("1x1").shape) == {"data": 1, "model": 1}
        with pytest.raises(ValueError, match="DATAxMODEL"):
            parse_mesh("2x2x2")


class TestCacheSpecRules:
    def _entries(self, name, shape, *, layered=False, **mesh_shape):
        mesh_shape = mesh_shape or {"data": 2, "model": 4}
        fn = shd.make_cache_spec_fn(_stub_mesh(**mesh_shape))
        path = [jax.tree_util.DictKey("layers")] if layered else []
        path.append(jax.tree_util.DictKey(name))
        return fn(tuple(path), shape)

    def test_dense_kv_shards_kv_heads(self):
        got = self._entries("k", (4, 2, 16, 8, 64), layered=True)
        assert got == (None, "batch", None, "model", None)

    def test_dense_kv_falls_back_to_sequence(self):
        # 6 kv-heads don't divide model=4, seq 16 does
        got = self._entries("k", (4, 2, 16, 6, 64), layered=True)
        assert got == (None, "batch", "model", None, None)

    def test_paged_pool_shards_kv_head_axis(self):
        got = self._entries("k_pages", (4, 32, 4, 8, 64), layered=True)
        assert got == (None, None, None, "model", None)

    def test_paged_pool_replicates_nondivisible_heads(self):
        got = self._entries("k_pages", (4, 32, 4, 6, 64), layered=True)
        assert got == (None, None, None, None, None)

    def test_scale_pool_mirrors_payload(self):
        got = self._entries("k_scales", (4, 32, 4, 8), layered=True)
        assert got == (None, None, None, "model")

    def test_page_table_always_replicated(self):
        got = self._entries("page_table", (8, 16))
        assert got == (None, None)

    def test_slot_counters_follow_batch(self):
        assert self._entries("len", (8,)) == ("batch",)


class TestMeshIdentitySingleDevice:
    """Tier-1 rows: run on one device, prove mesh(1,1) and the decoupled
    prefill path change nothing."""

    def test_mesh_1x1_token_identity(self):
        cfg = _cfg("yi-9b")
        params = T.init_params(KEY, cfg)
        reqs = _requests(cfg, [5, 3])
        base = _tokens(params, cfg, reqs)
        meshed = _tokens(params, cfg, reqs, mesh=make_debug_mesh(1, 1))
        assert meshed == base

    def test_decoupled_prefill_matches_inline(self):
        cfg = _cfg("yi-9b")
        params = T.init_params(KEY, cfg)
        reqs = _requests(cfg, [5, 3, 7])
        inline = _tokens(params, cfg, reqs)
        dec = _tokens(params, cfg, reqs, decouple_prefill=True)
        assert dec == inline

    def test_decoupled_prefill_reports_stats(self):
        cfg = _cfg("yi-9b")
        params = T.init_params(KEY, cfg)
        eng = _engine(params, cfg, decouple_prefill=True)
        eng.generate(_requests(cfg, [5, 3]))
        assert eng.last_stats["decoupled_prefill_tokens"] == 8
        assert eng.declared_step_widths() == (1,)
        assert eng.declared_prefill_widths() == (eng.prefill_chunk,)

    def test_paged_decouple_rejected(self):
        cfg = _cfg("yi-9b")
        params = T.init_params(KEY, cfg)
        with pytest.raises(ValueError, match="decouple"):
            _engine(params, cfg, paged=True, page_size=4,
                    decouple_prefill=True)


@multi
class TestMeshIdentityMultiDevice:
    """The 8-fake-device rows: mesh(2,4) = DP x TP must be bit-identical
    to the un-meshed engine for every cache layout."""

    def _check(self, arch, **kw):
        cfg = _cfg(arch)
        params = T.init_params(KEY, cfg)
        reqs = _requests(cfg, [5, 3, 7], max_new=4)
        base = _tokens(params, cfg, reqs, **kw)
        meshed = _tokens(params, cfg, reqs, mesh=make_debug_mesh(2, 4),
                         **kw)
        assert meshed == base

    def test_float(self):
        self._check("yi-9b")

    def test_paged_int8(self):
        self._check("yi-9b", paged=True, page_size=4, cache_fmt="int8")

    def test_attn_int8(self):
        self._check("yi-9b", attn_int8=True)

    def test_decoupled_prefill(self):
        self._check("yi-9b", decouple_prefill=True)

    def test_decoupled_swa_moe(self):
        self._check("mixtral-8x7b", decouple_prefill=True)

    def test_decoupled_mla(self):
        self._check("deepseek-v3-671b", decouple_prefill=True)

    def test_mesh_stats_row(self):
        cfg = _cfg("yi-9b")
        params = T.init_params(KEY, cfg)
        eng = _engine(params, cfg, mesh=make_debug_mesh(2, 4))
        eng.generate(_requests(cfg, [5]))
        assert eng.last_stats["mesh"] == {
            "devices": 8, "axes": {"data": 2, "model": 4}}


@multi
class TestVisionMeshIdentity:
    def test_data_parallel_identity(self):
        cfg = get_vision_config("resnet50", reduced=True)
        params = vmodels.init(KEY, cfg)
        rng = np.random.default_rng(0)
        reqs = [ImageRequest(image=rng.standard_normal(
                    (*cfg.input_hw, cfg.in_channels)).astype(np.float32))
                for _ in range(5)]

        def run(mesh=None):
            eng = VisionEngine(params, cfg, batch_slots=4, mesh=mesh)
            eng.warmup()
            return eng.infer(reqs), eng.last_stats

        base, _ = run()
        meshed, st = run(make_debug_mesh(8, 1))
        for a, b in zip(base, meshed):
            jax.tree.map(np.testing.assert_array_equal, a, b)
        assert st["mesh"] == {"devices": 8,
                              "axes": {"data": 8, "model": 1}}
