"""Layer-level correctness: chunked attention vs naive softmax, SSD vs
sequential recurrence, MoE vs per-token dense evaluation, and
prefill-vs-decode consistency for every cache type."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig, StageCfg
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, *, causal=True, window=0):
    B, Sq, H, dh = q.shape
    Skv, KvH = k.shape[1], k.shape[2]
    rep = H // KvH
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * dh**-0.5
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


class TestFlashAttention:
    @pytest.mark.parametrize("exact", [False, True])
    @pytest.mark.parametrize("window", [0, 8])
    @pytest.mark.parametrize("sq,h,kvh,dh", [(32, 4, 4, 16), (33, 8, 2, 8)])
    def test_vs_naive(self, exact, window, sq, h, kvh, dh):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, sq, h, dh))
        k = jax.random.normal(ks[1], (2, sq, kvh, dh))
        v = jax.random.normal(ks[2], (2, sq, kvh, dh))
        out = L.flash_attention(q, k, v, causal=True, window=window,
                                block_q=8, block_kv=8, exact_causal=exact)
        want = naive_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_different_dv(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 16, 4, 8))
        k = jax.random.normal(ks[1], (1, 16, 4, 8))
        v = jax.random.normal(ks[2], (1, 16, 4, 12))
        out = L.flash_attention(q, k, v, block_q=4, block_kv=4)
        want = naive_attention(q, k, v)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


class TestSSD:
    def _sequential(self, x, dt, A, b, c):
        """Oracle: literal per-step recurrence."""
        B, Lq, H, P = x.shape
        N = b.shape[-1]
        h = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(Lq):
            dec = jnp.exp(dt[:, t] * A)                      # (B, H)
            db = dt[:, t, :, None, None] * b[:, t, None, None, :]
            h = h * dec[..., None, None] + db * x[:, t, ..., None]
            ys.append(jnp.einsum("bhpn,bn->bhp", h, c[:, t]))
        return jnp.stack(ys, axis=1)

    @pytest.mark.parametrize("l,chunk", [(16, 4), (17, 4), (12, 12), (8, 16)])
    def test_chunked_vs_sequential(self, l, chunk):
        ks = jax.random.split(KEY, 5)
        B, H, P, N = 2, 3, 4, 5
        x = jax.random.normal(ks[0], (B, l, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, l, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        b = jax.random.normal(ks[3], (B, l, N))
        c = jax.random.normal(ks[4], (B, l, N))
        y, final = SSM.ssd_chunked(x, dt, A, b, c, chunk=chunk)
        want = self._sequential(x, dt, A, b, c)
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)

    def test_final_state_consistent_with_step(self):
        ks = jax.random.split(KEY, 5)
        B, l, H, P, N = 1, 8, 2, 3, 4
        x = jax.random.normal(ks[0], (B, l, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, l, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        b = jax.random.normal(ks[3], (B, l, N))
        c = jax.random.normal(ks[4], (B, l, N))
        _, final = SSM.ssd_chunked(x, dt, A, b, c, chunk=4)
        # replay sequentially
        h = jnp.zeros((B, H, P, N))
        for t in range(l):
            dec = jnp.exp(dt[:, t] * A)
            db = dt[:, t, :, None, None] * b[:, t, None, None, :]
            h = h * dec[..., None, None] + db * x[:, t, ..., None]
        np.testing.assert_allclose(final, h, rtol=1e-4, atol=1e-4)


class TestMamba1Scan:
    def test_assoc_scan_vs_loop(self):
        ks = jax.random.split(KEY, 2)
        B, Lq, D, N = 2, 12, 4, 3
        abar = jax.nn.sigmoid(jax.random.normal(ks[0], (B, Lq, D, N)))
        bx = jax.random.normal(ks[1], (B, Lq, D, N))
        h = SSM._selective_scan(abar, bx)
        ref = jnp.zeros((B, D, N))
        for t in range(Lq):
            ref = abar[:, t] * ref + bx[:, t]
            np.testing.assert_allclose(h[:, t], ref, rtol=1e-5, atol=1e-6)


class TestMoE:
    def _cfg(self, e=4, k=2, cap=8.0):
        return get_config("mixtral-8x7b", reduced=True).__class__(
            **{**dataclasses.asdict(get_config("mixtral-8x7b", reduced=True)),
               "n_experts": e, "top_k": k, "capacity_factor": cap})

    def test_vs_dense_reference(self):
        # with a huge capacity factor nothing drops; compare against a
        # per-token dense evaluation of the selected experts.
        cfg = self._cfg(cap=64.0)
        p = MOE.init_moe(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
        out, aux = MOE.moe_fwd(p, x, cfg)

        xf = x.reshape(-1, cfg.d_model)
        logits = xf @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        vals, idx = jax.lax.top_k(probs, cfg.top_k)
        vals = vals / vals.sum(-1, keepdims=True)
        want = jnp.zeros_like(xf)
        for t in range(xf.shape[0]):
            for j in range(cfg.top_k):
                e = int(idx[t, j])
                g = xf[t] @ p["w_gate"][e]
                u = xf[t] @ p["w_up"][e]
                y = (jax.nn.silu(g) * u) @ p["w_down"][e]
                want = want.at[t].add(vals[t, j] * y)
        np.testing.assert_allclose(out.reshape(-1, cfg.d_model), want,
                                   rtol=5e-4, atol=5e-4)
        assert np.isfinite(float(aux))

    def test_capacity_drops_dont_crash(self):
        cfg = self._cfg(cap=0.25)       # aggressive dropping
        p = MOE.init_moe(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        out, _ = MOE.moe_fwd(p, x, cfg)
        assert bool(jnp.isfinite(out).all())

    def test_chunked_equals_unchunked(self):
        # token chunking must not change results (same per-row capacity
        # semantics when nothing drops).
        cfg = self._cfg(cap=64.0)
        import dataclasses as dc
        p = MOE.init_moe(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
        full, _ = MOE.moe_fwd(p, x, dc.replace(cfg, moe_chunk=16))
        chunked, _ = MOE.moe_fwd(p, x, dc.replace(cfg, moe_chunk=4))
        np.testing.assert_allclose(full, chunked, rtol=5e-5, atol=5e-5)

    def test_aux_loss_balanced_at_uniform(self):
        # uniform router -> aux ~ 1.0 (per Switch normalization)
        cfg = self._cfg()
        p = MOE.init_moe(KEY, cfg)
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        _, aux = MOE.moe_fwd(p, x, cfg)
        assert 0.8 < float(aux) < 1.2


class TestPrefillDecodeConsistency:
    """Greedy decode after prefill must match teacher-forced prefill logits."""

    @pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b",
                                      "falcon-mamba-7b", "zamba2-7b",
                                      "deepseek-v3-671b"])
    def test_logits_match(self, arch):
        cfg = get_config(arch, reduced=True)
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no MoE drops
        params = T.init_params(KEY, cfg)
        Sq = 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, Sq), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        hidden, _ = T.forward(params, batch, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        want = jnp.einsum("bsd,dv->bsv", hidden, head)[..., : cfg.vocab]

        caches = T.init_caches(cfg, batch=1, max_len=Sq + 1, dtype=jnp.float32)
        got = []
        for t in range(Sq):
            logits, caches = T.decode_step(
                params, caches, {"tokens": toks[:, t:t + 1]}, cfg)
            got.append(logits[:, 0])
        got = jnp.stack(got, axis=1)
        np.testing.assert_allclose(got, want.astype(jnp.float32),
                                   rtol=2e-3, atol=2e-3)
