"""Analytical runtime model vs the paper's closed forms (Tables 1-2, Eqs. 1-3)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dataflows import ALL_DATAFLOWS, Dataflow, GemmShape, map_gemm
from repro.core.runtime_model import (
    ArrayShape,
    fill_latency_axon,
    fill_latency_sa,
    runtime_scaleout,
    runtime_scaleup,
    runtime_table2,
    speedup,
)

dims = st.integers(min_value=1, max_value=512)


class TestFillLatency:
    def test_square_halves(self):
        # §3.1: for R == C the fill drops from 2R-2 to R-1 (exactly half).
        for r in (2, 4, 16, 64, 256):
            a = ArrayShape(r, r)
            assert fill_latency_sa(a) == 2 * r - 2
            assert fill_latency_axon(a) == r - 1

    def test_paper_256_example(self):
        # §3.1: (256, 256) -> 510 cycles becomes 255.
        a = ArrayShape(256, 256)
        assert fill_latency_sa(a) == 510
        assert fill_latency_axon(a) == 255

    @given(r=dims, c=dims)
    def test_axon_never_worse(self, r, c):
        a = ArrayShape(r, c)
        assert fill_latency_axon(a) <= fill_latency_sa(a)


class TestTable2ClosedForms:
    """runtime_scaleup with a full-size array must equal Table 2 exactly."""

    @given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64))
    @settings(max_examples=200)
    def test_full_size_mapping(self, m, k, n):
        shape = GemmShape(m, k, n)
        for df in ALL_DATAFLOWS:
            st_map = map_gemm(shape, df)
            arr = ArrayShape(st_map.S_R, st_map.S_C)
            for axon in (False, True):
                got = runtime_scaleup(shape, arr, df, axon=axon)
                want = runtime_table2(shape, df, axon=axon)
                assert got == want, (df, axon, shape)


class TestScaling:
    def test_eq2_tiling_factors(self):
        # 2x2 tiles of a 16x16 array: runtime scales by exactly 4.
        shape = GemmShape(32, 100, 32)
        arr = ArrayShape(16, 16)
        one = GemmShape(16, 100, 16)
        assert runtime_scaleup(shape, arr, Dataflow.OS, axon=False) == \
            4 * runtime_scaleup(one, arr, Dataflow.OS, axon=False)

    def test_eq3_scaleout(self):
        shape = GemmShape(64, 128, 64)
        arr = ArrayShape(16, 16)
        t_1 = runtime_scaleout(shape, arr, Dataflow.OS,
                               partitions_r=1, partitions_c=1, axon=False)
        t_4 = runtime_scaleout(shape, arr, Dataflow.OS,
                               partitions_r=2, partitions_c=2, axon=False)
        assert t_1 == runtime_scaleup(shape, arr, Dataflow.OS, axon=False)
        assert t_4 == t_1 // 4  # perfectly divisible here

    @given(m=dims, k=dims, n=dims,
           r=st.sampled_from([4, 8, 16, 32]), c=st.sampled_from([4, 8, 16, 32]))
    @settings(max_examples=200)
    def test_axon_always_at_least_as_fast(self, m, k, n, r, c):
        shape = GemmShape(m, k, n)
        arr = ArrayShape(r, c)
        for df in ALL_DATAFLOWS:
            assert runtime_scaleup(shape, arr, df, axon=True) <= \
                runtime_scaleup(shape, arr, df, axon=False)

    def test_speedup_bounded_by_2(self):
        # fill halves; total speedup is < 2 and approaches 2 only when the
        # fill term dominates (T small, square array).
        shape = GemmShape(256, 1, 256)
        arr = ArrayShape(256, 256)
        s = speedup(shape, arr, Dataflow.OS)
        assert 1.0 < s < 2.0
        assert s > 1.4  # fill-dominated regime


class TestPaperHeadlines:
    """Paper-verifiable claims on the Table 3 suite (Fig. 12).

    Note (EXPERIMENTS.md §Fidelity): the paper's *suite averages* (1.47x at
    64x64, 1.76x at 256x256) are not derivable from Eq. 2 / Table 2 as
    printed -- with the per-tile readout term the square-array speedup is
    bounded by 1.5x.  We therefore assert the claims that ARE unambiguous:
    the closed forms themselves (TestTable2ClosedForms), the 510->255 fill
    halving, 'up to 2x' in the fill-dominated limit (with readout pipelined
    under the next tile's fill), monotone improvement with array size, and
    the temporal-dimension-limited workloads (DB0) seeing ~no benefit.
    """

    def _speedups(self, r, overlap=True, df=Dataflow.OS):
        # Same dataflow on both sides: the paper's comparison is
        # per-dataflow ("speeds up GeMM irrespective of dataflow"), and the
        # implemented hardware is OS (§5.1).
        from repro.core.workloads import TABLE3
        arr = ArrayShape(r, r)
        out = {}
        for name, shape in TABLE3.items():
            t_sa = runtime_scaleup(shape, arr, df, axon=False,
                                   overlap_readout=overlap)
            t_ax = runtime_scaleup(shape, arr, df, axon=True,
                                   overlap_readout=overlap)
            out[name] = t_sa / t_ax
        return out

    def test_all_workloads_speed_up(self):
        for name, s in self._speedups(64).items():
            assert s >= 1.0, name

    def test_up_to_2x_in_fill_dominated_limit(self):
        # GEMM_0 / GEMM_1 have T == 10 under OS; nearly pure fill.
        s = self._speedups(256)
        assert s["GEMM_1"] > 1.8, s["GEMM_1"]

    def test_db0_temporal_limited(self):
        # §5.2.1: DB0's runtime is limited by the temporal dimension
        # (K = 50000); scaling up / Axon barely helps.
        s = self._speedups(256)
        assert s["DB0"] < 1.05, s["DB0"]

    def test_larger_arrays_speed_up_more(self):
        def avg(r):
            v = list(self._speedups(r).values())
            return sum(v) / len(v)
        assert avg(256) > avg(64) > 1.1
