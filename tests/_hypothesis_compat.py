"""Optional-hypothesis shim shared by the property-test modules.

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis API when installed; otherwise ``@given`` marks the test skipped
and example-based tests in the same module still collect and run.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep: property tests skip, example tests run
    import pytest as _pytest

    def given(*_a, **_k):
        return lambda fn: _pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategiesStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategiesStub()
