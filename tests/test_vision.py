"""The vision subsystem: model zoo, engine, tracer, and table cross-checks.

Three layers of assurance:

  1. the reduced model zoo runs under ``backend="pallas"`` (interpret on
     CPU) and matches ``backend="xla"`` numerically, layer stack included;
  2. the engine's continuous batching returns exactly what a direct batched
     ``apply`` returns, in request order, for mixed-arrival traffic;
  3. shapes traced from the FULL executable models reproduce the
     hand-transcribed workload tables in ``repro.core.workloads`` exactly
     (the tables feed the paper figures -- transcription errors fail here),
     and drive the runtime/energy models to the paper's Axon-vs-SA ratios.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import axon
from repro.configs import VISION_IDS, get_vision_config
from repro.core import workloads
from repro.core.im2col_model import lower_to_gemm
from repro.vision import models, postprocess, preprocess, trace
from repro.vision.engine import ImageRequest, VisionEngine

KEY = jax.random.PRNGKey(0)


def _shape_tuple(c):
    return (c.H, c.W, c.C_in, c.C_out, c.n, c.stride, c.padding)


@pytest.fixture(scope="module")
def zoo():
    """Reduced params + a test batch per vision arch (init once per module)."""
    out = {}
    for name in VISION_IDS:
        cfg = get_vision_config(name, reduced=True)
        params = models.init(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (2, *cfg.input_hw, cfg.in_channels), cfg.pdtype)
        out[name] = (cfg, params, x)
    return out


class TestModelZoo:
    @pytest.mark.parametrize("name", VISION_IDS)
    def test_pallas_matches_xla(self, zoo, name):
        """The acceptance gate: forward under the kernel backend == XLA."""
        cfg, params, x = zoo[name]
        with axon.policy(backend="xla"):
            want = models.apply(params, x, cfg)
        with axon.policy(backend="pallas"):    # interpret-mode on CPU CI
            got = models.apply(params, x, cfg)
        assert jax.tree.structure(got) == jax.tree.structure(want)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert g.shape == w.shape
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-4, atol=2e-4)

    def test_output_shapes(self, zoo):
        cfg, params, x = zoo["resnet50"]
        with axon.policy(backend="xla"):
            logits = models.apply(params, x, cfg)
        assert logits.shape == (2, cfg.num_classes)
        cfg, params, x = zoo["yolov3-tiny"]
        with axon.policy(backend="xla"):
            dets = models.apply(params, x, cfg)
        assert set(dets) == {"det1", "det2"}
        h = cfg.input_hw[0] // 32
        assert dets["det1"].shape == (2, h, h, cfg.head_channels)
        assert dets["det2"].shape == (2, 2 * h, 2 * h, cfg.head_channels)

    def test_input_shape_validated(self, zoo):
        cfg, params, _ = zoo["resnet50"]
        bad = jnp.zeros((1, 8, 8, 3), cfg.pdtype)
        with pytest.raises(ValueError, match="expected input"):
            models.apply(params, bad, cfg)


class TestEngine:
    def test_matches_direct_apply_in_request_order(self, zoo):
        cfg, params, _ = zoo["mobilenet-v1"]
        rng = np.random.default_rng(0)
        imgs = rng.normal(size=(10, *cfg.input_hw, 3)).astype(np.float32)
        # staggered arrivals; batch_slots=4 forces multiple partial batches
        reqs = [ImageRequest(image=imgs[i], arrival_s=0.005 * (i // 3))
                for i in range(len(imgs))]
        eng = VisionEngine(params, cfg, batch_slots=4)
        eng.warmup()
        outs = eng.infer(reqs)
        with axon.policy(backend="xla"):
            want = models.apply(params, jnp.asarray(imgs), cfg)
        np.testing.assert_allclose(np.stack(outs), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_stats_and_occupancy(self, zoo):
        cfg, params, _ = zoo["mobilenet-v1"]
        rng = np.random.default_rng(1)
        reqs = [ImageRequest(image=rng.normal(
            size=(*cfg.input_hw, 3)).astype(np.float32)) for _ in range(8)]
        eng = VisionEngine(params, cfg, batch_slots=4)
        eng.warmup()
        eng.infer(reqs)
        st = eng.last_stats
        assert st["images"] == 8 and st["steps"] == 2
        assert st["mean_occupancy"] == pytest.approx(1.0)
        assert st["img_per_s"] > 0
        assert st["p99_latency_s"] >= st["p50_latency_s"] > 0

    def test_pytree_outputs_for_detector(self, zoo):
        cfg, params, _ = zoo["yolov3-tiny"]
        rng = np.random.default_rng(2)
        reqs = [ImageRequest(image=rng.normal(
            size=(*cfg.input_hw, 3)).astype(np.float32)) for _ in range(3)]
        eng = VisionEngine(params, cfg, batch_slots=2)
        outs = eng.infer(reqs)
        assert all(set(o) == {"det1", "det2"} for o in outs)
        with axon.policy(backend="xla"):
            want = models.apply(
                params, jnp.asarray(np.stack([r.image for r in reqs])), cfg)
        for i, o in enumerate(outs):
            np.testing.assert_allclose(o["det2"], np.asarray(want["det2"][i]),
                                       rtol=1e-5, atol=1e-5)

    def test_letterbox_compiles_outside_timed_loop(self, zoo):
        """Regression: first-seen letterbox geometries compiled INSIDE the
        timed loop, so a wave's p99 charged one-time compilation to serving
        latency.  _warm_geometries pre-traces every (shape, dtype) before
        the clock starts -- the timed loop must add no new traces, and two
        identical waves must report comparable latency percentiles."""
        cfg, params, _ = zoo["mobilenet-v1"]
        rng = np.random.default_rng(3)
        # odd geometries + mixed input dtypes (the jit retraces per dtype)
        shapes = [(17, 31, 3), (23, 9, 3), (17, 31, 3)]
        reqs = [ImageRequest(image=rng.normal(size=s).astype(np.float32))
                for s in shapes]
        reqs.append(ImageRequest(
            image=(rng.random((17, 31, 3)) * 255).astype(np.uint8)))
        eng = VisionEngine(params, cfg, batch_slots=4)
        eng.warmup()
        assert eng._warm_geometries(reqs) == 3   # 2 shapes x dtypes seen
        before = preprocess._letterbox_jit.cache_info()
        eng.infer(reqs)
        p99_first = eng.last_stats["p99_latency_s"]
        after = preprocess._letterbox_jit.cache_info()
        assert after.currsize == before.currsize  # no trace in the loop
        eng.infer(reqs)
        p99_second = eng.last_stats["p99_latency_s"]
        # both waves run warm: neither should carry a compile-sized spike
        # (a compile is ~100x a warm step; 10x absorbs scheduler jitter)
        assert p99_first < 10 * p99_second + 0.25

    def test_bad_image_shape_rejected(self, zoo):
        """Wrong channel count / rank is always rejected; wrong spatial size
        only when letterboxing is disabled (it is admitted otherwise)."""
        cfg, params, _ = zoo["mobilenet-v1"]
        eng = VisionEngine(params, cfg, batch_slots=2)
        with pytest.raises(ValueError, match="not servable"):
            eng.infer([ImageRequest(image=np.zeros((4, 4, 7), np.float32))])
        with pytest.raises(ValueError, match="not servable"):
            eng.infer([ImageRequest(image=np.zeros((4, 4), np.float32))])
        strict = VisionEngine(params, cfg, batch_slots=2, letterbox=False)
        with pytest.raises(ValueError, match="not servable"):
            strict.infer([ImageRequest(image=np.zeros((4, 4, 3),
                                                      np.float32))])


class TestTraceCrossValidation:
    """The satellite gate: hand tables == shapes traced from runnable models."""

    def test_resnet50_table_matches_trace(self):
        traced = trace.conv_shapes(get_vision_config("resnet50"))
        table = workloads.resnet50_convs()
        assert [_shape_tuple(c) for c in traced] \
            == [_shape_tuple(c) for c in table]

    def test_yolov3_table_matches_trace(self):
        traced = trace.conv_shapes(get_vision_config("yolov3"))
        table = workloads.yolov3_convs()
        assert [_shape_tuple(c) for c in traced] \
            == [_shape_tuple(c) for c in table]

    def test_yolov3_tiny_table_matches_trace(self):
        traced = trace.conv_shapes(get_vision_config("yolov3-tiny"))
        table = workloads.yolov3_tiny_convs()
        assert [_shape_tuple(c) for c in traced] \
            == [_shape_tuple(c) for c in table]

    def test_mobilenet_dw_table_matches_trace(self):
        """MOBILENET_DW lists the *unique* DW shapes (14x14x512 s1 runs 5x)."""
        recs = [r for r in trace.trace_model(get_vision_config("mobilenet-v1"))
                if r.depthwise]
        assert len(recs) == 13
        uniq = list(dict.fromkeys(
            _shape_tuple(trace.to_conv_shape(r)) for r in recs))
        assert uniq == [_shape_tuple(c) for c in workloads.MOBILENET_DW]

    @pytest.mark.parametrize("entry,model", [
        ("Resnet50_0_conv2d", "resnet50"),
        ("Resnet50_1_conv2d", "resnet50"),
        ("YOLO_v3_0_conv2d", "yolov3"),
        ("YOLO_v3_1_conv2d", "yolov3"),
    ])
    def test_table3_conv_gemms_vs_trace(self, entry, model):
        """Table 3's printed conv GeMMs carry real filter geometry but NOT
        real window counts: (M, K) = (C_out, n*n*C_in) must match a layer
        the runnable model executes, while the printed N disagrees with the
        standard @224/@416 architectures (e.g. Resnet50_1 prints N=676=26^2
        where the actual 3x3x512 layer has 49=7^2).  The traced shapes --
        validated layer-for-layer above -- therefore supersede Table 3 as
        the paper-figure inputs; this test documents the discrepancy."""
        printed = workloads.TABLE3[entry]
        traced = {g for _, g in trace.lowered_gemms(get_vision_config(model))}
        assert any(g.M == printed.M and g.K == printed.K for g in traced), \
            f"{entry}: no traced layer with filter geometry " \
            f"M={printed.M}, K={printed.K}"
        assert printed not in traced, \
            f"{entry} now matches a traced layer exactly -- " \
            "promote Table 3 back to ground truth"


class TestTracer:
    def test_trace_runs_no_compute(self):
        """Tracing full ResNet50@224 must be metadata-only (fast), and the
        records carry resolved geometry."""
        recs = trace.trace_model(get_vision_config("resnet50"))
        assert len(recs) == 53
        first = recs[0]
        assert (first.H, first.W, first.C_in, first.C_out) == (224, 224, 3, 64)
        assert first.stride == (2, 2) and first.padding == ((3, 3), (3, 3))
        assert first.H_out == first.W_out == 112
        assert all(r.macs > 0 for r in recs)

    def test_reduced_config_traces_scaled_shapes(self):
        recs = trace.trace_model(get_vision_config("resnet50", reduced=True))
        assert recs[0].H == 32 and recs[0].C_out == 8

    def test_to_conv_shape_rejects_asymmetric(self):
        tc = trace.TracedConv(name="bad", H=8, W=8, C_in=4, C_out=4, kh=3,
                              kw=3, stride=(2, 1),
                              padding=((1, 1), (1, 1)))
        with pytest.raises(ValueError, match="no ConvShape equivalent"):
            trace.to_conv_shape(tc)

    def test_to_conv_shape_rejects_grouped_non_depthwise(self):
        """A dense ConvShape would overstate K/MACs by groups-x."""
        tc = trace.TracedConv(name="bad", H=8, W=8, C_in=8, C_out=8, kh=3,
                              kw=3, stride=(1, 1), padding=((1, 1), (1, 1)),
                              groups=2)
        with pytest.raises(ValueError, match="grouped conv"):
            trace.to_conv_shape(tc)

    def test_depthwise_excluded_from_fig11_accounting(self):
        cfg = get_vision_config("mobilenet-v1")
        dense_only = trace.conv_shapes(cfg)
        with_dw = trace.conv_shapes(cfg, include_depthwise=True)
        assert len(with_dw) == len(dense_only) + 13


class TestPaperReport:
    @pytest.mark.parametrize("name", ["resnet50", "yolov3"])
    def test_axon_wins_on_runtime_and_energy(self, name):
        rep = trace.paper_report(get_vision_config(name))
        assert rep["throughput_speedup"] >= 1.0
        assert rep["cycle_speedup"] > 1.0
        # the paper's §5.2.1 energy claim direction: less DRAM traffic, and
        # an energy win in the 1.x-2.x band for these conv stacks
        assert 0 < rep["traffic_bytes"]["reduction"] < 1
        assert 1.2 < rep["energy_ratio"] < 3.0
        assert rep["conv_layers"] == len(
            trace.conv_shapes(get_vision_config(name)))

    def test_report_consistent_with_gemm_lowering(self):
        cfg = get_vision_config("yolov3-tiny")
        rep = trace.paper_report(cfg)
        macs = sum(lower_to_gemm(c).M * lower_to_gemm(c).K * lower_to_gemm(c).N
                   for c in trace.conv_shapes(cfg))
        assert rep["macs"] == macs


class TestLetterbox:
    def test_geometry_and_fill(self):
        img = np.full((50, 100, 3), 2.0, np.float32)
        out = np.asarray(preprocess.letterbox(img, (64, 64), fill=0.5))
        assert out.shape == (64, 64, 3)
        # wide image: rows above/below the resized strip are pure fill
        (nh, nw), (pt, pl) = preprocess.letterbox_geometry((50, 100), (64, 64))
        assert (nh, nw) == (32, 64) and pl == 0
        np.testing.assert_allclose(out[:pt], 0.5)
        np.testing.assert_allclose(out[pt + nh:], 0.5)
        np.testing.assert_allclose(out[pt: pt + nh], 2.0, atol=1e-6)

    def test_identity_when_shape_matches(self):
        img = np.random.default_rng(0).normal(size=(64, 64, 3)) \
            .astype(np.float32)
        out = np.asarray(preprocess.letterbox(img, (64, 64)))
        np.testing.assert_allclose(out, img, atol=1e-5)

    def test_degenerate_rejected(self):
        with pytest.raises(ValueError):
            preprocess.letterbox_geometry((0, 10), (64, 64))
        with pytest.raises(ValueError):
            preprocess.letterbox(np.zeros((4, 4)), (64, 64))

    def test_unletterbox_round_trip(self):
        # a box drawn on the canvas maps back to original coordinates
        boxes = preprocess.unletterbox_boxes(
            jnp.asarray([[0.0, 0.25, 1.0, 0.75]]), (50, 100), (64, 64))
        np.testing.assert_allclose(np.asarray(boxes), [[0, 0, 1, 1]],
                                   atol=0.01)

    def test_engine_accepts_variable_sizes(self, zoo):
        cfg, params, _ = zoo["resnet50"]
        eng = VisionEngine(params, cfg, batch_slots=2,
                           policy=axon.ExecutionPolicy(backend="xla"))
        rng = np.random.default_rng(0)
        shapes = [(32, 32, 3), (20, 48, 3), (64, 16, 3)]
        reqs = [ImageRequest(image=rng.normal(size=s).astype(np.float32))
                for s in shapes]
        outs = eng.infer(reqs)
        assert all(o.shape == (cfg.num_classes,) for o in outs)
        # the exact-size request must match direct apply on the raw image
        direct = models.apply(params, jnp.asarray(reqs[0].image)[None], cfg)
        np.testing.assert_allclose(outs[0], np.asarray(direct[0]),
                                   rtol=1e-4, atol=1e-5)

    def test_strict_mode_rejects_mismatched_shapes(self, zoo):
        cfg, params, _ = zoo["resnet50"]
        eng = VisionEngine(params, cfg, batch_slots=2, letterbox=False,
                           policy=axon.ExecutionPolicy(backend="xla"))
        bad = [ImageRequest(image=np.zeros((16, 16, 3), np.float32))]
        with pytest.raises(ValueError, match="not servable"):
            eng.infer(bad)


class TestPostprocess:
    def _synthetic_map(self, h, w, A, C, boxes):
        """Detection map whose decode yields the given (cell, anchor, cls,
        logit) spikes on a zero (sigmoid=0.5) background."""
        det = np.full((1, h, w, A * (5 + C)), -20.0, np.float32)
        det = det.reshape(1, h, w, A, 5 + C)
        for (cy, cx, a, cls, score_logit) in boxes:
            det[0, cy, cx, a, 0:2] = 0.0          # center of the cell
            det[0, cy, cx, a, 2:4] = 0.0          # wh = anchor size
            det[0, cy, cx, a, 4] = score_logit
            det[0, cy, cx, a, 5 + cls] = score_logit
        return jnp.asarray(det.reshape(1, h, w, A * (5 + C)))

    def test_decode_centers_and_sizes(self):
        anchors = ((41.6, 83.2),)                 # /416 -> (0.1, 0.2)
        det = self._synthetic_map(4, 4, 1, 3, [(1, 2, 0, 0, 8.0)])
        boxes, scores = postprocess.decode_scale(det, anchors, num_classes=3)
        idx = int(scores[0].max(-1).argmax())
        cx, cy = (2 + 0.5) / 4, (1 + 0.5) / 4
        np.testing.assert_allclose(
            np.asarray(boxes[0, idx]),
            [cx - 0.05, cy - 0.1, cx + 0.05, cy + 0.1], atol=1e-5)
        assert float(scores[0, idx].max()) > 0.99

    def test_nms_class_aware(self):
        boxes = jnp.asarray([[0.1, 0.1, 0.5, 0.5], [0.12, 0.12, 0.52, 0.52],
                             [0.1, 0.1, 0.5, 0.5], [0.7, 0.7, 0.9, 0.9]])
        scores = jnp.asarray([0.9, 0.8, 0.85, 0.3])
        classes = jnp.asarray([0, 0, 1, 0], jnp.int32)
        b, s, c, v = postprocess.nms(boxes, scores, classes, max_det=4,
                                     score_thresh=0.2)
        # same-class overlap suppressed; cross-class overlap survives
        np.testing.assert_allclose(np.asarray(s), [0.9, 0.85, 0.3, 0.0],
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(c), [0, 1, 0, 0])
        np.testing.assert_array_equal(np.asarray(v),
                                      [True, True, True, False])
        np.testing.assert_allclose(np.asarray(b[3]), 0.0)

    def test_nms_oversized_boxes_stay_class_separated(self):
        """Boxes overshooting the canvas must not leak across the per-class
        offset bands (suppression geometry is canvas-clipped first)."""
        boxes = jnp.asarray([[-5.0, -5.0, 6.0, 6.0],
                             [-5.0, -5.0, 6.0, 6.0]])
        scores = jnp.asarray([0.9, 0.8])
        classes = jnp.asarray([0, 1], jnp.int32)
        _, _, c, v = postprocess.nms(boxes, scores, classes, max_det=2,
                                     score_thresh=0.2)
        np.testing.assert_array_equal(np.asarray(v), [True, True])
        np.testing.assert_array_equal(np.asarray(c), [0, 1])

    def test_nms_score_threshold(self):
        boxes = jnp.asarray([[0.1, 0.1, 0.2, 0.2]])
        _, s, _, v = postprocess.nms(boxes, jnp.asarray([0.1]),
                                     jnp.zeros((1,), jnp.int32),
                                     score_thresh=0.5, max_det=2)
        assert not bool(v.any()) and float(s.sum()) == 0.0

    def test_yolo_tiny_smoke(self, zoo):
        """End-to-end: tiny YOLO outputs -> fixed-shape detections."""
        cfg, params, x = zoo["yolov3-tiny"]
        out = models.apply(params, x, cfg)
        res = postprocess.postprocess_yolo(
            out, arch=cfg.arch, num_classes=cfg.num_classes,
            score_thresh=0.05, max_det=16)
        N = x.shape[0]
        assert res["boxes"].shape == (N, 16, 4)
        assert res["scores"].shape == (N, 16)
        assert res["classes"].shape == (N, 16)
        assert res["valid"].shape == (N, 16)
        assert bool(jnp.all(jnp.isfinite(res["boxes"])))
        # jits as one program
        jitted = jax.jit(lambda o: postprocess.postprocess_yolo(
            o, arch=cfg.arch, num_classes=cfg.num_classes,
            score_thresh=0.05, max_det=16))
        res2 = jitted(out)
        np.testing.assert_allclose(np.asarray(res2["scores"]),
                                   np.asarray(res["scores"]), atol=1e-6)

    def test_anchor_scale_mismatch_rejected(self, zoo):
        cfg, params, x = zoo["yolov3-tiny"]
        out = models.apply(params, x, cfg)
        with pytest.raises(ValueError, match="anchor scales"):
            postprocess.postprocess_yolo(out, arch="yolov3",
                                         num_classes=cfg.num_classes)
