"""Property-based differential tests: ``axon.einsum``/``matmul``/``conv2d``
vs jnp / lax.

Every kernel-dispatched backend must agree with ``jnp.einsum`` on any
matmul-shaped spec the planner accepts -- and fall back to XLA (still
agreeing bit-for-bit there) on everything it rejects.  The shared checker is
driven two ways: a curated example sweep (specs the model zoo uses plus the
degenerate M=1 / N=1 / K=1 / empty-dim shapes) that always runs, and
hypothesis fuzzing over random dimension assignments when hypothesis is
installed (CI); without it the ``@given`` tests skip via
``_hypothesis_compat``.

The conv section fuzzes the generalized ``axon.conv2d`` /
``depthwise_conv2d`` front door (tuple strides, asymmetric/SAME padding,
groups, 1x1, kernel == input) against ``jax.lax.conv_general_dilated``, and
pins the dispatch edge cases (kernel larger than the padded input,
zero-area outputs) to the XLA fallback instead of a Pallas shape failure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import axon


def _tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=2e-4, atol=2e-5))


def _operand(shape, dtype, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


def check_spec(spec, lhs_shape, rhs_shape, dtype=jnp.float32,
               backend="interpret"):
    """axon.einsum(spec) under ``backend`` must match jnp.einsum in shape,
    dtype, and values."""
    a = _operand(lhs_shape, dtype, 0)
    b = _operand(rhs_shape, dtype, 1)
    want = jnp.einsum(spec, a, b)
    with axon.policy(backend=backend):
        got = axon.einsum(spec, a, b)
    assert got.shape == want.shape, (spec, got.shape, want.shape)
    assert got.dtype == want.dtype, (spec, got.dtype, want.dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               err_msg=spec, **_tol(dtype))


# specs the model zoo exercises + shapes that stress the planner's edges
EXAMPLES = [
    # plain GeMMs, ragged sizes
    ("mk,kn->mn", (32, 24), (24, 40)),
    ("mk,kn->mn", (100, 17), (17, 3)),
    ("mk,kn->nm", (12, 9), (9, 7)),              # transposed output
    # lhs-only batch folds into M (model projections)
    ("bsd,df->bsf", (2, 10, 16), (16, 24)),
    ("bld,de->ble", (3, 5, 8), (8, 12)),
    # shared batch -> vmapped kernel (MoE expert GeMMs, attention scores)
    ("bmk,bkn->bmn", (3, 8, 12), (3, 12, 10)),
    ("becd,edf->becf", (2, 3, 4, 8), (3, 8, 6)),
    ("bthc,bsc->bths", (2, 3, 4, 8), (2, 5, 8)),
    # gemv-shaped (decode-step projections)
    ("k,kn->n", (32,), (32, 16)),
    ("mk,kn->mn", (1, 64), (64, 32)),            # M=1
    ("bd,de->be", (4, 16), (16, 24)),            # small-M batch
    # degenerate dims: planner must reject or handle, result must match
    ("mk,kn->mn", (5, 1), (1, 7)),               # K=1
    ("mk,kn->mn", (5, 8), (8, 1)),               # N=1
    ("mk,kn->mn", (0, 8), (8, 4)),               # empty M
    ("mk,kn->mn", (5, 0), (0, 4)),               # empty K (zeros result)
    ("bsd,df->bsf", (2, 0, 8), (8, 4)),          # empty fold dim
    # non-matmul shapes: XLA fallback must stay bit-identical
    ("ij,ij->ij", (4, 6), (4, 6)),               # elementwise
    ("mk,kn->", (3, 4), (4, 5)),                 # full reduction
    ("ik,jk->ij", (5, 8), (6, 8)),               # shared contraction label
]


class TestEinsumExamples:
    @pytest.mark.parametrize("spec,lhs,rhs", EXAMPLES,
                             ids=[e[0] for e in EXAMPLES])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_jnp(self, spec, lhs, rhs, dtype):
        check_spec(spec, lhs, rhs, dtype)

    def test_xla_backend_bit_identical(self):
        a = _operand((33, 17), jnp.float32, 0)
        b = _operand((17, 21), jnp.float32, 1)
        with axon.policy(backend="xla"):
            got = axon.einsum("mk,kn->mn", a, b)
        assert (np.asarray(got) == np.asarray(
            jnp.einsum("mk,kn->mn", a, b))).all()

    def test_preferred_element_type(self):
        a = _operand((16, 8), jnp.bfloat16, 0)
        b = _operand((8, 12), jnp.bfloat16, 1)
        with axon.policy(backend="interpret"):
            got = axon.einsum("mk,kn->mn", a, b,
                              preferred_element_type=jnp.float32)
        want = jnp.einsum("mk,kn->mn", a, b,
                          preferred_element_type=jnp.float32)
        assert got.dtype == want.dtype == jnp.float32
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


class TestMatmulExamples:
    @pytest.mark.parametrize("lhs,rhs", [
        ((16, 12), (12, 20)),
        ((1, 12), (12, 20)),                     # gemv row
        ((2, 5, 12), (12, 20)),                  # leading dims fold
        ((2, 3, 4, 12), (12, 8)),
        ((3, 8, 12), (3, 12, 6)),                # shared batch
        ((12,), (12, 8)),                        # vector lhs
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_jnp_matmul(self, lhs, rhs, dtype):
        a = _operand(lhs, dtype, 0)
        b = _operand(rhs, dtype, 1)
        with axon.policy(backend="interpret"):
            got = axon.matmul(a, b)
        want = jnp.matmul(a, b)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))


# ----------------------------------------------------------------- hypothesis

_TEMPLATES = [
    "mk,kn->mn", "mk,kn->nm", "bsd,df->bsf", "bmk,bkn->bmn",
    "bd,de->be", "abk,kn->abn", "bthc,bsc->bths",
]


class TestEinsumProperties:
    @given(template=st.sampled_from(_TEMPLATES),
           dims=st.lists(st.integers(1, 12), min_size=8, max_size=8),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @settings(max_examples=30, deadline=None)
    def test_random_dims(self, template, dims, dtype):
        """Any dimension assignment to a planner-shaped spec matches jnp."""
        inputs, _ = template.split("->")
        la, lb = inputs.split(",")
        labels = sorted(set(la + lb))
        size = {c: dims[i % len(dims)] for i, c in enumerate(labels)}
        lhs = tuple(size[c] for c in la)
        rhs = tuple(size[c] for c in lb)
        check_spec(template, lhs, rhs, jnp.dtype(dtype))

    @given(m=st.integers(0, 9), k=st.integers(0, 9), n=st.integers(0, 9),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @settings(max_examples=25, deadline=None)
    def test_degenerate_gemm_shapes(self, m, k, n, dtype):
        """M/K/N of 0 and 1 (GEMV, rank-1, empty) all match jnp."""
        check_spec("mk,kn->mn", (m, k), (k, n), jnp.dtype(dtype))

    @given(b=st.integers(1, 4), s=st.integers(1, 6), d=st.integers(1, 10),
           f=st.integers(1, 10))
    @settings(max_examples=20, deadline=None)
    def test_projection_property(self, b, s, d, f):
        """The model-zoo projection spec at arbitrary sizes."""
        check_spec("bsd,df->bsf", (b, s, d), (d, f))

    @given(lead=st.lists(st.integers(1, 3), min_size=0, max_size=3),
           k=st.integers(1, 8), n=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_matmul_lead_dims(self, lead, k, n):
        """matmul folds arbitrary leading lhs dims like jnp.matmul."""
        a = _operand(tuple(lead) + (4, k), jnp.float32, 0)
        b = _operand((k, n), jnp.float32, 1)
        with axon.policy(backend="interpret"):
            got = axon.matmul(a, b)
        np.testing.assert_allclose(got, jnp.matmul(a, b),
                                   rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------- convolution

from repro.kernels import ref  # noqa: E402


def check_conv(x_shape, w_shape, *, stride=1, padding=0, groups=1,
               dtype=jnp.float32, depthwise=False):
    """axon conv under ``interpret`` must match lax.conv_general_dilated in
    shape and values (the ``xla`` backend IS that call, checked too)."""
    x = _operand(x_shape, dtype, 0)
    w = _operand(w_shape, dtype, 1) * 0.3
    op = axon.depthwise_conv2d if depthwise else axon.conv2d
    kw = {} if depthwise else {"groups": groups}
    # resolve SAME/asymmetric once, against lax directly (not via our oracle)
    strides = ref.normalize_stride(stride)
    pads = padding if isinstance(padding, str) \
        else list(ref.normalize_padding(padding))
    w_lax = w[:, :, None, :] if depthwise else w
    fgc = x_shape[-1] if depthwise else groups
    want = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w_lax.astype(jnp.float32),
        window_strides=strides, padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=fgc).astype(dtype)
    for backend in ("interpret", "xla"):
        with axon.policy(backend=backend):
            got = op(x, w, stride=stride, padding=padding, **kw)
        assert got.shape == want.shape, (backend, got.shape, want.shape)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   err_msg=str((backend, x_shape, w_shape,
                                                stride, padding, groups)),
                                   **_tol(dtype))


class TestConvExamples:
    @pytest.mark.parametrize("x,w,kw", [
        ((1, 10, 8, 4), (3, 3, 4, 6), dict(stride=(2, 3), padding=1)),
        ((2, 9, 9, 3), (3, 3, 3, 4), dict(padding=((0, 2), (1, 0)))),
        ((1, 11, 7, 4), (3, 3, 4, 4), dict(stride=2, padding="SAME")),
        ((1, 8, 8, 4), (1, 1, 4, 8), dict(padding="VALID")),       # 1x1
        ((1, 6, 6, 4), (6, 6, 4, 4), dict()),                      # k == h
        ((2, 8, 8, 6), (3, 3, 3, 8), dict(padding=1, groups=2)),
        ((1, 7, 7, 8), (1, 1, 2, 12), dict(groups=4)),             # grouped 1x1
        ((1, 5, 9, 4), (3, 5, 4, 6), dict(padding=(1, 2))),        # kh != kw
    ], ids=["tuple-stride", "asym-pad", "same-s2", "1x1", "k==h",
            "groups2", "groups4-1x1", "rect-kernel"])
    def test_matches_lax(self, x, w, kw):
        check_conv(x, w, **kw)

    @pytest.mark.parametrize("kw", [
        dict(stride=(2, 1), padding="SAME"),
        dict(stride=2, padding=1),
        dict(padding=((1, 0), (0, 1))),
    ], ids=["same", "stride2", "asym"])
    def test_depthwise_matches_lax(self, kw):
        check_conv((2, 9, 8, 6), (3, 3, 6), depthwise=True, **kw)

    def test_invalid_groups_raise(self):
        x = _operand((1, 8, 8, 6), jnp.float32, 0)
        w = _operand((3, 3, 2, 8), jnp.float32, 1)
        with pytest.raises(ValueError, match="groups"):
            axon.conv2d(x, w, groups=4)           # 6 != 2 * 4
        with pytest.raises(ValueError, match="groups"):
            axon.conv2d(x, w, padding=1, groups=0)

    def test_bad_padding_string_raises(self):
        x = _operand((1, 8, 8, 4), jnp.float32, 0)
        w = _operand((3, 3, 4, 4), jnp.float32, 1)
        with pytest.raises(ValueError, match="padding"):
            axon.conv2d(x, w, padding="FULL")


class TestConvDispatchEdgeCases:
    """Satellite regression: shapes the Pallas kernel cannot lower must take
    the XLA reference path, not die in a pallas_call shape failure."""

    @pytest.mark.parametrize("backend", ["pallas", "interpret"])
    def test_kernel_larger_than_padded_input(self, backend):
        x = _operand((2, 3, 3, 4), jnp.float32, 0)
        w = _operand((7, 7, 4, 5), jnp.float32, 1)
        with axon.policy(backend=backend):
            out = axon.conv2d(x, w)               # zero-area, XLA fallback
        assert out.shape == (2, 0, 0, 5)

    @pytest.mark.parametrize("backend", ["pallas", "interpret"])
    def test_zero_area_output_exact(self, backend):
        # H + pads == kh - 1: H_out == 0 exactly
        x = _operand((1, 4, 6, 3), jnp.float32, 0)
        w = _operand((5, 3, 3, 2), jnp.float32, 1)
        with axon.policy(backend=backend):
            out = axon.conv2d(x, w, padding=0)
        assert out.shape == (1, 0, 4, 2)

    def test_depthwise_zero_area(self):
        x = _operand((1, 2, 8, 4), jnp.float32, 0)
        w = _operand((5, 3, 4), jnp.float32, 1)
        with axon.policy(backend="interpret"):
            out = axon.depthwise_conv2d(x, w, padding=((0, 0), (1, 1)))
        assert out.shape == (1, 0, 8, 4)

    def test_empty_batch_and_channels(self):
        with axon.policy(backend="interpret"):
            out = axon.conv2d(_operand((0, 8, 8, 4), jnp.float32, 0),
                              _operand((3, 3, 4, 8), jnp.float32, 1))
            assert out.shape == (0, 6, 6, 8)
            out = axon.conv2d(_operand((1, 8, 8, 0), jnp.float32, 0),
                              _operand((3, 3, 0, 8), jnp.float32, 1))
            assert out.shape == (1, 6, 6, 8)

    def test_kernel_raises_clear_error_when_called_directly(self):
        """The raw kernel refuses zero-area outputs with a pointer to the
        front door (instead of a cryptic Pallas grid failure)."""
        from repro.kernels.im2col_conv import im2col_conv
        x = _operand((1, 3, 3, 4), jnp.float32, 0)
        w = _operand((5, 5, 4, 2), jnp.float32, 1)
        with pytest.raises(ValueError, match="zero-area"):
            im2col_conv(x, w, interpret=True)

    def test_stride_and_padding_validation(self):
        x = _operand((1, 8, 8, 4), jnp.float32, 0)
        w = _operand((3, 3, 4, 4), jnp.float32, 1)
        with pytest.raises(ValueError, match="stride"):
            axon.conv2d(x, w, stride=0)
        with pytest.raises(ValueError, match="padding"):
            axon.conv2d(x, w, padding=-1)


class TestConvProperties:
    @given(h=st.integers(4, 10), w=st.integers(4, 10),
           cin=st.integers(1, 6), cout=st.integers(1, 6),
           kh=st.sampled_from([1, 2, 3, 5]), kw=st.sampled_from([1, 3]),
           sh=st.integers(1, 3), sw=st.integers(1, 3),
           pad=st.sampled_from([0, 1, 2, "SAME", "VALID", ((0, 1), (2, 0))]),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @settings(max_examples=25, deadline=None)
    def test_conv2d_random(self, h, w, cin, cout, kh, kw, sh, sw, pad, dtype):
        """Any (stride, padding, kernel) geometry -- including zero-area
        outputs -- matches lax under both kernel and XLA backends."""
        check_conv((1, h, w, cin), (kh, kw, cin, cout), stride=(sh, sw),
                   padding=pad, dtype=jnp.dtype(dtype))

    @given(h=st.integers(4, 9), cig=st.integers(1, 4),
           groups=st.sampled_from([1, 2, 4]), cog=st.integers(1, 4),
           k=st.sampled_from([1, 3]), s=st.integers(1, 2))
    @settings(max_examples=15, deadline=None)
    def test_grouped_conv_random(self, h, cig, groups, cog, k, s):
        """Grouped conv (vmapped per-group GeMMs) matches lax's
        feature_group_count for any group/channel split."""
        check_conv((2, h, h, cig * groups), (k, k, cig, cog * groups),
                   stride=s, padding=k // 2, groups=groups)

    @given(h=st.integers(4, 9), c=st.integers(1, 8),
           k=st.sampled_from([1, 3, 5]), s=st.integers(1, 2),
           pad=st.sampled_from([0, 1, "SAME"]))
    @settings(max_examples=15, deadline=None)
    def test_depthwise_random(self, h, c, k, s, pad):
        check_conv((1, h, h, c), (k, k, c), stride=s, padding=pad,
                   depthwise=True)

    @given(h=st.integers(3, 6), k=st.integers(1, 3))
    @settings(max_examples=10, deadline=None)
    def test_kernel_equals_input(self, h, k):
        """k == h (one output pixel) and k > h-ish geometries."""
        check_conv((1, h, h, 3), (h, h, 3, 4))
        check_conv((1, h, h, 3), (h, k, 3, 4))


class TestConvGradsGeneralized:
    """jax.grad through the generalized conv paths (tuple stride, SAME,
    groups, depthwise) must match the XLA backend's grads."""

    def _grads(self, backend, op, x, w, **kw):
        def loss(xx, ww):
            with axon.policy(backend=backend):
                return (op(xx, ww, **kw) ** 2).sum()
        return jax.grad(loss, argnums=(0, 1))(x, w)

    @pytest.mark.parametrize("kw", [
        dict(stride=(2, 3), padding="SAME", groups=2),
        dict(stride=2, padding=((0, 2), (1, 0))),
        dict(stride=(1, 2), padding=1),
    ], ids=["same-groups", "asym", "tuple-stride"])
    def test_conv2d_grad(self, kw):
        x = _operand((2, 9, 8, 6), jnp.float32, 0)
        w = _operand((3, 3, 6 // kw.get("groups", 1), 8), jnp.float32, 1) * 0.3
        got = self._grads("interpret", axon.conv2d, x, w, **kw)
        want = self._grads("xla", axon.conv2d, x, w, **kw)
        for g, r in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=2e-4, atol=2e-4)

    def test_depthwise_grad(self):
        x = _operand((2, 8, 9, 5), jnp.float32, 0)
        w = _operand((3, 3, 5), jnp.float32, 1) * 0.3
        kw = dict(stride=(2, 1), padding="SAME")
        got = self._grads("interpret", axon.depthwise_conv2d, x, w, **kw)
        want = self._grads("xla", axon.depthwise_conv2d, x, w, **kw)
        for g, r in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=2e-4, atol=2e-4)
