"""Property-based differential tests: ``axon.einsum``/``matmul`` vs jnp.

Every kernel-dispatched backend must agree with ``jnp.einsum`` on any
matmul-shaped spec the planner accepts -- and fall back to XLA (still
agreeing bit-for-bit there) on everything it rejects.  The shared checker is
driven two ways: a curated example sweep (specs the model zoo uses plus the
degenerate M=1 / N=1 / K=1 / empty-dim shapes) that always runs, and
hypothesis fuzzing over random dimension assignments when hypothesis is
installed (CI); without it the ``@given`` tests skip via
``_hypothesis_compat``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import axon


def _tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=2e-4, atol=2e-5))


def _operand(shape, dtype, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


def check_spec(spec, lhs_shape, rhs_shape, dtype=jnp.float32,
               backend="interpret"):
    """axon.einsum(spec) under ``backend`` must match jnp.einsum in shape,
    dtype, and values."""
    a = _operand(lhs_shape, dtype, 0)
    b = _operand(rhs_shape, dtype, 1)
    want = jnp.einsum(spec, a, b)
    with axon.policy(backend=backend):
        got = axon.einsum(spec, a, b)
    assert got.shape == want.shape, (spec, got.shape, want.shape)
    assert got.dtype == want.dtype, (spec, got.dtype, want.dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               err_msg=spec, **_tol(dtype))


# specs the model zoo exercises + shapes that stress the planner's edges
EXAMPLES = [
    # plain GeMMs, ragged sizes
    ("mk,kn->mn", (32, 24), (24, 40)),
    ("mk,kn->mn", (100, 17), (17, 3)),
    ("mk,kn->nm", (12, 9), (9, 7)),              # transposed output
    # lhs-only batch folds into M (model projections)
    ("bsd,df->bsf", (2, 10, 16), (16, 24)),
    ("bld,de->ble", (3, 5, 8), (8, 12)),
    # shared batch -> vmapped kernel (MoE expert GeMMs, attention scores)
    ("bmk,bkn->bmn", (3, 8, 12), (3, 12, 10)),
    ("becd,edf->becf", (2, 3, 4, 8), (3, 8, 6)),
    ("bthc,bsc->bths", (2, 3, 4, 8), (2, 5, 8)),
    # gemv-shaped (decode-step projections)
    ("k,kn->n", (32,), (32, 16)),
    ("mk,kn->mn", (1, 64), (64, 32)),            # M=1
    ("bd,de->be", (4, 16), (16, 24)),            # small-M batch
    # degenerate dims: planner must reject or handle, result must match
    ("mk,kn->mn", (5, 1), (1, 7)),               # K=1
    ("mk,kn->mn", (5, 8), (8, 1)),               # N=1
    ("mk,kn->mn", (0, 8), (8, 4)),               # empty M
    ("mk,kn->mn", (5, 0), (0, 4)),               # empty K (zeros result)
    ("bsd,df->bsf", (2, 0, 8), (8, 4)),          # empty fold dim
    # non-matmul shapes: XLA fallback must stay bit-identical
    ("ij,ij->ij", (4, 6), (4, 6)),               # elementwise
    ("mk,kn->", (3, 4), (4, 5)),                 # full reduction
    ("ik,jk->ij", (5, 8), (6, 8)),               # shared contraction label
]


class TestEinsumExamples:
    @pytest.mark.parametrize("spec,lhs,rhs", EXAMPLES,
                             ids=[e[0] for e in EXAMPLES])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_jnp(self, spec, lhs, rhs, dtype):
        check_spec(spec, lhs, rhs, dtype)

    def test_xla_backend_bit_identical(self):
        a = _operand((33, 17), jnp.float32, 0)
        b = _operand((17, 21), jnp.float32, 1)
        with axon.policy(backend="xla"):
            got = axon.einsum("mk,kn->mn", a, b)
        assert (np.asarray(got) == np.asarray(
            jnp.einsum("mk,kn->mn", a, b))).all()

    def test_preferred_element_type(self):
        a = _operand((16, 8), jnp.bfloat16, 0)
        b = _operand((8, 12), jnp.bfloat16, 1)
        with axon.policy(backend="interpret"):
            got = axon.einsum("mk,kn->mn", a, b,
                              preferred_element_type=jnp.float32)
        want = jnp.einsum("mk,kn->mn", a, b,
                          preferred_element_type=jnp.float32)
        assert got.dtype == want.dtype == jnp.float32
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


class TestMatmulExamples:
    @pytest.mark.parametrize("lhs,rhs", [
        ((16, 12), (12, 20)),
        ((1, 12), (12, 20)),                     # gemv row
        ((2, 5, 12), (12, 20)),                  # leading dims fold
        ((2, 3, 4, 12), (12, 8)),
        ((3, 8, 12), (3, 12, 6)),                # shared batch
        ((12,), (12, 8)),                        # vector lhs
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_jnp_matmul(self, lhs, rhs, dtype):
        a = _operand(lhs, dtype, 0)
        b = _operand(rhs, dtype, 1)
        with axon.policy(backend="interpret"):
            got = axon.matmul(a, b)
        want = jnp.matmul(a, b)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))


# ----------------------------------------------------------------- hypothesis

_TEMPLATES = [
    "mk,kn->mn", "mk,kn->nm", "bsd,df->bsf", "bmk,bkn->bmn",
    "bd,de->be", "abk,kn->abn", "bthc,bsc->bths",
]


class TestEinsumProperties:
    @given(template=st.sampled_from(_TEMPLATES),
           dims=st.lists(st.integers(1, 12), min_size=8, max_size=8),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @settings(max_examples=30, deadline=None)
    def test_random_dims(self, template, dims, dtype):
        """Any dimension assignment to a planner-shaped spec matches jnp."""
        inputs, _ = template.split("->")
        la, lb = inputs.split(",")
        labels = sorted(set(la + lb))
        size = {c: dims[i % len(dims)] for i, c in enumerate(labels)}
        lhs = tuple(size[c] for c in la)
        rhs = tuple(size[c] for c in lb)
        check_spec(template, lhs, rhs, jnp.dtype(dtype))

    @given(m=st.integers(0, 9), k=st.integers(0, 9), n=st.integers(0, 9),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @settings(max_examples=25, deadline=None)
    def test_degenerate_gemm_shapes(self, m, k, n, dtype):
        """M/K/N of 0 and 1 (GEMV, rank-1, empty) all match jnp."""
        check_spec("mk,kn->mn", (m, k), (k, n), jnp.dtype(dtype))

    @given(b=st.integers(1, 4), s=st.integers(1, 6), d=st.integers(1, 10),
           f=st.integers(1, 10))
    @settings(max_examples=20, deadline=None)
    def test_projection_property(self, b, s, d, f):
        """The model-zoo projection spec at arbitrary sizes."""
        check_spec("bsd,df->bsf", (b, s, d), (d, f))

    @given(lead=st.lists(st.integers(1, 3), min_size=0, max_size=3),
           k=st.integers(1, 8), n=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_matmul_lead_dims(self, lead, k, n):
        """matmul folds arbitrary leading lhs dims like jnp.matmul."""
        a = _operand(tuple(lead) + (4, k), jnp.float32, 0)
        b = _operand((k, n), jnp.float32, 1)
        with axon.policy(backend="interpret"):
            got = axon.matmul(a, b)
        np.testing.assert_allclose(got, jnp.matmul(a, b),
                                   rtol=2e-4, atol=2e-5)
