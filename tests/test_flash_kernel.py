"""Pallas flash-attention kernel vs the model-level scan implementation and
a naive softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.models.layers import flash_attention as flash_scan

KEY = jax.random.PRNGKey(0)


def naive(q, k, v, causal):
    B, H, Sq, dh = q.shape
    KvH, Skv = k.shape[1], k.shape[2]
    rep = H // KvH
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * dh**-0.5
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)


class TestFlashKernel:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,h,kvh,s,dh,bq,bkv", [
        (1, 4, 4, 64, 16, 16, 16),     # MHA
        (2, 8, 2, 48, 8, 16, 16),      # GQA rep=4, ragged seq
        (1, 4, 1, 33, 16, 8, 16),      # MQA, ragged both blocks
    ])
    def test_vs_naive(self, causal, dtype, b, h, kvh, s, dh, bq, bkv):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, kvh, s, dh), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, kvh, s, dh), jnp.float32).astype(dtype)
        out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                                  block_kv=bkv, interpret=True)
        want = naive(q, k, v, causal)
        tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
            else dict(rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **tol)

    def test_matches_model_level_scan(self):
        # kernel (B,H,S,dh) layout vs model-level (B,S,H,dh) layout
        ks = jax.random.split(KEY, 3)
        b, h, kvh, s, dh = 2, 4, 2, 32, 16
        q = jax.random.normal(ks[0], (b, s, h, dh))
        k = jax.random.normal(ks[1], (b, s, kvh, dh))
        v = jax.random.normal(ks[2], (b, s, kvh, dh))
        ref = flash_scan(q, k, v, causal=True, block_q=8, block_kv=8)
        out = flash_attention_fwd(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3),
                                  causal=True, block_q=8, block_kv=8,
                                  interpret=True)
        np.testing.assert_allclose(out.transpose(0, 2, 1, 3), ref,
                                   rtol=2e-5, atol=2e-5)
