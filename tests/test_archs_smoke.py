"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness asserts; plus a decode step for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    ks = jax.random.split(KEY, 3)
    batch = {"labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "audio":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
        batch["tokens"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    elif cfg.frontend == "vlm":
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
        batch["pixel_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    hidden, aux = T.forward(params, batch, cfg)
    exp_s = S + (cfg.n_patches if cfg.frontend == "vlm" else 0)
    assert hidden.shape == (B, exp_s, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), arch
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    # untrained CE should be near log(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab) * 2 + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    caches = T.init_caches(cfg, batch=B, max_len=32, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    batch = {"tokens": tok}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    logits, caches = T.decode_step(params, caches, batch, cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    logits2, caches = T.decode_step(params, caches, batch, cfg)
    assert int(caches["pos"]) == 2
    assert bool(jnp.isfinite(logits2).all()), arch


def test_full_configs_param_counts():
    """Full configs must be in the ballpark of their published sizes."""
    expect = {
        "deepseek-v3-671b": (600e9, 760e9),
        "mixtral-8x7b": (42e9, 52e9),
        "yi-9b": (8e9, 10e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen2.5-14b": (13e9, 16.5e9),
        "llama3-405b": (380e9, 430e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "zamba2-7b": (6e9, 9e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "internvl2-1b": (0.4e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
