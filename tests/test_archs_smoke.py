"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness asserts; plus a decode step for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    ks = jax.random.split(KEY, 3)
    batch = {"labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "audio":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
        batch["tokens"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    elif cfg.frontend == "vlm":
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
        batch["pixel_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    hidden, aux = T.forward(params, batch, cfg)
    exp_s = S + (cfg.n_patches if cfg.frontend == "vlm" else 0)
    assert hidden.shape == (B, exp_s, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), arch
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    # untrained CE should be near log(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab) * 2 + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(KEY, cfg)
    caches = T.init_caches(cfg, batch=B, max_len=32, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    batch = {"tokens": tok}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
    logits, caches = T.decode_step(params, caches, batch, cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    logits2, caches = T.decode_step(params, caches, batch, cfg)
    np.testing.assert_array_equal(np.asarray(caches["pos"]), [2] * B)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_step_matches_decode_loop(arch):
    """Chunked teacher-forced prefill (ragged valid masks, per-slot
    positions) must match per-request one-token decode for every arch."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no MoE drops
    params = T.init_params(KEY, cfg)
    Sq, lens, chunk = 6, [6, 3], 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0, cfg.vocab)
    embeds = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, cfg.d_model),
                               jnp.float32) * 0.02

    def batch_of(sl_b, sl_t):
        out = {"tokens": toks[sl_b, sl_t]}
        if cfg.frontend == "audio":
            out["embeds"] = embeds[sl_b, sl_t]
        return out

    caches = T.init_caches(cfg, batch=B, max_len=Sq + 1, dtype=jnp.float32)
    got = [[], []]
    fed = [0, 0]
    while any(lens[b] - fed[b] for b in range(B)):
        sl = slice(0, chunk)
        valid = np.zeros((B, chunk), bool)
        tk = np.zeros((B, chunk), np.int32)
        em = np.zeros((B, chunk, cfg.d_model), np.float32)
        for b in range(B):
            n = min(chunk, lens[b] - fed[b])
            tk[b, :n] = np.asarray(toks[b, fed[b]: fed[b] + n])
            em[b, :n] = np.asarray(embeds[b, fed[b]: fed[b] + n])
            valid[b, :n] = True
        batch = {"tokens": jnp.asarray(tk)}
        if cfg.frontend == "audio":
            batch["embeds"] = jnp.asarray(em)
        logits, caches = T.prefill_step(params, caches, batch,
                                        jnp.asarray(valid), cfg)
        for b in range(B):
            n = int(valid[b].sum())
            got[b] += [np.asarray(logits[b, i]) for i in range(n)]
            fed[b] += n
    np.testing.assert_array_equal(np.asarray(caches["pos"]), lens)

    for b in range(B):
        c1 = T.init_caches(cfg, batch=1, max_len=Sq + 1, dtype=jnp.float32)
        for t in range(lens[b]):
            want, c1 = T.decode_step(
                params, c1, batch_of(slice(b, b + 1), slice(t, t + 1)), cfg)
            np.testing.assert_allclose(got[b][t], np.asarray(want[0, 0]),
                                       rtol=2e-3, atol=2e-3,
                                       err_msg=f"{arch} slot {b} tok {t}")


def test_full_configs_param_counts():
    """Full configs must be in the ballpark of their published sizes."""
    expect = {
        "deepseek-v3-671b": (600e9, 760e9),
        "mixtral-8x7b": (42e9, 52e9),
        "yi-9b": (8e9, 10e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen2.5-14b": (13e9, 16.5e9),
        "llama3-405b": (380e9, 430e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "zamba2-7b": (6e9, 9e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "internvl2-1b": (0.4e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
