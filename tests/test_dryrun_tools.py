"""Dry-run tooling: loop-corrected HLO cost walker, collective parser,
sharding specs, and the analytic memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.dryrun import collective_bytes
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.memory_model import sharded_bytes
from repro.parallel.sharding import resolve
from repro.parallel.specs import make_param_spec_fn


class TestHloCostWalker:
    def _cost(self, fn, *args):
        txt = jax.jit(fn).lower(*args).compile().as_text()
        return analyze_hlo(txt)

    def test_single_dot(self):
        a = jnp.ones((128, 64))
        b = jnp.ones((64, 32))
        c = self._cost(lambda a, b: a @ b, a, b)
        assert c.dot_flops == 2 * 128 * 64 * 32
        assert c.while_loops == 0

    def test_scan_multiplies_by_trip_count(self):
        a = jnp.ones((64, 64))

        def f(a):
            def body(c, _):
                return c @ a, None
            out, _ = jax.lax.scan(body, a, None, length=7)
            return out

        c = self._cost(f, a)
        assert c.dot_flops == pytest.approx(7 * 2 * 64**3, rel=0.01)

    def test_nested_scans_compose(self):
        a = jnp.ones((32, 32))

        def f(a):
            def inner(c, _):
                return c @ a, None

            def outer(c, _):
                c, _ = jax.lax.scan(inner, c, None, length=4)
                return c, None

            out, _ = jax.lax.scan(outer, a, None, length=3)
            return out

        c = self._cost(f, a)
        assert c.dot_flops == pytest.approx(12 * 2 * 32**3, rel=0.01)
        assert c.while_loops == 2

    def test_batched_dot_contraction(self):
        a = jnp.ones((8, 16, 32))
        b = jnp.ones((8, 32, 24))
        c = self._cost(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
        assert c.dot_flops == 2 * 8 * 16 * 32 * 24


class TestCollectiveParser:
    HLO = """
ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[128,128]{1,0} all-gather(%small), dimensions={0}
  %small = bf16[8,128]{1,0} copy(%p0)
  ROOT %out = f32[64,128]{1,0} copy(%ar)
}
"""

    def test_operand_bytes(self):
        res = collective_bytes(self.HLO)
        assert res["bytes"]["all-reduce"] == 64 * 128 * 4
        # all-gather operand (8,128) bf16
        assert res["bytes"]["all-gather"] == 8 * 128 * 2
        assert res["counts"]["all-reduce"] == 1


class TestParamSpecs:
    def test_spec_coverage_all_archs(self):
        # every leaf gets a spec whose length matches its rank
        for arch in ("yi-9b", "mixtral-8x7b", "deepseek-v3-671b",
                     "falcon-mamba-7b", "zamba2-7b"):
            cfg = get_config(arch, reduced=True)
            from repro.models import transformer as T
            params = jax.eval_shape(
                lambda c=cfg: T.init_params(jax.random.PRNGKey(0), c))
            spec_fn = make_param_spec_fn(cfg)
            flat, _ = jax.tree_util.tree_flatten_with_path(params)
            for path, leaf in flat:
                ent = spec_fn(path, leaf.shape)
                assert len(ent) == len(leaf.shape), (arch, path, leaf.shape)

    def test_big_matrices_2d_sharded(self):
        cfg = get_config("yi-9b")
        spec_fn = make_param_spec_fn(cfg)

        class K:  # fake DictKey
            def __init__(self, key):
                self.key = key

        assert spec_fn((K("attn"), K("wq")), (48, 4096, 4096)) == \
            (None, "fsdp", "model")
        assert spec_fn((K("attn"), K("wo")), (48, 4096, 4096)) == \
            (None, "model", "fsdp")
        assert spec_fn((K("embed"),), (64000, 4096)) == ("model", "fsdp")

    def test_expert_weights_ep_vs_tp(self):
        class K:
            def __init__(self, key):
                self.key = key

        import dataclasses as dc
        # deepseek ships expert_shard='tp' since §Perf iteration 6d; build
        # an explicit EP variant to cover both paths.
        ep = make_param_spec_fn(dc.replace(get_config("deepseek-v3-671b"),
                                           expert_shard="ep"))
        tp = make_param_spec_fn(get_config("mixtral-8x7b"))
        shape = (58, 256, 7168, 2048)
        assert ep((K("ffn"), K("w_gate")), shape) == (None, "model", "fsdp", None)
        assert tp((K("ffn"), K("w_gate")), shape) == (None, None, "fsdp", "model")


def _make_mesh(shape, names):
    """jax.make_mesh across versions: axis_types only exists on newer jax."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, names,
                             axis_types=(axis_type.Auto,) * len(names))
    return jax.make_mesh(shape, names)


class TestResolveGuards:
    def test_divisibility_guard(self):
        mesh = _make_mesh((1, 1), ("data", "model"))
        # dims divisible by 1 -> axes kept
        spec = resolve(mesh, ("data", "model"), (8, 8))
        assert spec == jax.sharding.PartitionSpec("data", "model")
        # unknown axis dropped
        spec = resolve(mesh, ("nonexistent", None), (8, 8))
        assert spec == jax.sharding.PartitionSpec(None, None)


class TestShardedBytes:
    def test_exact_accounting(self):
        mesh = _make_mesh((1,), ("model",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sds = jax.ShapeDtypeStruct((64, 32), jnp.bfloat16,
                                   sharding=NamedSharding(mesh, P("model")))
        assert sharded_bytes([sds]) == 64 * 32 * 2  # 1 device = full
