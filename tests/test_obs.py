"""repro.obs telemetry: metric semantics, tracer guard, ring bounding,
Chrome-trace schema, serve span nesting, and the off-by-default no-op.

The load-bearing properties:

  * recording under ANY JAX trace (``jax.eval_shape``, jit staging) is
    silently dropped -- instrumentation can sit next to jitted call sites
    without double-counting abstract evaluations or leaking tracers;
  * with telemetry disabled the engines are a true no-op: bit-identical
    tokens, zero metric objects created, zero events buffered;
  * every exported trace passes the same schema validator the CLI runs,
    so "Perfetto accepts it" is enforced by code.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.configs import get_config
from repro.models import transformer as T
from repro.obs import (annotate, attribution, metrics, optrace, profiler,
                       streaming, trace_export)
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and state empty.

    ``configure`` is sticky across enable/disable by design, so the
    fixture restores the defaults explicitly on both sides."""
    streaming.stop()
    optrace.disable()
    optrace.reset()
    optrace.configure(sample_every=1, measure_dispatch=False)
    metrics.clear()
    yield
    streaming.stop()
    optrace.disable()
    optrace.reset()
    optrace.configure(sample_every=1, measure_dispatch=False)
    metrics.clear()


def _cfg(arch="yi-9b"):
    cfg = get_config(arch, reduced=True)
    return dataclasses.replace(cfg, capacity_factor=64.0)


def _requests(cfg, specs, seed=1):
    key = jax.random.PRNGKey(seed)
    reqs = []
    for plen, mnew in specs:
        key, sub = jax.random.split(key)
        prompt = [int(t) for t in jax.random.randint(sub, (plen,), 2,
                                                     cfg.vocab)]
        reqs.append(Request(prompt=prompt, max_new_tokens=mnew))
    return reqs


# ---------------------------------------------------------------------------
# metric semantics
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_inc_and_value(self):
        c = metrics.counter("t_c", "help", labels=("op",))
        c.inc(op="a")
        c.inc(2.5, op="a")
        c.inc(op="b")
        assert c.value(op="a") == 3.5
        assert c.value(op="b") == 1.0
        assert c.value(op="never") == 0.0

    def test_counter_rejects_negative(self):
        c = metrics.counter("t_cneg")
        with pytest.raises(ValueError):
            c.inc(-1.0)

    def test_label_schema_enforced(self):
        c = metrics.counter("t_cl", labels=("op",))
        with pytest.raises(ValueError):
            c.inc(kind="x")               # wrong label name
        with pytest.raises(ValueError):
            c.inc()                       # missing label

    def test_gauge_set_and_add(self):
        g = metrics.gauge("t_g")
        g.set(4.0)
        assert g.value() == 4.0
        g.add(-1.5)
        assert g.value() == 2.5
        g.set(0.25)
        assert g.value() == 0.25

    def test_histogram_buckets_sum_count(self):
        h = metrics.histogram("t_h", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        (key, st), = h._values.items()
        assert st["counts"] == [1, 2, 1, 1]    # last bin is the +Inf tail
        assert st["count"] == 5
        assert st["sum"] == pytest.approx(56.05)
        assert h.percentile(50) == 1.0
        assert h.percentile(99) == 10.0        # +Inf tail reports last bound

    def test_registry_name_conflicts_raise(self):
        metrics.counter("t_dup", labels=("a",))
        with pytest.raises(ValueError):
            metrics.gauge("t_dup")             # different type
        with pytest.raises(ValueError):
            metrics.counter("t_dup", labels=("b",))  # different schema
        # identical re-registration returns the same object
        assert metrics.counter("t_dup", labels=("a",)) is \
            metrics.counter("t_dup", labels=("a",))

    def test_snapshot_and_json_roundtrip(self, tmp_path):
        metrics.counter("t_snap", "a counter", labels=("k",)).inc(k="x")
        metrics.histogram("t_snap_h").observe(0.01)
        path = str(tmp_path / "m.json")
        metrics.REGISTRY.write_json(path)
        snap = json.load(open(path))
        assert snap["t_snap"]["type"] == "counter"
        assert snap["t_snap"]["values"] == [
            {"labels": {"k": "x"}, "value": 1.0}]
        h = snap["t_snap_h"]
        assert h["type"] == "histogram"
        assert h["values"][0]["count"] == 1
        assert len(h["values"][0]["counts"]) == len(h["buckets"]) + 1

    def test_prometheus_text_format(self):
        metrics.counter("t_prom", "helpful", labels=("op",)).inc(op='a"b')
        metrics.histogram("t_prom_h", buckets=(1.0,)).observe(0.5)
        text = metrics.prometheus_text()
        assert "# HELP t_prom helpful" in text
        assert "# TYPE t_prom counter" in text
        assert 't_prom{op="a\\"b"} 1.0' in text
        assert 't_prom_h_bucket{le="1"} 1' in text
        assert 't_prom_h_bucket{le="+Inf"} 1' in text
        assert "t_prom_h_count 1" in text


# ---------------------------------------------------------------------------
# tracer guard
# ---------------------------------------------------------------------------


class TestTracerGuard:
    def test_no_recording_under_eval_shape(self):
        c = metrics.counter("t_guard_es")

        def f(x):
            c.inc()
            return x * 2

        jax.eval_shape(f, jnp.ones((4,)))
        assert c.value() == 0.0
        f(jnp.ones((4,)))                     # eager: records
        assert c.value() == 1.0

    def test_no_recording_under_jit_trace(self):
        c = metrics.counter("t_guard_jit")

        def f(x):
            c.inc()
            return x + 1

        jf = jax.jit(f)
        jf(jnp.ones((4,)))                    # traces once: inc dropped
        jf(jnp.ones((4,)))                    # cached: python never runs
        assert c.value() == 0.0

    def test_tracer_valued_record_dropped(self):
        g = metrics.gauge("t_guard_val")
        optrace.enable()

        @jax.jit
        def f(x):
            g.set(x[0])                       # x[0] is a Tracer
            return x

        f(jnp.ones((4,)))
        assert g.value() == 0.0

    def test_no_dispatch_events_under_jit(self):
        import repro.axon as axon
        optrace.enable()
        a = jnp.ones((32, 64), jnp.float32)
        b = jnp.ones((64, 48), jnp.float32)

        @jax.jit
        def f(a, b):
            return axon.einsum("mk,kn->mn", a, b,
                               policy=axon.ExecutionPolicy(
                                   backend="interpret"))

        f(a, b)
        assert optrace.events() == []
        # the same call eagerly DOES record
        with axon.policy(backend="interpret"):
            axon.einsum("mk,kn->mn", a, b)
        assert len(optrace.events()) == 1
        ev = optrace.events()[0]
        assert ev.op == "einsum" and ev.kind == "gemm"
        assert ev.block is not None and ev.order in ("OS", "WS", "IS")


# ---------------------------------------------------------------------------
# ring bounding
# ---------------------------------------------------------------------------


class TestRingBuffer:
    def test_op_ring_is_bounded(self):
        optrace.enable(ring_size=8)
        for i in range(20):
            optrace.record_dispatch("einsum", "gemm", spec=f"s{i}")
        evs = optrace.events()
        assert len(evs) == 8
        assert optrace.dropped_ops() == 12
        assert [e.spec for e in evs] == [f"s{i}" for i in range(12, 20)]
        # the counters saw every record, not just the surviving ring slice
        assert metrics.REGISTRY.get("axon_dispatch_total").value(
            op="einsum", kind="gemm") == 20.0

    def test_enable_resets_and_rejects_bad_size(self):
        optrace.enable(ring_size=4)
        optrace.record_dispatch("einsum", "gemm")
        optrace.enable(ring_size=4)            # reset=True drops the buffer
        assert optrace.events() == []
        with pytest.raises(ValueError):
            optrace.enable(ring_size=0)


# ---------------------------------------------------------------------------
# chrome-trace schema
# ---------------------------------------------------------------------------


class TestTraceExport:
    def test_roundtrip_is_schema_valid(self, tmp_path):
        optrace.enable()
        optrace.record_dispatch("einsum", "gemm", spec="mk,kn->mn",
                                lhs=(8, 16), rhs=(16, 8), flops=2048.0)
        with optrace.span("unit_span", cat="test", answer=42):
            pass
        optrace.add_instant("marker", cat="test")
        path = str(tmp_path / "trace.json")
        trace = trace_export.write_chrome_trace(path)
        assert trace_export.validate_chrome_trace(trace) == []
        loaded = json.load(open(path))         # full JSON round-trip
        assert trace_export.validate_chrome_trace(loaded) == []
        assert loaded["displayTimeUnit"] == "ms"
        names = {e["name"] for e in loaded["traceEvents"]}
        assert {"einsum:gemm", "unit_span", "marker",
                "process_name", "thread_name"} <= names
        x = next(e for e in loaded["traceEvents"]
                 if e["name"] == "unit_span")
        assert x["ph"] == "X" and x["dur"] >= 0 and x["args"]["answer"] == 42
        i = next(e for e in loaded["traceEvents"]
                 if e["name"] == "einsum:gemm")
        assert i["ph"] == "i" and i["args"]["spec"] == "mk,kn->mn"
        assert i["args"]["lhs"] == [8, 16]      # tuples JSON-ified

    def test_validator_catches_bad_traces(self):
        assert trace_export.validate_chrome_trace([]) != []
        assert trace_export.validate_chrome_trace({}) != []
        bad = {"traceEvents": [{"name": "x", "ph": "Q", "pid": 1, "tid": 1,
                                "ts": 0}]}
        assert any("phase" in e for e in
                   trace_export.validate_chrome_trace(bad))
        bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1,
                                "ts": -5, "dur": 1}]}
        assert any("ts" in e for e in
                   trace_export.validate_chrome_trace(bad))
        bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1,
                                "ts": 0}]}      # X without dur
        assert any("dur" in e for e in
                   trace_export.validate_chrome_trace(bad))

    def test_write_refuses_invalid(self, tmp_path, monkeypatch):
        optrace.enable()
        monkeypatch.setattr(trace_export, "chrome_trace",
                            lambda *a, **k: {"traceEvents": [{"ph": "Q"}]})
        with pytest.raises(ValueError):
            trace_export.write_chrome_trace(str(tmp_path / "t.json"))


# ---------------------------------------------------------------------------
# serve integration: span nesting + metrics for a 2-request run
# ---------------------------------------------------------------------------


class TestServeSpans:
    def test_two_request_run_nests_spans(self):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=16,
                             prefill_chunk=4, paged=True, page_size=4)
        reqs = _requests(cfg, [(3, 4), (6, 3)])
        optrace.enable()
        outs = engine.generate(reqs)
        assert all(len(o) > 0 for o in outs)

        spans = optrace.spans()
        steps = [s for s in spans if s.name == "serve_step"]
        assert len(steps) == engine.last_stats["steps"]
        t_end = max(s.ts_s + s.dur_s for s in steps)
        for ridx in range(2):
            tid = optrace.TID_REQUEST_BASE + ridx
            lane = {s.name: s for s in spans if s.tid == tid}
            assert {"admit", "prefill", "first_token", "decode",
                    "done"} <= set(lane)
            pre, dec = lane["prefill"], lane["decode"]
            # phases tile the request lifecycle in order...
            assert pre.ts_s <= dec.ts_s
            assert dec.ts_s == pytest.approx(pre.ts_s + pre.dur_s,
                                             abs=1e-6)
            # ...and end within the engine-step envelope (completion is
            # stamped just after the final step span closes)
            assert dec.ts_s + dec.dur_s <= t_end + 0.05
            assert pre.args["request"] == ridx
            assert pre.args["prompt_len"] == len(reqs[ridx].prompt)

        snap = metrics.snapshot()
        assert metrics.REGISTRY.get("serve_requests_total").value() == 2.0
        assert metrics.REGISTRY.get("serve_tokens_total").value() == \
            sum(len(o) for o in outs)
        for name in ("pagepool_occupancy", "pagepool_prefix_hit_rate",
                     "mapper_cache_hit_rate", "serve_ttft_seconds"):
            assert name in snap, name
        # stats carry the mapper cache health row (both engines' convention)
        mc = engine.last_stats["mapper_cache"]
        assert set(mc) >= {"hits", "misses", "hit_rate", "entries"}

        trace = trace_export.chrome_trace()
        assert trace_export.validate_chrome_trace(trace) == []


# ---------------------------------------------------------------------------
# off-by-default no-op
# ---------------------------------------------------------------------------


class TestOffByDefault:
    def test_disabled_run_allocates_nothing(self):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=16,
                             prefill_chunk=4)
        engine.generate(_requests(cfg, [(3, 3), (5, 2)]))
        assert len(metrics.REGISTRY) == 0      # zero metric objects
        assert optrace.events() == []
        assert optrace.spans() == []

    def test_tokens_bit_identical_obs_on_vs_off(self):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        reqs = _requests(cfg, [(3, 4), (6, 3), (4, 5)])

        def run(enabled):
            if enabled:
                optrace.enable()
            else:
                optrace.disable()
            engine = ServeEngine(params, cfg, batch_slots=2, max_len=16,
                                 prefill_chunk=4, seed=7)
            return engine.generate(reqs)

        off = run(False)
        on = run(True)
        assert on == off
        assert len(optrace.spans()) > 0        # the on run did record


# ---------------------------------------------------------------------------
# profiler scopes
# ---------------------------------------------------------------------------


class TestProfiler:
    def test_wall_scope_records_when_enabled(self):
        optrace.enable()
        with profiler.wall("unit") as scope:
            scope.ready(jnp.ones((8, 8)) * 2)
        assert scope.elapsed_s > 0
        h = metrics.REGISTRY.get("obs_wall_seconds")
        assert h is not None
        key = ("unit",)
        assert h._values[key]["count"] == 1
        assert any(s.name == "unit" and s.cat == "wall"
                   for s in optrace.spans())

    def test_wall_scope_noop_when_disabled(self):
        with profiler.wall("unit") as scope:
            scope.ready(jnp.ones((2,)))
        assert scope.elapsed_s > 0             # timing still returned
        assert len(metrics.REGISTRY) == 0      # nothing recorded


# ---------------------------------------------------------------------------
# the CLI smoke contract (what CI runs and uploads)
# ---------------------------------------------------------------------------


class TestCliSmoke:
    def test_smoke_emits_valid_artifacts(self, tmp_path):
        from repro.obs.__main__ import main
        trace_path = str(tmp_path / "trace.json")
        metrics_path = str(tmp_path / "metrics.json")
        rc = main(["--smoke", "--requests", "2",
                   "--trace-out", trace_path,
                   "--metrics-out", metrics_path])
        assert rc == 0
        trace = json.load(open(trace_path))
        assert trace_export.validate_chrome_trace(trace) == []
        snap = json.load(open(metrics_path))
        # the acceptance-criteria snapshot contents
        kinds = {v["labels"]["kind"]
                 for v in snap["axon_dispatch_total"]["values"]}
        assert {"gemm", "gemv", "conv2d", "dwconv", "xla"} <= kinds
        assert snap["axon_fallback_total"]["values"]   # fallback reasons
        assert "mapper_cache_hit_rate" in snap
        assert "pagepool_occupancy" in snap
        assert "pagepool_prefix_hit_rate" in snap

# ---------------------------------------------------------------------------
# prometheus exposition escaping
# ---------------------------------------------------------------------------


class TestPrometheusEscaping:
    def test_help_line_escapes_backslash_and_newline(self):
        metrics.counter(
            "esc_total", 'help with "quotes", a \\ and a\nnewline').inc()
        text = metrics.prometheus_text()
        help_lines = [ln for ln in text.splitlines()
                      if ln.startswith("# HELP esc_total")]
        # quotes are legal verbatim in HELP text; backslash and newline
        # must be escaped or the exposition parser sees a torn line
        assert help_lines == [
            r'# HELP esc_total help with "quotes", a \\ and a\nnewline']

    def test_hostile_label_values_escaped(self):
        c = metrics.counter("esc_lbl_total", "t", labels=("who",))
        hostile = 'a\\b"c\nd'
        c.inc(who=hostile)
        text = metrics.prometheus_text()
        sample = [ln for ln in text.splitlines()
                  if ln.startswith("esc_lbl_total{")]
        assert sample == ['esc_lbl_total{who="a\\\\b\\"c\\nd"} 1.0']
        # every exposition line is complete: no raw newline ever splits a
        # sample line in half
        for ln in text.splitlines():
            assert ln.startswith(("#", "esc_lbl_total", "esc_total")), ln


# ---------------------------------------------------------------------------
# ring sampling (production-rate mode)
# ---------------------------------------------------------------------------


class TestSampling:
    def _burst(self, n):
        for i in range(n):
            optrace.record_dispatch("einsum", "gemm", backend="interpret",
                                    flops=1.0, bytes=2.0)

    def test_counters_exact_ring_one_in_n(self):
        optrace.enable(ring_size=4096)
        optrace.configure(sample_every=4)
        self._burst(100)
        # side counters never sampled: exact
        c = metrics.REGISTRY.get("axon_dispatch_total")
        assert c.value(op="einsum", kind="gemm") == 100.0
        # ring holds exactly every 4th dispatch; the rest are tallied
        assert len(optrace.events()) == 25
        assert optrace.sampled_out_ops() == 75
        assert optrace.dropped_ops() == 0      # nothing evicted

    def test_sampling_is_deterministic(self):
        def run():
            optrace.enable(ring_size=4096)
            optrace.configure(sample_every=8)
            self._burst(64)
            return [(e.op, e.kind) for e in optrace.events()]
        a = run()
        b = run()
        assert a == b and len(a) == 8

    def test_dropped_ops_counts_evictions_not_sampling(self):
        optrace.enable(ring_size=8)
        self._burst(20)
        assert len(optrace.events()) == 8      # bounded
        assert optrace.dropped_ops() == 12     # evicted, not sampled out
        assert optrace.sampled_out_ops() == 0

    def test_configure_rejects_bad_stride(self):
        with pytest.raises(ValueError):
            optrace.configure(sample_every=0)


# ---------------------------------------------------------------------------
# streaming exporter lifecycle
# ---------------------------------------------------------------------------


class TestStreaming:
    def test_snapshots_during_long_serve(self, tmp_path):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                             prefill_chunk=4, paged=True, page_size=4)
        reqs = _requests(cfg, [(3, 12), (6, 12), (4, 12)])
        optrace.enable()
        exp = streaming.start(str(tmp_path), interval_s=0.05)
        try:
            engine.generate(reqs)
            mid_run = exp.snapshots_written
        finally:
            streaming.stop()
        # the serve is long against a 50ms cadence: snapshots landed
        # while it ran, not only at the final stop() flush
        assert mid_run >= 2
        assert streaming.active() is None      # clean shutdown
        snaps = streaming.read_jsonl(str(tmp_path / streaming.JSONL_NAME))
        assert len(snaps) >= 2
        assert [s["seq"] for s in snaps] == list(range(1, len(snaps) + 1))
        # the engine's collector published pool gauges on the cadence:
        # a mid-run snapshot already carries them
        mid = snaps[min(mid_run, len(snaps)) - 1]
        assert "pagepool_occupancy" in mid["metrics"]
        assert "mapper_cache_hit_rate" in mid["metrics"]
        # prom textfile is whole (atomic os.replace; no tmp file left over)
        prom = (tmp_path / streaming.PROM_NAME).read_text()
        assert prom.endswith("\n") and "# TYPE" in prom
        assert not (tmp_path / (streaming.PROM_NAME + ".tmp")).exists()

    def test_stop_flushes_at_least_once(self, tmp_path):
        optrace.enable()
        metrics.gauge("stream_unit_gauge", "g").set(3.0)
        streaming.start(str(tmp_path), interval_s=60.0)
        streaming.stop()
        snaps = streaming.read_jsonl(str(tmp_path / streaming.JSONL_NAME))
        assert len(snaps) == 1
        assert snaps[0]["metrics"]["stream_unit_gauge"]["values"]

    def test_read_jsonl_ignores_torn_tail(self, tmp_path):
        p = tmp_path / streaming.JSONL_NAME
        p.write_text('{"seq": 1, "metrics": {}}\n{"seq": 2, "met')
        snaps = streaming.read_jsonl(str(p))
        assert [s["seq"] for s in snaps] == [1]

    def test_failing_collector_never_kills_exporter(self, tmp_path):
        def boom():
            raise RuntimeError("collector crash")
        optrace.enable()
        streaming.start(str(tmp_path), interval_s=60.0)
        assert streaming.add_collector(boom)
        streaming.stop()                       # final flush runs the collector
        snaps = streaming.read_jsonl(str(tmp_path / streaming.JSONL_NAME))
        assert len(snaps) == 1                 # snapshot still written


# ---------------------------------------------------------------------------
# device-timeline annotation
# ---------------------------------------------------------------------------


class TestAnnotate:
    def test_scope_name_lands_in_compiled_hlo(self):
        def f(x):
            with annotate.scope("unit_attention_scope"):
                return x * 2.0
        compiled = jax.jit(f).lower(jnp.ones((4,))).compile()
        # the name stack travels through lowering into the compiled
        # module's metadata -- that is what the device profiler renders
        assert "unit_attention_scope" in compiled.as_text()

    def test_scope_does_not_change_results(self):
        def f(x):
            with annotate.scope("unit_scope"):
                return x @ x
        x = jax.random.normal(KEY, (8, 8))
        np.testing.assert_array_equal(jax.jit(f)(x), x @ x)

    def test_host_scope_is_noop_without_capture(self):
        ran = []
        with annotate.host_scope("serve_step", enabled=True):
            ran.append(1)
        with annotate.host_scope("serve_step", enabled=False):
            ran.append(2)
        assert ran == [1, 2]


# ---------------------------------------------------------------------------
# measured-vs-modeled attribution
# ---------------------------------------------------------------------------


class TestAttribution:
    def test_kind_rows_join_measured_and_modeled(self):
        import repro.axon as ax
        optrace.enable()
        optrace.configure(measure_dispatch=True)
        a = jax.random.normal(KEY, (16, 16), jnp.float32)
        b = jax.random.normal(KEY, (16, 16), jnp.float32)
        ax.matmul(a, b)                        # eager: measured + modeled
        rows = attribution.kind_rows()
        measured = [r for r in rows if r["measured_wall_s"]]
        assert measured, rows
        row = measured[0]
        assert row["count"] >= 1 and row["measured_calls"] >= 1
        assert row["modeled_flops"] > 0 and row["modeled_bytes"] > 0
        assert row["achieved_flops_per_s"] > 0
        assert row["achieved_bytes_per_s"] > 0
        assert row["time_error_ratio"] > 0
        assert row["roofline"] in ("compute-bound", "memory-bound")
        rep = attribution.report()
        assert rep["totals"]["measured_wall_s"] > 0
        assert rep["chip"]["ridge_flops_per_byte"] > 0
        sec = attribution.paper_section()
        assert sec["available"] and sec["kinds"]

    def test_paper_section_says_why_when_empty(self):
        sec = attribution.paper_section()
        assert sec["available"] is False
        assert "measure_dispatch" in sec["reason"]

    def test_write_json_roundtrip(self, tmp_path):
        import repro.axon as ax
        optrace.enable()
        optrace.configure(measure_dispatch=True)
        ax.matmul(jnp.ones((8, 8)), jnp.ones((8, 8)))
        out = tmp_path / "attribution.json"
        rep = attribution.write_json(str(out))
        assert json.load(open(out)) == json.loads(json.dumps(rep))


# ---------------------------------------------------------------------------
# engine achieved-intensity row
# ---------------------------------------------------------------------------


class TestEngineAttributionRow:
    def test_serve_last_stats_attribution(self):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        optrace.enable()
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=16,
                             prefill_chunk=4)
        engine.generate(_requests(cfg, [(3, 4), (6, 3)]))
        att = engine.last_stats["attribution"]
        # telemetry was on before the first trace of every step width, so
        # every executed step has a known per-trace modeled cost
        assert att["modeled_step_coverage"] == 1.0
        assert att["modeled_flops"] > 0 and att["modeled_bytes"] > 0
        assert att["achieved_flops_per_s"] > 0
        assert att["time_error_ratio"] > 0
        assert att["roofline"] in ("compute-bound", "memory-bound")

    def test_no_attribution_row_when_disabled(self):
        cfg = _cfg()
        params = T.init_params(KEY, cfg)
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=16,
                             prefill_chunk=4)
        engine.generate(_requests(cfg, [(3, 2)]))
        assert "attribution" not in engine.last_stats
