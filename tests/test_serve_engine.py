"""Continuous-batching serve engine: slot isolation, scheduling, identity.

The load-bearing property is the regression for the wave engine's padding
bug (left-padded EOS tokens leaked into shorter prompts' KV caches, and
``reqs[0].eos_id`` was assumed for the whole wave): batch-of-N generation
must equal batch-of-1 generation per request, token for token, under greedy
decoding.  The continuous engine's slot masking makes this hold for ragged
prompts, per-request eos ids, backfill, and any prefill chunking.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import (
    Request,
    ServeEngine,
    WaveServeEngine,
    make_chunk_step,
)

KEY = jax.random.PRNGKey(0)


def _cfg(arch):
    cfg = get_config(arch, reduced=True)
    # MoE capacity is sized per routed chunk; lift it so chunked prefill and
    # one-token decode route identically (no capacity drops) in identity tests
    return dataclasses.replace(cfg, capacity_factor=64.0)


def _params(cfg):
    return T.init_params(KEY, cfg)


def _requests(cfg, specs, seed=1):
    """specs: list of (prompt_len, max_new, eos_id)."""
    key = jax.random.PRNGKey(seed)
    reqs = []
    for plen, mnew, eos in specs:
        key, sub = jax.random.split(key)
        prompt = [int(t) for t in jax.random.randint(sub, (plen,), 2,
                                                     cfg.vocab)]
        reqs.append(Request(prompt=prompt, max_new_tokens=mnew, eos_id=eos))
    return reqs


MIXED = [(3, 6, 1), (9, 4, 7), (5, 8, 1), (12, 3, 2), (2, 5, 1), (7, 7, 3)]


class TestBatchIdentity:
    """Regression for the wave ``_wave`` padding bug: batch-of-N == batch-of-1."""

    @pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b",
                                      "falcon-mamba-7b", "deepseek-v3-671b"])
    def test_batchN_equals_batch1(self, arch):
        cfg = _cfg(arch)
        params = _params(cfg)
        reqs = _requests(cfg, MIXED)
        batched = ServeEngine(params, cfg, batch_slots=3, max_len=64,
                              prefill_chunk=4).generate(reqs)
        solo = ServeEngine(params, cfg, batch_slots=1, max_len=64,
                           prefill_chunk=4).generate(reqs)
        for i, (b, s) in enumerate(zip(batched, solo)):
            assert b == s, f"req {i}: batched {b} != batch-of-1 {s}"

    def test_prefill_chunk_invariance(self):
        # token-level (chunk=1) through wide chunks must agree exactly
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, MIXED)
        outs = [ServeEngine(params, cfg, batch_slots=3, max_len=64,
                            prefill_chunk=c).generate(reqs)
                for c in (1, 3, 8)]
        assert outs[0] == outs[1] == outs[2]

    def test_queue_policy_does_not_change_outputs(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, MIXED)
        fifo = ServeEngine(params, cfg, batch_slots=2, max_len=64,
                           queue_policy="fifo").generate(reqs)
        sjf = ServeEngine(params, cfg, batch_slots=2, max_len=64,
                          queue_policy="sjf").generate(reqs)
        assert fifo == sjf


class TestScheduling:
    def test_backfill_more_requests_than_slots(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(4, 5, 1)] * 7 + [(11, 3, 1)])
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=32)
        outs = engine.generate(reqs)
        assert all(o is not None and len(o) >= 1 for o in outs)
        st = engine.last_stats
        assert st["generated_tokens"] == sum(len(o) for o in outs)
        assert len(st["requests"]) == len(reqs)
        assert all(r["latency_s"] > 0 for r in st["requests"])

    def test_per_request_eos_stops_slot(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(5, 8, 1), (6, 8, 1)])
        engine = ServeEngine(params, cfg, batch_slots=2, max_len=32)
        first = engine.generate(reqs)
        # re-run with each request's eos set to its own first output token:
        # the slot must stop immediately after emitting it
        for i, r in enumerate(reqs):
            r.eos_id = first[i][0]
        outs = ServeEngine(params, cfg, batch_slots=2,
                           max_len=32).generate(reqs)
        assert outs == [[first[0][0]], [first[1][0]]]

    def test_prefill_chunk_clamped_to_window(self):
        cfg = _cfg("mixtral-8x7b")            # reduced SWA window = 8
        win = min(s.window for s in cfg.stages if s.window)
        engine = ServeEngine(_params(cfg), cfg, batch_slots=1, max_len=32,
                             prefill_chunk=64)
        assert engine.prefill_chunk == win
        outs = engine.generate(_requests(cfg, [(12, 4, 1)]))
        assert len(outs[0]) == 4

    def test_rejects_oversized_request_before_any_compute(self):
        cfg = _cfg("yi-9b")
        engine = ServeEngine(_params(cfg), cfg, batch_slots=1, max_len=16)
        # the bad request is LAST: validation must fail fast up front, not
        # after serving (and discarding) the good ones
        with pytest.raises(ValueError, match="max_len"):
            engine.generate(_requests(cfg, [(4, 2, 1), (12, 8, 1)]))
        assert engine.last_stats is None

    def test_max_new_tokens_zero(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(4, 0, 1), (5, 3, 1)])
        outs = ServeEngine(params, cfg, batch_slots=2,
                           max_len=16).generate(reqs)
        assert outs[0] == [] and len(outs[1]) == 3
        wave = WaveServeEngine(params, cfg, batch_slots=2,
                               max_len=16).generate(reqs)
        assert wave[0] == []

    def test_rejects_empty_prompt(self):
        cfg = _cfg("yi-9b")
        engine = ServeEngine(_params(cfg), cfg, batch_slots=1, max_len=16)
        with pytest.raises(ValueError, match="empty"):
            engine.generate([Request(prompt=[], max_new_tokens=2)])

    def test_temperature_sampling_runs(self):
        cfg = _cfg("yi-9b")
        outs = ServeEngine(_params(cfg), cfg, batch_slots=2, max_len=32,
                           temperature=0.8).generate(
            _requests(cfg, [(4, 6, 1), (7, 6, 1)]))
        assert all(1 <= len(o) <= 6 for o in outs)


class TestSlotStateMachine:
    @pytest.mark.parametrize("arch", ["yi-9b", "zamba2-7b"])
    def test_reset_slots_clears_only_masked(self, arch):
        cfg = _cfg(arch)
        params = _params(cfg)
        caches = T.init_caches(cfg, batch=2, max_len=8, dtype=jnp.float32)
        toks = jnp.asarray([[3, 4, 5], [6, 7, 8]], jnp.int32)
        _, caches = T.prefill_step(params, caches, {"tokens": toks},
                                   jnp.ones((2, 3), bool), cfg)
        np.testing.assert_array_equal(np.asarray(caches["pos"]), [3, 3])
        reset = T.reset_slots(caches, jnp.asarray([True, False]))
        np.testing.assert_array_equal(np.asarray(reset["pos"]), [0, 3])
        nonzero = False
        for (path, old), (_, new) in zip(
                jax.tree_util.tree_flatten_with_path(caches)[0],
                jax.tree_util.tree_flatten_with_path(reset)[0]):
            names = [getattr(k, "key", None) for k in path]
            name = next((n for n in reversed(names) if isinstance(n, str)),
                        None)
            axis = 1 if "layers" in names else 0
            if name in T._STALE_OK:
                # attention content stays (unreachable once counters are 0)
                np.testing.assert_array_equal(np.asarray(old),
                                              np.asarray(new), err_msg=name)
                continue
            # counters + recurrent state: slot 0 zeroed, slot 1 untouched
            slot0 = np.asarray(jnp.take(new, 0, axis=axis))
            assert not slot0.any(), names
            np.testing.assert_array_equal(
                np.asarray(jnp.take(old, 1, axis=axis)),
                np.asarray(jnp.take(new, 1, axis=axis)), err_msg=str(names))
            nonzero = nonzero or bool(
                np.asarray(jnp.take(new, 1, axis=axis)).any())
        assert nonzero

    def test_freed_slot_reuse_does_not_leak(self):
        # run a request through a slot, then a different one through the
        # same slot: its output must match a fresh engine's
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        r1, r2 = _requests(cfg, [(9, 3, 1), (4, 5, 1)])
        engine = ServeEngine(params, cfg, batch_slots=1, max_len=32)
        out_seq = engine.generate([r1, r2])
        out_fresh = ServeEngine(params, cfg, batch_slots=1,
                                max_len=32).generate([r2])
        assert out_seq[1] == out_fresh[0]

    def test_chunk_step_ignores_inactive_slots(self):
        # an all-invalid lane must leave its caches bit-identical
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        step = jax.jit(make_chunk_step(cfg))
        caches = T.init_caches(cfg, batch=2, max_len=8, dtype=jnp.float32)
        toks = jnp.asarray([[3, 4], [9, 9]], jnp.int32)
        valid = jnp.asarray([[True, True], [False, False]])
        _, caches2 = step(params, caches, toks, valid, KEY)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(caches)[0],
                jax.tree_util.tree_flatten_with_path(caches2)[0]):
            names = [getattr(k, "key", None) for k in pa]
            axis = 1 if "layers" in names else 0
            lane_before = np.asarray(jnp.take(a, 1, axis=axis))
            lane_after = np.asarray(jnp.take(b, 1, axis=axis))
            np.testing.assert_array_equal(lane_before, lane_after, err_msg=str(names))


class TestCacheDtype:
    """Regression: generate() hardcoded f32 caches, silently doubling the
    cache bytes of every quantized/bf16 serving run."""

    def test_default_follows_activation_dtype(self):
        cfg = _cfg("yi-9b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=2, max_len=32)
        assert eng.cache_dtype == jnp.dtype(cfg.cdtype)

    def test_quantized_engine_cache_is_not_f32(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        quant = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                            quantized="int8")
        assert quant.cache_dtype == jnp.bfloat16
        reqs = _requests(cfg, [(4, 3, 1)])
        quant.generate(reqs)
        ref = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                          cache_dtype=jnp.float32)
        ref.generate(reqs)
        # the KV payload halves; counters (int32) keep the ratio below 2x
        assert quant.last_stats["cache_bytes"] < ref.last_stats["cache_bytes"]

    def test_explicit_override_respected(self):
        cfg = _cfg("yi-9b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=1, max_len=16,
                          quantized="int8", cache_dtype=jnp.float32)
        assert eng.cache_dtype == jnp.float32
        wave = WaveServeEngine(_params(cfg), cfg, batch_slots=1, max_len=16,
                               cache_dtype=jnp.bfloat16)
        assert wave.cache_dtype == jnp.bfloat16


class TestStats:
    """Regression: ttft conflated queue wait with compute -- a request that
    waited 9 steps for a slot reported a 9-step "time to first token"."""

    def test_queue_wait_separated_from_ttft(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        # one slot, two requests: the second queues behind the whole first
        reqs = _requests(cfg, [(8, 6, 1), (4, 4, 1)])
        eng = ServeEngine(params, cfg, batch_slots=1, max_len=32,
                          prefill_chunk=2)
        eng.generate(reqs)
        r0, r1 = eng.last_stats["requests"]
        for r in (r0, r1):
            assert r["queue_s"] == r["admit_s"]
            assert r["ttft_s"] == pytest.approx(
                r["first_token_s"] - r["admit_s"])
            assert r["decode_s"] == pytest.approx(
                r["done_s"] - r["first_token_s"])
            assert r["ttft_s"] >= 0 and r["decode_s"] >= 0
        # r0 is admitted at the first scheduling point (its queue_s is only
        # engine setup); r1 waits out r0's entire prefill + decode
        assert r0["queue_s"] < r1["queue_s"]
        assert r1["queue_s"] >= r0["done_s"]      # slot freed, then admitted
        # the old conflated number: latency from t=0 vs ttft from admission
        assert r1["ttft_s"] < r1["first_token_s"]

    def test_prefill_throughput_reported_separately(self):
        cfg = _cfg("yi-9b")
        eng = ServeEngine(_params(cfg), cfg, batch_slots=2, max_len=32)
        outs = eng.generate(_requests(cfg, MIXED[:3]))
        st = eng.last_stats
        assert st["prefill_tokens"] == sum(p for p, _, _ in MIXED[:3])
        assert st["prefill_tokens_per_s"] > 0
        assert st["generated_tokens"] == sum(len(o) for o in outs)
        assert st["cache_bytes"] > 0
        assert st["cache_bytes_per_slot"] == st["cache_bytes"] // 2


class TestChunkWidthContract:
    """A decoding slot rides inside width-``prefill_chunk`` steps whenever
    any other slot is prefilling: its single valid token must sample the
    bit-identical next token it would get from a width-1 step, whatever
    garbage occupies the masked padding lanes."""

    @pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b",
                                      "deepseek-v3-671b"])
    def test_decode_at_chunk_width_matches_width1(self, arch):
        cfg = _cfg(arch)
        params = _params(cfg)
        C = 4
        step = make_chunk_step(cfg)               # eager: caches not donated
        caches = T.init_caches(cfg, batch=1, max_len=16, dtype=jnp.float32)
        prompt = jax.random.randint(jax.random.PRNGKey(3), (1, C), 2,
                                    cfg.vocab).astype(jnp.int32)
        tok, caches = step(params, caches, prompt, jnp.ones((1, C), bool),
                           KEY)
        # width-C decode: fed token in lane 0, garbage in the masked lanes
        wide = jnp.full((1, C), cfg.vocab - 1, jnp.int32).at[0, 0].set(tok[0])
        v_wide = jnp.zeros((1, C), bool).at[0, 0].set(True)
        out_wide, _ = step(params, caches, wide, v_wide, KEY)
        out_unit, _ = step(params, caches, tok[:, None],
                           jnp.ones((1, 1), bool), KEY)
        assert int(out_wide[0]) == int(out_unit[0])


class TestWaveBaseline:
    def test_wave_engine_generates(self):
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(4, 4, 1), (4, 4, 1)])
        outs = WaveServeEngine(params, cfg, batch_slots=2,
                               max_len=32).generate(reqs)
        assert [len(o) for o in outs] == [4, 4]

    def test_wave_matches_continuous_on_uniform_prompts(self):
        # with equal prompt lengths the wave padding bug cannot trigger: both
        # engines must produce identical greedy outputs.  prefill_chunk=1
        # keeps the token-at-a-time compute path bit-identical to the wave's
        # (wider chunks reorder the attention summation, which can flip a
        # greedy near-tie).
        cfg = _cfg("yi-9b")
        params = _params(cfg)
        reqs = _requests(cfg, [(6, 5, 1), (6, 5, 1), (6, 5, 1)])
        wave = WaveServeEngine(params, cfg, batch_slots=3,
                               max_len=32).generate(reqs)
        cont = ServeEngine(params, cfg, batch_slots=3, max_len=32,
                           prefill_chunk=1).generate(reqs)
        assert wave == cont
