"""The unified ``repro.axon`` operator API: policy scoping, registry
dispatch, mapper caching, and numerical parity with jnp.einsum across the
contraction specs the model zoo actually uses."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import axon
from repro.axon.policy import ExecutionPolicy
from repro.core.dataflows import Dataflow
from repro.core import mapper

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


class TestPolicy:
    def test_default_policy(self):
        p = axon.current_policy()
        assert p.backend == "auto"
        assert p.block is None and p.order is None

    def test_context_nesting_and_restoration(self):
        base = axon.current_policy()
        with axon.policy(backend="interpret") as p1:
            assert axon.current_policy() is p1
            assert p1.backend == "interpret"
            with axon.policy(block=(64, 64, 64), order=Dataflow.WS) as p2:
                cur = axon.current_policy()
                assert cur is p2
                # inner scope inherits the outer backend
                assert cur.backend == "interpret"
                assert cur.block == (64, 64, 64)
                assert cur.order is Dataflow.WS
            assert axon.current_policy() is p1
            assert axon.current_policy().block is None
        assert axon.current_policy() is base

    def test_context_restores_on_exception(self):
        base = axon.current_policy()
        with pytest.raises(RuntimeError):
            with axon.policy(backend="xla"):
                raise RuntimeError("boom")
        assert axon.current_policy() is base

    def test_full_policy_object_and_overrides(self):
        pol = ExecutionPolicy(backend="xla", zero_gate=True)
        with axon.policy(pol) as p:
            assert p is pol
        with axon.policy(pol, backend="interpret") as p:
            assert p.backend == "interpret" and p.zero_gate

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError):
            ExecutionPolicy(backend="cuda")

    def test_set_default_policy(self):
        old = axon.set_default_policy(ExecutionPolicy(backend="xla"))
        try:
            assert axon.current_policy().backend == "xla"
        finally:
            axon.set_default_policy(old)
        assert axon.current_policy() is old

    def test_set_default_policy_reaches_other_threads(self):
        import threading
        old = axon.set_default_policy(ExecutionPolicy(backend="interpret"))
        try:
            seen = []
            t = threading.Thread(
                target=lambda: seen.append(axon.current_policy().backend))
            t.start()
            t.join()
            assert seen == ["interpret"]
        finally:
            axon.set_default_policy(old)

    def test_force_interpret_override(self):
        assert ExecutionPolicy(backend="pallas",
                               force_interpret=False).interpret() is False
        assert ExecutionPolicy(backend="xla",
                               force_interpret=True).interpret() is True

    def test_integer_einsum_stays_exact_on_xla(self):
        # fp32-accumulating kernels are inexact for large ints: must fall back
        a = jnp.full((4, 8), 2**23, jnp.int32) + jnp.arange(8, dtype=jnp.int32)
        b = jnp.ones((8, 4), jnp.int32)
        with axon.policy(backend="interpret"):
            # shape-wise the spec is kernel-mappable; dtype forces fallback
            assert axon.explain("mk,kn->mn", a, b)["kind"] in ("gemm", "gemv")
            out = axon.einsum("mk,kn->mn", a, b)
        assert (np.asarray(out) == np.asarray(
            jnp.einsum("mk,kn->mn", a, b))).all()

    def test_unsupported_accum_dtype_raises(self):
        a, b = _rand((16, 8)), _rand((8, 4), seed=1)
        with axon.policy(backend="interpret", accum_dtype=jnp.bfloat16):
            with pytest.raises(NotImplementedError):
                axon.einsum("mk,kn->mn", a, b)


class TestDispatchRouting:
    """``axon.explain`` reports which registry kernel a spec lands on."""

    def test_projection_is_gemm(self):
        with axon.policy(backend="interpret"):
            info = axon.explain("bsd,de->bse", (2, 8, 8), (8, 4))
        assert info["kind"] == "gemm"
        assert (info["B"], info["M"], info["K"], info["N"]) == (1, 16, 8, 4)

    def test_small_batch_decode_is_gemv(self):
        # decode-step projections (M <= 8 rows) ride the streaming kernel
        with axon.policy(backend="interpret"):
            info = axon.explain("bd,de->be", (2, 8), (8, 6))
        assert info["kind"] == "gemv"

    def test_shared_batch_is_vmapped_gemm(self):
        with axon.policy(backend="interpret"):
            info = axon.explain("becd,edf->becf", (2, 3, 5, 6), (3, 6, 7))
        assert info["kind"] == "gemm" and info["vmapped"]
        assert info["B"] == 3

    def test_vector_is_gemv(self):
        with axon.policy(backend="interpret"):
            info = axon.explain("k,kn->n", (16,), (16, 8))
        assert info["kind"] == "gemv"

    def test_zero_gate_policy_reroutes(self):
        with axon.policy(backend="interpret", zero_gate=True):
            info = axon.explain("mk,kn->mn", (32, 16), (16, 8))
        assert info["kind"] == "zero_gate"

    @pytest.mark.parametrize("spec,shapes", [
        ("ij,jk->k", ((2, 3), (3, 4))),               # lhs-only label summed
        ("ij,ij->ij", ((4, 4), (4, 4))),              # elementwise (no K)
        ("ii->i", ((4, 4),)),                         # trace-like, 1 operand
        ("ij,jk,kl->il", ((2, 3), (3, 4), (4, 5))),   # 3 operands
    ])
    def test_non_matmul_falls_back_to_xla(self, spec, shapes):
        with axon.policy(backend="interpret"):
            info = axon.explain(spec, *shapes)
        assert info["kind"] == "xla"

    def test_xla_backend_short_circuits(self):
        with axon.policy(backend="xla"):
            info = axon.explain("mk,kn->mn", (8, 8), (8, 8))
        assert info["kind"] == "xla"

    def test_registry_lists_kernels(self):
        from repro.axon import registry
        for kind in ("gemm", "gemv", "zero_gate", "conv2d", "dwconv",
                     "xla_einsum"):
            assert kind in registry.kinds()


class TestMapperCache:
    def test_sweep_runs_once_per_unique_shape(self):
        mapper.mapper_cache_clear()
        a, b = _rand((48, 32)), _rand((32, 24), seed=1)
        with axon.policy(backend="interpret"):
            for _ in range(5):
                axon.matmul(a, b)
        assert mapper.sweep_calls() == 1
        info = mapper.mapper_cache_info()
        assert info.misses == 1 and info.hits >= 4
        # a new shape (or dtype => bytes_per_elem) is a new key
        with axon.policy(backend="interpret"):
            axon.matmul(_rand((16, 32)), b)
            axon.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16))
        assert mapper.sweep_calls() == 3

    def test_cached_decision_identical(self):
        mapper.mapper_cache_clear()
        from repro.core.dataflows import GemmShape
        first = mapper.select_tpu_blocking(GemmShape(512, 256, 512))
        second = mapper.select_tpu_blocking(GemmShape(512, 256, 512))
        assert first == second
        assert mapper.sweep_calls() == 1


# every matmul-shaped spec the models issue, with representative tiny dims
MODEL_SPECS = [
    ("bsd,de->bse", (2, 3, 8), (8, 6)),          # qkv/out projections
    ("bsd,df->bsf", (2, 3, 8), (8, 10)),         # mlp up/gate
    ("bsf,fd->bsd", (2, 3, 10), (10, 8)),        # mlp down
    ("bsd,dv->bsv", (2, 3, 8), (8, 12)),         # lm head
    ("bsq,qe->bse", (2, 3, 4), (4, 8)),          # mla q_b
    ("becd,edf->becf", (2, 3, 4, 6), (3, 6, 5)),  # moe expert gemm (EP batch)
    ("becf,efd->becd", (2, 3, 4, 5), (3, 5, 6)),  # moe down
    ("bqgrd,bkgd->bqgrk", (1, 3, 2, 2, 4), (1, 5, 2, 4)),  # flash scores
    ("bqgrk,bkgd->bqgrd", (1, 3, 2, 2, 5), (1, 5, 2, 4)),  # flash values
    ("bgrd,bkgd->bgrk", (2, 2, 3, 4), (2, 5, 2, 4)),  # decode scores
    ("bthn,chn->bthc", (2, 1, 2, 3), (4, 2, 3)),  # mla absorbed q_eff
    ("bthc,bsc->bths", (2, 1, 2, 4), (2, 5, 4)),  # mla latent scores
    ("bldn,bln->bld", (2, 3, 4, 5), (2, 3, 5)),   # mamba1 C contraction
    ("bkc,kc->bc", (2, 3, 4), (3, 4)),            # conv1d step
    ("bd,de->be", (2, 8), (8, 6)),                # decode projections
    ("blr,rd->bld", (2, 3, 4), (4, 6)),           # mamba1 dt projection
    ("abc,abc->", (2, 3, 4), (2, 3, 4)),          # full-reduction dot
]


class TestNumericalParity:
    @pytest.mark.parametrize("spec,sa,sb", MODEL_SPECS)
    def test_xla_backend_bit_identical(self, spec, sa, sb):
        a, b = _rand(sa), _rand(sb, seed=1)
        ref = jnp.einsum(spec, a, b)
        with axon.policy(backend="xla"):
            out = axon.einsum(spec, a, b)
        assert out.dtype == ref.dtype
        assert (np.asarray(out) == np.asarray(ref)).all()

    @pytest.mark.parametrize("spec,sa,sb", MODEL_SPECS)
    def test_interpret_backend_allclose(self, spec, sa, sb):
        a, b = _rand(sa), _rand(sb, seed=1)
        ref = jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
        with axon.policy(backend="interpret"):
            out = axon.einsum(spec, a, b,
                              preferred_element_type=jnp.float32)
        assert out.dtype == ref.dtype
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=1e-5)

    def test_bf16_operands_fp32_accumulation(self):
        a = _rand((2, 3, 32), jnp.bfloat16)
        b = _rand((32, 8), jnp.bfloat16, seed=1)
        ref = jnp.einsum("bsd,de->bse", a, b,
                         preferred_element_type=jnp.float32)
        with axon.policy(backend="interpret"):
            out = axon.einsum("bsd,de->bse", a, b,
                              preferred_element_type=jnp.float32)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)

    def test_grad_parity_under_interpret(self):
        a, b = _rand((4, 8)), _rand((8, 6), seed=1)

        def loss_axon(a, b):
            with axon.policy(backend="interpret"):
                return (axon.einsum("mk,kn->mn", a, b) ** 2).sum()

        def loss_ref(a, b):
            return (jnp.einsum("mk,kn->mn", a, b) ** 2).sum()

        ga = jax.grad(loss_axon, argnums=(0, 1))(a, b)
        gr = jax.grad(loss_ref, argnums=(0, 1))(a, b)
        for x, y in zip(ga, gr):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-4, atol=1e-4)

    def test_jit_with_policy_scope(self):
        a, b = _rand((2, 3, 8)), _rand((8, 6), seed=1)

        @jax.jit
        def f(a, b):
            with axon.policy(backend="interpret"):
                return axon.einsum("bsd,de->bse", a, b)

        np.testing.assert_allclose(np.asarray(f(a, b)),
                                   np.asarray(jnp.einsum("bsd,de->bse", a, b)),
                                   rtol=2e-5, atol=1e-5)

    def test_conv2d_parity(self):
        x = _rand((1, 10, 10, 4))
        w = _rand((3, 3, 4, 8), seed=1)
        with axon.policy(backend="xla"):
            ref = axon.conv2d(x, w, stride=1, padding=1)
        with axon.policy(backend="interpret"):
            out = axon.conv2d(x, w, stride=1, padding=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=1e-4)

    def test_matmul_front_door(self):
        a, b = _rand((5, 3, 8)), _rand((8, 4), seed=1)
        with axon.policy(backend="interpret"):
            out = axon.matmul(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=2e-5, atol=1e-5)


class TestOpsShims:
    def test_deprecation_warning_and_parity(self):
        from repro.kernels import ops
        a, b = _rand((32, 16)), _rand((16, 24), seed=1)
        with pytest.warns(DeprecationWarning):
            out = ops.auto_gemm(a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=2e-5, atol=1e-5)
