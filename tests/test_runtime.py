"""Optimizer, checkpointing, data pipeline, compression, trainer fault
tolerance, and a tiny end-to-end training run (loss must fall)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import (
    compress_with_feedback,
    compressed_psum,
    dequantize_int8,
    init_error,
    quantize_int8,
)
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def _ref_step(self, p, g, mu, nu, step, cfg):
        lr = adamw.schedule(cfg, jnp.asarray(step))
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / (1 - cfg.b1 ** step)
        nhat = nu / (1 - cfg.b2 ** step)
        return p - lr * (mhat / (np.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p)

    def test_matches_reference_math(self):
        cfg = adamw.OptConfig(lr=1e-2, warmup_steps=0, total_steps=1000,
                              clip_norm=1e9)
        p = {"w": jnp.array([1.0, -2.0, 3.0])}
        g = {"w": jnp.array([0.1, 0.2, -0.3])}
        st = adamw.init_opt_state(p, cfg)
        new_p, new_st, _ = adamw.adamw_update(p, g, st, cfg)
        want = self._ref_step(np.array([1.0, -2.0, 3.0]),
                              np.array([0.1, 0.2, -0.3]),
                              np.zeros(3), np.zeros(3), 1, cfg)
        np.testing.assert_allclose(new_p["w"], want, rtol=1e-5)

    def test_no_decay_on_norm_scales(self):
        cfg = adamw.OptConfig(lr=1e-2, warmup_steps=0, weight_decay=1.0,
                              clip_norm=1e9)
        p = {"scale": jnp.ones(4), "w": jnp.ones(4)}
        g = {"scale": jnp.zeros(4), "w": jnp.zeros(4)}
        st = adamw.init_opt_state(p, cfg)
        new_p, _, _ = adamw.adamw_update(p, g, st, cfg)
        np.testing.assert_allclose(new_p["scale"], p["scale"])   # untouched
        assert float(jnp.abs(new_p["w"] - p["w"]).sum()) > 0      # decayed

    def test_clip(self):
        g = {"w": jnp.full((100,), 10.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(100.0)
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_schedule_shape(self):
        cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(101)]
        assert lrs[0] == 0.0
        assert lrs[10] == pytest.approx(1.0)
        assert lrs[100] == pytest.approx(0.1, abs=1e-6)
        assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


class TestCompression:
    def test_quantize_roundtrip_bound(self):
        x = jax.random.normal(KEY, (1000,))
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_accumulates_exactly(self):
        # sum of compressed grads + final residual == sum of true grads
        gs = [jax.random.normal(jax.random.PRNGKey(i), (64,)) * 10 ** (i % 3)
              for i in range(8)]
        err = init_error({"w": gs[0]})
        total_comp = jnp.zeros(64)
        for g in gs:
            comp, err = compress_with_feedback({"w": g}, err)
            total_comp = total_comp + comp["w"]
        total_true = sum(gs)
        np.testing.assert_allclose(total_comp + err["w"], total_true,
                                   rtol=1e-4, atol=1e-4)

    def test_compressed_psum_single_axis(self):
        # axis size 1 under shard_map: identity up to quantization error
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # older jax ships it under experimental
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((1,), ("pod",))
        x = jax.random.normal(KEY, (128,))
        f = shard_map(lambda v: compressed_psum(v, "pod"), mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec())
        out = f(x)
        np.testing.assert_allclose(out, x, atol=float(jnp.abs(x).max()) / 100)


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
        state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                 "b": {"c": jnp.ones((4,), jnp.int32)}}
        for s in (1, 2, 3):
            mgr.save(s, jax.tree.map(lambda x: x * s, state),
                     extra={"data_state": {"step": s, "seed": 0}})
        assert mgr.all_steps() == [2, 3]          # keep_n GC
        restored, extra = mgr.restore(state)
        np.testing.assert_allclose(restored["a"], state["a"] * 3)
        assert extra["data_state"]["step"] == 3

    def test_hash_verification(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
        state = {"a": jnp.ones((3,))}
        mgr.save(1, state)
        npz = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
        with open(npz, "r+b") as f:
            f.seek(30)
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            mgr.restore(state)

    def test_structure_mismatch_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"a": jnp.ones(3)})
        with pytest.raises(ValueError):
            mgr.restore({"b": jnp.ones(3)})


class TestData:
    def test_deterministic_and_resumable(self):
        d1 = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=4, seed=7)
        b1 = [d1.next() for _ in range(3)]
        d2 = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=4, seed=7)
        d2.restore({"step": 2, "seed": 7})
        np.testing.assert_array_equal(d2.next()["tokens"], b1[2]["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=2, seed=0)
        b = d.next()
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        assert (b["tokens"] < 100).all() and (b["labels"] < 100).all()

    def test_host_slicing_disjoint(self):
        a = SyntheticLMDataset(vocab=50, seq_len=8, global_batch=8, seed=1,
                               host_index=0, host_count=2)
        b = SyntheticLMDataset(vocab=50, seq_len=8, global_batch=8, seed=1,
                               host_index=1, host_count=2)
        assert a.next()["tokens"].shape[0] == 4
        assert not np.array_equal(a.next()["tokens"], b.next()["tokens"])


class TestEndToEndTraining:
    def _setup(self, tmp_path, arch="yi-9b", steps_cfg=None):
        cfg = get_config(arch, reduced=True)
        opt = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                              **(steps_cfg or {}))
        state = init_train_state(KEY, cfg, opt)
        step = jax.jit(make_train_step(cfg, opt))
        data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, global_batch=4,
                                  seed=3)
        return cfg, opt, state, step, data

    def test_loss_decreases(self, tmp_path):
        cfg, opt, state, step, data = self._setup(tmp_path)
        first = None
        for i in range(30):
            state, metrics = step(state, data.next())
            if first is None:
                first = float(metrics["loss"])
        assert float(metrics["loss"]) < first - 0.3, (first, float(metrics["loss"]))

    def test_microbatched_equals_full_batch(self, tmp_path):
        cfg, opt, state, _, data = self._setup(tmp_path)
        batch = data.next()
        s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(state, batch)
        s2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(state, batch)
        # same averaged gradients -> same updated params (fp32 tolerance)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)

    def test_trainer_resume_after_crash(self, tmp_path):
        cfg, opt, state, step, data = self._setup(tmp_path)
        calls = {"n": 0}

        def flaky_step(st, b):
            calls["n"] += 1
            if calls["n"] == 7:
                raise RuntimeError("injected device failure")
            return step(st, b)

        tr = Trainer(train_step=flaky_step, state=state, dataset=data,
                     ckpt_dir=str(tmp_path), ckpt_every=3, max_retries=2)
        history = tr.run(10)
        assert int(tr.state["step"]) == 10
        assert len(history) >= 10          # all 10 steps eventually completed
        # checkpoint exists and reloads
        tr2 = Trainer(train_step=step, state=state, dataset=data,
                      ckpt_dir=str(tmp_path))
        assert tr2.maybe_resume()
        assert int(tr2.state["step"]) == 10
