"""im2col traffic, energy, utilization/CMSA and mapper model tests."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hw
from repro.core.cmsa_model import utilization_improvement_cmsa
from repro.core.dataflows import Dataflow, GemmShape
from repro.core.energy_model import (
    PAPER_ASIC,
    area_overhead_im2col,
    dram_energy_joules,
    power_overhead_im2col,
    zero_gating_power_reduction,
)
from repro.core.im2col_model import ConvShape, im2col_traffic, lower_to_gemm, model_traffic
from repro.core.mapper import modeled_traffic, select_asic_mapping, select_tpu_blocking
from repro.core.runtime_model import ArrayShape
from repro.core.utilization import utilization, utilization_improvement
from repro.core.workloads import GEMV, TABLE3, resnet50_convs, yolov3_convs


class TestIm2colModel:
    def test_fig7_example(self):
        # 3x3 filter on 6x6 ifmap -> 4x4 OFMAP, 16 windows.
        conv = ConvShape(6, 6, 1, 1, 3)
        assert conv.H_out == conv.W_out == 4
        g = lower_to_gemm(conv)
        assert (g.M, g.K, g.N) == (1, 9, 16)

    def test_table3_conv_lowerings(self):
        # Resnet50_0: 7x7x3 stride-2 conv -> K = 147 (Table 3).
        conv = ConvShape(500, 500, 3, 64, 7, stride=2, padding=0)
        g = lower_to_gemm(conv)
        assert g.K == 147 and g.M == 64
        # YOLO_v3_0: 3x3x32 -> K = 288.
        conv = ConvShape(416, 416, 32, 64, 3, stride=2, padding=1)
        assert lower_to_gemm(conv).K == 288

    def test_reduction_approaches_two_thirds_for_3x3(self):
        conv = ConvShape(224, 224, 64, 64, 3, stride=1, padding=1)
        t = im2col_traffic(conv, feeder_group=224)
        assert 0.6 < t.reduction < 0.67

    def test_no_reduction_for_1x1(self):
        conv = ConvShape(56, 56, 256, 64, 1)
        t = im2col_traffic(conv)
        assert t.reduction == 0.0
        assert t.axon_elems == t.sw_im2col_elems

    @given(n=st.sampled_from([1, 3, 5, 7]), s=st.sampled_from([1, 2]),
           hw_=st.sampled_from([14, 28, 56]), c=st.sampled_from([3, 16, 64]))
    @settings(max_examples=40)
    def test_axon_never_more_traffic(self, n, s, hw_, c):
        conv = ConvShape(hw_, hw_, c, 32, n, stride=s, padding=n // 2)
        t = im2col_traffic(conv)
        assert t.axon_elems <= t.sw_im2col_elems

    def test_sram_read_model_matches_feeder_sim(self):
        # analytical fresh-element count == the simulated feeder's SRAM reads
        from repro.core.axon_sim import simulate_im2col_feeders
        n, group = 3, 8
        ifmap = np.arange(400.0).reshape(20, 20)
        sim = simulate_im2col_feeders(ifmap, n, group=group)
        conv = ConvShape(20, n + group - 1 + 2, 1, 1, n)  # one window row ~ group+2
        # per-group model: n^2 + (g-1)*n
        assert sim.sram_reads == n * n + (group - 1) * n

    def test_resnet50_yolo_traffic_reductions(self):
        # §5.2.1: ResNet50 conv traffic 261.2MB -> 153.5MB (41.2% reduction);
        # YOLOv3 2540 -> 1117MB (56.0%).  Our layer lists are the public
        # architectures (batch-1 @224/@416 fp16, so the absolute MB differ
        # from the paper's unstated batch/precision), but the *reduction
        # ratio* -- the actual claim -- must reproduce to within 10 points.
        sw_r, ax_r = model_traffic(resnet50_convs(), bytes_per_elem=2)
        sw_y, ax_y = model_traffic(yolov3_convs(), bytes_per_elem=2)
        paper_r = 1 - 153.5 / 261.2   # 0.412
        paper_y = 1 - 1117 / 2540     # 0.560
        assert abs((1 - ax_r / sw_r) - paper_r) < 0.10, (sw_r, ax_r)
        assert abs((1 - ax_y / sw_y) - paper_y) < 0.10, (sw_y, ax_y)

    def test_fig11_over_60pct_for_sota_3x3(self):
        # Fig. 11: >60% memory-access reduction for SOTA conv shapes.
        for conv in [ConvShape(56, 56, 64, 64, 3, stride=1, padding=1),
                     ConvShape(28, 28, 128, 128, 3, stride=1, padding=1),
                     ConvShape(14, 14, 256, 256, 3, stride=1, padding=1)]:
            t = im2col_traffic(conv, feeder_group=16)
            assert t.reduction > 0.60, (conv, t.reduction)


class TestEnergyModel:
    def test_paper_overheads(self):
        assert area_overhead_im2col() == pytest.approx(0.002, abs=5e-4)  # ~0.2%
        # Paper text says "1.6%", but its own measurements (59.98 vs
        # 59.88 mW) give 0.167% -- a 10x internal inconsistency in the paper;
        # we encode the measured values (see EXPERIMENTS.md §Fidelity).
        assert power_overhead_im2col() == pytest.approx(0.00167, abs=2e-4)

    def test_zero_gating_calibration(self):
        # 10% sparsity -> 5.3% total power reduction (§5.2.1)
        assert zero_gating_power_reduction(0.10) == pytest.approx(0.053, abs=1e-3)

    def test_dram_energy(self):
        # 107.7 MB saved on ResNet50 -> ~12.9 mJ (paper prints "12MJ", a unit
        # typo; the model reproduces the number in millijoules).
        saved = (261.2 - 153.5) * 1e6
        assert dram_energy_joules(saved) == pytest.approx(12.9e-3, rel=0.01)

    def test_peak_throughput_consistent(self):
        # 256 PEs * 550 MHz * 2 flops = 281.6 GFLOP/s ~ paper's 284 GFLOP/s.
        derived = 256 * PAPER_ASIC.freq_hz * 2
        assert derived == pytest.approx(PAPER_ASIC.peak_flops, rel=0.02)


class TestUtilization:
    def test_ur_bounded(self):
        arr = ArrayShape(128, 128)
        for shape in TABLE3.values():
            for axon in (False, True):
                u = utilization(shape, arr, Dataflow.OS, axon=axon)
                assert 0 < u <= 1

    def test_axon_ur_improvement_positive(self):
        arr = ArrayShape(128, 128)
        for shape in TABLE3.values():
            assert utilization_improvement(shape, arr, axon=True) >= 0

    def test_axon_beats_cmsa_on_average(self):
        # Fig. 13: Axon outperforms CMSA by ~27% on average (128x128).
        arr = ArrayShape(128, 128)
        ax, cm = [], []
        for shape in TABLE3.values():
            ax.append(utilization_improvement(shape, arr, axon=True))
            cm.append(utilization_improvement_cmsa(shape, arr))
        assert sum(ax) / len(ax) > sum(cm) / len(cm)

    def test_high_ur_workloads_have_small_improvement(self):
        # §5.2.2: GPT3 matmul1/addmm/lmhead already run at ~91% UR on the SA,
        # so the improvement is small for both Axon and CMSA.
        arr = ArrayShape(128, 128)
        for name in ("GPT3_1", "GPT3_2", "GPT3_3"):
            base = utilization(TABLE3[name], arr, Dataflow.OS, axon=False)
            assert base > 0.85, (name, base)
            assert utilization_improvement(TABLE3[name], arr, axon=True) < 0.15


class TestMapper:
    def test_asic_mapping_picks_min(self):
        from repro.core.runtime_model import runtime_scaleup
        arr = ArrayShape(64, 64)
        for shape in list(TABLE3.values())[:8]:
            m = select_asic_mapping(shape, arr, axon=True)
            want = min(runtime_scaleup(shape, arr, df, axon=True)
                       for df in Dataflow)
            assert m.cycles == want

    def test_tpu_blocking_fits_vmem(self):
        for shape in TABLE3.values():
            b = select_tpu_blocking(shape)
            assert b.vmem_bytes <= hw.VMEM_TILE_BUDGET

    def test_tpu_blocking_traffic_sane(self):
        # blocked traffic >= compulsory traffic (each operand once).
        for shape in TABLE3.values():
            b = select_tpu_blocking(shape)
            compulsory = 2 * (shape.M * shape.K + shape.K * shape.N + shape.M * shape.N)
            assert b.hbm_traffic_bytes >= compulsory

    @given(m=st.integers(1, 4096), k=st.integers(1, 4096), n=st.integers(1, 4096))
    @settings(max_examples=30, deadline=None)
    def test_tpu_blocking_total_property(self, m, k, n):
        shape = GemmShape(m, k, n)
        b = select_tpu_blocking(shape)
        assert b.bm >= 1 and b.bk >= 1 and b.bn >= 1
        assert b.bm <= max(shape.M, 128) and b.bn <= max(shape.N, 128)

    def test_gemv_prefers_reading_weights_once(self):
        # GEMV: the weight matrix dominates traffic; the chosen loop order
        # must not re-read it (Nt==1 or IS/WS order with single pass).
        shape = GEMV["MV_1"]
        b = select_tpu_blocking(shape)
        w_bytes = shape.K * shape.N * 2
        assert b.hbm_traffic_bytes < 1.5 * w_bytes + 2 * (shape.M * shape.K + shape.M * shape.N) * 2
