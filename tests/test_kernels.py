"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode on CPU (the kernel bodies execute in
Python); on TPU the identical pallas_calls lower to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dataflows import Dataflow
from repro.kernels import ref
from repro.kernels.axon_gemm import axon_gemm
from repro.kernels.dwconv import dwconv
from repro.kernels.gemv import gemv
from repro.kernels.im2col_conv import hbm_traffic_model, im2col_conv
from repro.kernels.zero_gate_gemm import block_mask, skip_fraction, zero_gate_gemm

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=1e-5)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestAxonGemm:
    @pytest.mark.parametrize("order", list(Dataflow))
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("m,k,n,block", [
        (32, 32, 32, (16, 16, 16)),
        (48, 40, 56, (16, 8, 16)),     # non-divisible -> padded
        (8, 128, 8, (8, 32, 8)),
        (100, 17, 3, (32, 8, 2)),      # ragged everything
        (1, 64, 64, (1, 16, 16)),      # GEMV-shaped
    ])
    def test_matches_oracle(self, order, dtype, m, k, n, block):
        a = _rand(KEY, (m, k), dtype)
        b = _rand(jax.random.PRNGKey(1), (k, n), dtype)
        out = axon_gemm(a, b, block=block, order=order, interpret=True)
        want = ref.gemm_ref(a, b)
        assert out.shape == want.shape and out.dtype == want.dtype
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    @given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
           order=st.sampled_from(list(Dataflow)))
    @settings(max_examples=25, deadline=None)
    def test_property_shapes(self, m, k, n, order):
        a = _rand(KEY, (m, k), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
        out = axon_gemm(a, b, block=(16, 16, 16), order=order, interpret=True)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=2e-4, atol=1e-5)

    def test_orders_agree_with_each_other(self):
        a = _rand(KEY, (64, 48), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (48, 32), jnp.float32)
        outs = [axon_gemm(a, b, block=(16, 16, 16), order=o, interpret=True)
                for o in Dataflow]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=1e-5)


class TestIm2colConv:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,h,w,cin,cout,kh,stride,pad", [
        (1, 12, 12, 8, 16, 3, 1, 1),
        (2, 16, 16, 4, 8, 3, 2, 1),
        (1, 14, 14, 16, 8, 5, 1, 2),
        (1, 8, 8, 3, 4, 1, 1, 0),      # 1x1
        (1, 20, 20, 8, 8, 7, 2, 3),
        (2, 9, 13, 5, 6, 3, 1, 1),     # ragged spatial
    ])
    def test_matches_lax_conv(self, dtype, n, h, w, cin, cout, kh, stride, pad):
        x = _rand(KEY, (n, h, w, cin), dtype)
        wgt = _rand(jax.random.PRNGKey(1), (kh, kh, cin, cout), dtype) * 0.2
        out = im2col_conv(x, wgt, stride=stride, padding=pad,
                          block_rows=4, block_cout=8, block_cin=8, interpret=True)
        want = ref.conv2d_ref(x, wgt, stride=stride, padding=pad)
        assert out.shape == want.shape, (out.shape, want.shape)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    @given(h=st.integers(6, 18), cin=st.integers(1, 9), kh=st.sampled_from([1, 3, 5]),
           stride=st.sampled_from([1, 2]))
    @settings(max_examples=15, deadline=None)
    def test_property(self, h, cin, kh, stride):
        x = _rand(KEY, (1, h, h, cin), jnp.float32)
        wgt = _rand(jax.random.PRNGKey(1), (kh, kh, cin, 4), jnp.float32) * 0.3
        out = im2col_conv(x, wgt, stride=stride, padding=kh // 2,
                          block_rows=3, block_cout=4, block_cin=4, interpret=True)
        want = ref.conv2d_ref(x, wgt, stride=stride, padding=kh // 2)
        np.testing.assert_allclose(out, want, rtol=3e-4, atol=1e-4)

    def test_traffic_model_reduction(self):
        # the kernel's HBM traffic model must show the paper's >60% cut for
        # 3x3 stride-1 SOTA shapes.
        t = hbm_traffic_model((1, 56, 56, 64), (3, 3, 64, 64), stride=1, padding=1)
        assert t["reduction"] > 0.6


class TestDwConv:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("h,c,kh,stride", [
        (12, 8, 3, 1), (16, 16, 3, 2), (14, 4, 5, 1), (10, 32, 3, 1),
    ])
    def test_matches_oracle(self, dtype, h, c, kh, stride):
        x = _rand(KEY, (2, h, h, c), dtype)
        wgt = _rand(jax.random.PRNGKey(1), (kh, kh, c), dtype) * 0.3
        out = dwconv(x, wgt, stride=stride, padding=kh // 2,
                     block_rows=4, block_c=8, interpret=True)
        want = ref.dwconv_ref(x, wgt, stride=stride, padding=kh // 2)
        assert out.shape == want.shape
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))


class TestGemv:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("k,n,bk,bn", [
        (256, 512, 64, 128), (100, 300, 32, 64), (64, 64, 64, 64),
    ])
    def test_matches_oracle(self, dtype, k, n, bk, bn):
        x = _rand(KEY, (k,), dtype)
        w = _rand(jax.random.PRNGKey(1), (k, n), dtype)
        out = gemv(x, w, block_k=bk, block_n=bn, interpret=True)
        want = ref.gemv_ref(x, w)
        assert out.shape == want.shape
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_small_batch(self):
        x = _rand(KEY, (4, 128), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (128, 256), jnp.float32)
        out = gemv(x, w, block_k=64, block_n=64, interpret=True)
        np.testing.assert_allclose(out, ref.gemv_ref(x, w), rtol=2e-4, atol=1e-5)


class TestZeroGateGemm:
    def test_dense_equals_gemm(self):
        a = _rand(KEY, (64, 64), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (64, 32), jnp.float32)
        out = zero_gate_gemm(a, b, block=(16, 16, 16), interpret=True)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=2e-4, atol=1e-5)

    @pytest.mark.parametrize("zero_rows", [0.25, 0.5, 0.75])
    def test_block_sparse_exact(self, zero_rows):
        # zero whole block-rows of A -> skipped MXU passes, same result.
        a = np.array(_rand(KEY, (64, 64), jnp.float32))  # writable copy
        nz = int(64 * zero_rows) // 16 * 16
        a[:nz] = 0.0
        a = jnp.asarray(a)
        b = _rand(jax.random.PRNGKey(1), (64, 32), jnp.float32)
        mask = block_mask(a, 16, 16)
        assert skip_fraction(mask) == pytest.approx(zero_rows, abs=0.01)
        out = zero_gate_gemm(a, b, block=(16, 16, 16), interpret=True)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=2e-4, atol=1e-5)

    def test_paper_power_story(self):
        # 10% element sparsity structured into blocks -> ~10% of passes
        # skipped; with the paper's 53% MAC power fraction that is the 5.3%
        # saving of §5.2.1 (energy model cross-check).
        from repro.core.energy_model import zero_gating_power_reduction
        assert zero_gating_power_reduction(0.10) == pytest.approx(0.053, abs=1e-3)


class TestConvGrads:
    """Custom-VJP coverage: jax.grad through the kernel conv paths must
    match the XLA backend (the kernels' backward runs the exact reference
    VJP of the same function)."""

    @staticmethod
    def _grads(back, fn):
        return jax.grad(lambda x, w: fn(x, w, back), argnums=(0, 1))

    @pytest.mark.parametrize("backend", ["interpret", "pallas"])
    @pytest.mark.parametrize("stride,pad", [(1, 1), (2, 1)])
    def test_conv2d_grad_matches_xla(self, backend, stride, pad):
        from repro import axon
        x = _rand(KEY, (1, 10, 10, 4), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 4, 8), jnp.float32) * 0.2

        def loss(x, w, back):
            with axon.policy(backend=back):
                out = axon.conv2d(x, w, stride=stride, padding=pad,
                                  block_rows=4, block_cout=8, block_cin=8)
            return out.astype(jnp.float32).sum()

        got = self._grads(backend, loss)(x, w)
        want = self._grads("xla", loss)(x, w)
        for g, r in zip(got, want):
            np.testing.assert_allclose(g, r, rtol=2e-4, atol=1e-4)

    @pytest.mark.parametrize("backend", ["interpret", "pallas"])
    def test_depthwise_grad_matches_xla(self, backend):
        from repro import axon
        x = _rand(KEY, (2, 8, 8, 4), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 4), jnp.float32) * 0.3

        def loss(x, w, back):
            with axon.policy(backend=back):
                out = axon.depthwise_conv2d(x, w, stride=1, padding=1,
                                            block_rows=4, block_c=4)
            return (out.astype(jnp.float32) ** 2).sum()

        got = self._grads(backend, loss)(x, w)
        want = self._grads("xla", loss)(x, w)
        for g, r in zip(got, want):
            np.testing.assert_allclose(g, r, rtol=2e-4, atol=1e-4)

    def test_conv2d_grad_bf16_operands(self):
        from repro import axon
        x = _rand(KEY, (1, 8, 8, 4), jnp.bfloat16)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 4, 4), jnp.bfloat16) * 0.2

        def loss(x, w, back):
            with axon.policy(backend=back):
                out = axon.conv2d(x, w, stride=1, padding=1, block_rows=4,
                                  block_cout=4, block_cin=4)
            return out.astype(jnp.float32).sum()

        got = self._grads("interpret", loss)(x, w)
        want = self._grads("xla", loss)(x, w)
        for g, r in zip(got, want):
            assert g.dtype == r.dtype == jnp.bfloat16
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(r, np.float32),
                                       rtol=2e-2, atol=2e-2)


class TestOpsWrappers:
    def test_auto_gemm_runs(self):
        from repro.kernels import ops
        a = _rand(KEY, (256, 192), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (192, 160), jnp.float32)
        out = ops.auto_gemm(a, b)
        np.testing.assert_allclose(out, ref.gemm_ref(a, b), rtol=2e-4, atol=1e-5)

    def test_conv_wrapper(self):
        from repro.kernels import ops
        x = _rand(KEY, (1, 12, 12, 8), jnp.float32)
        w = _rand(jax.random.PRNGKey(1), (3, 3, 8, 16), jnp.float32) * 0.2
        out = ops.conv2d(x, w, stride=1, padding=1, block_rows=4,
                         block_cout=8, block_cin=8)
        np.testing.assert_allclose(out, ref.conv2d_ref(x, w, stride=1, padding=1),
                                   rtol=2e-4, atol=1e-4)
