"""Tests for ``repro.analysis``: golden known-bad fixtures (each must be
caught with the right rule ID), the live-repo-is-clean meta-test, and the
pinned regressions for what the analyzer originally flagged (quant impls
bypassing the accum-dtype policy check; the WS/IS output-revisit hazard)."""
from __future__ import annotations

import ast
import dataclasses
import functools
import importlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import contracts, lint, qt_invariants, retrace, run_all
from repro.analysis.findings import Finding, has_errors, render_json
from repro.axon import registry
from repro.axon.policy import ExecutionPolicy
from repro.core.dataflows import Dataflow
from repro.kernels.axon_gemm import axon_gemm
from repro.quant import qtensor as qt


def rules(findings: list[Finding]) -> set[str]:
    return {f.rule for f in findings}


def _pallas_eqn(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    calls = contracts.find_pallas_calls(jaxpr.jaxpr)
    assert calls, "fixture did not trace to a pallas_call"
    return calls[0]


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int8)


# ---------------------------------------------------------------------------
# contracts: golden bad kernels
# ---------------------------------------------------------------------------


class TestContractFixtures:
    def test_f32_accum_on_int8_operands_is_axc005(self):
        """An int8 x int8 kernel accumulating in f32 drops low bits."""
        def bad(a, b):
            def body(a_ref, b_ref, o_ref):
                o_ref[...] = jnp.dot(
                    a_ref[...].astype(jnp.int8), b_ref[...],
                    preferred_element_type=jnp.float32)
            return pl.pallas_call(
                body, grid=(1,),
                in_specs=[pl.BlockSpec((64, 64), lambda i: (0, 0)),
                          pl.BlockSpec((64, 64), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((64, 64), lambda i: (0, 0)),
                out_shape=jax.ShapeDtypeStruct((64, 64), jnp.float32),
                interpret=True)(a, b)
        eqn = _pallas_eqn(bad, _i8(64, 64), _i8(64, 64))
        fs = contracts.check_pallas_eqn(eqn, "quant_gemm", "fixture")
        assert "AXC005" in rules(fs)
        assert all(f.severity == "ERROR" for f in fs)

    def test_index_map_skipping_a_tile_is_axc002(self):
        """Index map collapses two grid rows onto one tile: a tile is
        never written."""
        def bad(a):
            def body(a_ref, o_ref):
                o_ref[...] = a_ref[...]
            return pl.pallas_call(
                body, grid=(4,),
                in_specs=[pl.BlockSpec((32, 64), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((32, 64), lambda i: (i // 2, 0)),
                out_shape=jax.ShapeDtypeStruct((128, 64), jnp.float32),
                interpret=True)(a)
        eqn = _pallas_eqn(bad, _f32(128, 64))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert "AXC002" in rules(fs)

    def test_out_of_bounds_tile_is_axc003(self):
        def bad(a):
            def body(a_ref, o_ref):
                o_ref[...] = a_ref[...]
            return pl.pallas_call(
                body, grid=(2,),
                in_specs=[pl.BlockSpec((32, 64), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((32, 64), lambda i: (i + 1, 0)),
                out_shape=jax.ShapeDtypeStruct((64, 64), jnp.float32),
                interpret=True)(a)
        eqn = _pallas_eqn(bad, _f32(64, 64))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert "AXC003" in rules(fs)

    def test_nonconsecutive_output_revisit_is_axc004(self):
        """The pre-fix WS loop order: the K grid dim is ignored by the
        output index map but sits in the middle of the grid."""
        def bad(a, b):
            def body(a_ref, b_ref, o_ref):
                k = pl.program_id(1)

                @pl.when(k == 0)
                def _init():
                    o_ref[...] = jnp.zeros_like(o_ref)
                o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                      preferred_element_type=jnp.float32)
            return pl.pallas_call(
                body, grid=(2, 2, 2),
                in_specs=[pl.BlockSpec((64, 64), lambda j, l, i: (i, l)),
                          pl.BlockSpec((64, 64), lambda j, l, i: (l, j))],
                out_specs=pl.BlockSpec((64, 64), lambda j, l, i: (i, j)),
                out_shape=jax.ShapeDtypeStruct((128, 128), jnp.float32),
                interpret=True)(a, b)
        eqn = _pallas_eqn(bad, _f32(128, 128), _f32(128, 128))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert "AXC004" in rules(fs)

    def test_trailing_ignored_grid_dim_is_clean(self):
        """The OS order ignores nothing mid-grid: K innermost is legal."""
        def good(a, b):
            def body(a_ref, b_ref, o_ref):
                k = pl.program_id(2)

                @pl.when(k == 0)
                def _init():
                    o_ref[...] = jnp.zeros_like(o_ref)
                o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                      preferred_element_type=jnp.float32)
            return pl.pallas_call(
                body, grid=(2, 2, 2),
                in_specs=[pl.BlockSpec((64, 64), lambda i, j, l: (i, l)),
                          pl.BlockSpec((64, 64), lambda i, j, l: (l, j))],
                out_specs=pl.BlockSpec((64, 64), lambda i, j, l: (i, j)),
                out_shape=jax.ShapeDtypeStruct((128, 128), jnp.float32),
                interpret=True)(a, b)
        eqn = _pallas_eqn(good, _f32(128, 128), _f32(128, 128))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert not fs

    def test_vmem_blowout_is_axc001(self):
        def bad(a):
            def body(a_ref, o_ref):
                o_ref[...] = a_ref[...]
            return pl.pallas_call(
                body, grid=(1,),
                in_specs=[pl.BlockSpec((2048, 2048), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((2048, 2048), lambda i: (0, 0)),
                out_shape=jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
                interpret=True)(a)
        eqn = _pallas_eqn(bad, _f32(2048, 2048))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert "AXC001" in rules(fs)

    def test_ragged_output_block_is_axc006(self):
        def bad(a):
            def body(a_ref, o_ref):
                o_ref[...] = a_ref[...]
            return pl.pallas_call(
                body, grid=(2,),
                in_specs=[pl.BlockSpec((48, 64), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((48, 64), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((80, 64), jnp.float32),
                interpret=True)(a)
        eqn = _pallas_eqn(bad, _f32(80, 64))
        fs = contracts.check_pallas_eqn(eqn, "gemm", "fixture")
        assert "AXC006" in rules(fs)

    def test_unknown_kind_lacking_driver_is_axc000(self):
        fs = contracts.run(kinds=["gemm", "definitely_not_registered"])
        assert any(f.rule == "AXC000"
                   and f.subject == "definitely_not_registered"
                   for f in fs)


# ---------------------------------------------------------------------------
# retrace: a two-signature engine sneaking a third
# ---------------------------------------------------------------------------


class TestRetraceFixtures:
    def test_third_width_is_rtr001(self, monkeypatch):
        from repro.serve import engine as se

        def sneaky(states, prefill_chunk):
            # half-chunk "optimization" for a single prefilling slot: a
            # third traced signature
            n_pre = sum(s == "prefill" for s in states)
            if n_pre == 1 and prefill_chunk > 2:
                return prefill_chunk // 2
            return prefill_chunk if n_pre else 1

        monkeypatch.setattr(se, "step_width", sneaky)
        fs = retrace.run()
        assert any(f.rule == "RTR001" and f.subject == "ServeEngine"
                   for f in fs)

    def test_vision_partial_batch_is_rtr001(self, monkeypatch):
        from repro.vision import engine as ve
        monkeypatch.setattr(
            ve, "step_batch",
            lambda n_admitted, batch_slots: max(n_admitted, 1))
        fs = retrace.run()
        assert any(f.rule == "RTR001" and f.subject == "VisionEngine"
                   for f in fs)

    def test_dead_declaration_is_rtr002(self, monkeypatch):
        from repro.serve import engine as se
        monkeypatch.setattr(se, "declared_step_widths",
                            lambda chunk: (chunk, 1, 7))
        fs = retrace.run()
        assert any(f.rule == "RTR002" for f in fs)

    def test_live_engines_are_clean(self):
        assert retrace.run() == []

    def test_step_width_contract(self):
        from repro.serve.engine import declared_step_widths, step_width
        assert step_width(["prefill", "decode", "free"], 16) == 16
        assert step_width(["decode", "decode"], 16) == 1
        assert step_width([], 16) == 1
        assert declared_step_widths(16) == (16, 1)
        assert declared_step_widths(1) == (1,)

    def test_vision_step_batch_contract(self):
        from repro.vision.engine import declared_step_batches, step_batch
        assert all(step_batch(n, 8) == 8 for n in range(9))
        assert declared_step_batches(8) == (8,)


# ---------------------------------------------------------------------------
# qt invariants
# ---------------------------------------------------------------------------


class TestQtInvariantFixtures:
    def test_positive_channel_axis_is_qti001(self):
        good = qt.quantize_weight(jnp.ones((8, 16)), fmt="int8")
        bad = dataclasses.replace(good, axis=1)
        fs = qt_invariants.check_tensor(bad, "fixture")
        assert "QTI001" in rules(fs)

    def test_non_keepdims_scale_is_qti002(self):
        good = qt.quantize_weight(jnp.ones((8, 16)), fmt="int8")
        bad = dataclasses.replace(good, scale=good.scale.reshape(-1))
        fs = qt_invariants.check_tensor(bad, "fixture")
        assert "QTI002" in rules(fs)

    def test_wrong_pack_axis_length_is_qti003(self):
        good = qt.quantize_weight(jnp.ones((8, 16)), fmt="int4")
        bad = dataclasses.replace(good, pack_size=9)
        fs = qt_invariants.check_tensor(bad, "fixture")
        assert "QTI003" in rules(fs)

    def test_ragged_act_scale_is_qti004(self):
        good = qt.quantize_weight(jnp.ones((4, 8, 16)),
                                  reduce_axes=(-2,), fmt="int8")
        bad = dataclasses.replace(
            good, act_scale=jnp.ones((4, 8, 1), jnp.float32))
        fs = qt_invariants.check_tensor(bad, "fixture")
        assert "QTI004" in rules(fs)

    def test_positive_axis_literal_in_source_is_qti006(self):
        src = "w = quantize_weight(x, axis=3)\n"
        fs = qt_invariants.check_source("fixture.py", ast.parse(src))
        assert rules(fs) == {"QTI006"}
        assert fs[0].line == 1

    def test_negative_axis_literal_is_clean(self):
        src = "w = quantize_weight(x, axis=-1)\n"
        assert qt_invariants.check_source("f.py", ast.parse(src)) == []

    def test_layout_errors_clean_on_all_formats(self):
        for fmt in ("int8", "int4", "fp8"):
            t = qt.quantize_weight(jnp.ones((33, 16)), fmt=fmt)
            assert t.layout_errors() == [], fmt


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------


def _lint(src: str, modname: str = "repro.kernels.fixture") -> list[Finding]:
    return lint.check_file("fixture.py", ast.parse(src), modname)


class TestLintFixtures:
    def test_ops_import_is_lnt001(self):
        for src in ("from repro.kernels import ops\n",
                    "import repro.kernels.ops\n",
                    "from repro.kernels.ops import gemm\n"):
            assert "LNT001" in rules(_lint(src)), src

    def test_tracer_branch_is_lnt002(self):
        src = (
            "def _k(a_ref, o_ref):\n"
            "    i = pl.program_id(0)\n"
            "    if i == 0:\n"
            "        o_ref[...] = a_ref[...]\n"
            "out = pl.pallas_call(_k, interpret=flag)(a)\n")
        assert "LNT002" in rules(_lint(src))

    def test_static_dtype_branch_is_clean(self):
        src = (
            "def _k(a_ref, o_ref):\n"
            "    if a_ref.dtype == jnp.int32:\n"
            "        o_ref[...] = a_ref[...]\n"
            "out = pl.pallas_call(_k, interpret=flag)(a)\n")
        assert "LNT002" not in rules(_lint(src))

    def test_host_np_in_kernel_is_lnt003(self):
        src = (
            "def _k(a_ref, o_ref):\n"
            "    o_ref[...] = np.zeros((8, 8))\n"
            "out = pl.pallas_call(_k, interpret=flag)(a)\n")
        assert "LNT003" in rules(_lint(src))

    def test_jit_in_kernel_is_lnt003(self):
        src = (
            "def _k(a_ref, o_ref):\n"
            "    o_ref[...] = jax.jit(lambda x: x)(a_ref[...])\n"
            "out = pl.pallas_call(_k, interpret=flag)(a)\n")
        assert "LNT003" in rules(_lint(src))

    def test_missing_vjp_marker_is_lnt004(self):
        @registry.register("_lint_fixture_kind")
        def impl():                                    # pragma: no cover
            pass
        try:
            fs = lint._lnt004_vjp_markers()
            assert any(f.rule == "LNT004"
                       and f.subject == "_lint_fixture_kind" for f in fs)
        finally:
            registry._REGISTRY.pop("_lint_fixture_kind")
            registry._META.pop("_lint_fixture_kind")

    def test_interpret_literal_is_lnt005(self):
        src = "out = pl.pallas_call(_k, interpret=True)(a)\n"
        fs = _lint(src)
        assert "LNT005" in rules(fs)

    def test_raw_einsum_in_models_is_lnt006(self):
        src = "y = jnp.einsum('mk,kn->mn', a, b)\n"
        assert "LNT006" in rules(_lint(src, "repro.models.layers"))
        assert "LNT006" in rules(_lint(src, "repro.vision.blocks"))
        # dispatch itself legitimately calls jnp.einsum
        assert "LNT006" not in rules(_lint(src, "repro.axon.dispatch"))

    def test_kernel_import_outside_axon_is_lnt007(self):
        src = "from repro.kernels.axon_gemm import axon_gemm\n"
        assert "LNT007" in rules(_lint(src, "repro.models.layers"))
        assert "LNT007" not in rules(_lint(src, "repro.axon.dispatch"))
        # the attention kernel is wired into models by design: unrestricted
        ok = "from repro.kernels.flash_attention import f\n"
        assert "LNT007" not in rules(_lint(ok, "repro.models.layers"))

    def test_pallas_call_without_interpret_is_lnt008(self):
        src = "out = pl.pallas_call(_k, grid=(1,))(a)\n"
        assert "LNT008" in rules(_lint(src))

    def test_host_clock_in_kernel_body_is_lnt009(self):
        src = (
            "import time\n"
            "def _k(a_ref, o_ref):\n"
            "    t = time.perf_counter()\n"
            "    o_ref[...] = a_ref[...]\n"
            "out = pl.pallas_call(_k, grid=(1,), interpret=flag)(a)\n"
        )
        assert "LNT009" in rules(_lint(src))

    def test_host_clock_in_jitted_step_is_lnt009(self):
        src = (
            "from time import perf_counter\n"
            "import jax\n"
            "def step(params, tokens):\n"
            "    t0 = perf_counter()\n"
            "    return tokens\n"
            "step = jax.jit(step)\n"
        )
        assert "LNT009" in rules(_lint(src, "repro.serve.fixture"))

    def test_obs_call_in_step_factory_is_lnt009(self):
        src = (
            "from repro.obs import metrics\n"
            "def make_chunk_step(cfg):\n"
            "    def step(params, tokens):\n"
            "        metrics.counter('steps').inc()\n"
            "        return tokens\n"
            "    return step\n"
        )
        assert "LNT009" in rules(_lint(src, "repro.serve.fixture"))

    def test_host_clock_in_host_loop_is_not_lnt009(self):
        # the engines' generate()/infer() loops time on the host by
        # design; only traced bodies are off-limits
        src = (
            "import time\n"
            "import repro.obs as obs\n"
            "def generate(reqs):\n"
            "    t0 = time.perf_counter()\n"
            "    obs.optrace.add_span('x', t0, 0.0)\n"
            "    return []\n"
        )
        assert "LNT009" not in rules(_lint(src, "repro.serve.fixture"))

    def test_annotate_scope_in_traced_step_is_not_lnt009(self):
        # the annotate API exists to be called under the tracer
        src = (
            "from repro.obs import annotate as _ann\n"
            "import jax\n"
            "def make_chunk_step(cfg):\n"
            "    def step(params, tokens):\n"
            "        with _ann.scope('attention'):\n"
            "            return tokens\n"
            "    return step\n"
        )
        assert "LNT009" not in rules(_lint(src, "repro.serve.fixture"))

    def test_fstring_annotate_label_is_lnt010(self):
        src = (
            "from repro.obs import annotate as _ann\n"
            "def fwd(x, layer):\n"
            "    with _ann.scope(f'layer_{layer}'):\n"
            "        return x\n"
        )
        assert "LNT010" in rules(_lint(src, "repro.models.fixture"))

    def test_format_annotate_label_is_lnt010(self):
        src = (
            "from repro.obs.annotate import host_scope\n"
            "def run(name):\n"
            "    with host_scope('req_{}'.format(name)):\n"
            "        return None\n"
        )
        assert "LNT010" in rules(_lint(src, "repro.serve.fixture"))

    def test_static_and_concat_annotate_labels_are_clean(self):
        # constants, names, and bounded "+" concatenation are all fine
        src = (
            "from repro.obs import annotate as _ann\n"
            "def dispatch(kind, fn):\n"
            "    with _ann.scope('axon:' + kind):\n"
            "        return fn()\n"
            "def fwd(x):\n"
            "    with _ann.scope('attention'):\n"
            "        return x\n"
        )
        assert "LNT010" not in rules(_lint(src, "repro.axon.fixture"))

    def test_fstring_named_scope_in_traced_def_is_lnt010(self):
        src = (
            "import jax\n"
            "def make_chunk_step(cfg):\n"
            "    def step(params, i):\n"
            "        with jax.named_scope(f'step_{i}'):\n"
            "            return params\n"
            "    return step\n"
        )
        assert "LNT010" in rules(_lint(src, "repro.serve.fixture"))

    def test_fstring_named_scope_on_host_is_not_lnt010(self):
        # host-side code may interpolate (e.g. dryrun's per-cell optrace
        # spans) -- only traced bodies and the annotate API are bounded
        src = (
            "import jax\n"
            "from repro.obs import optrace\n"
            "def lower_cell(tag):\n"
            "    with jax.named_scope(f'lower_cell:{tag}'):\n"
            "        return None\n"
            "def span_cell(tag):\n"
            "    with optrace.span(f'lower_cell:{tag}'):\n"
            "        return None\n"
        )
        assert "LNT010" not in rules(_lint(src, "repro.launch.fixture"))


# ---------------------------------------------------------------------------
# meta: the live repo is clean, end to end
# ---------------------------------------------------------------------------


class TestLiveRepoClean:
    def test_run_all_no_findings(self):
        findings, counts, elapsed = run_all()
        assert [f.render() for f in findings] == []
        assert set(counts) == {"contracts", "retrace", "qt_invariants",
                               "lint", "pagetable"}
        assert not has_errors(findings)
        # render paths stay exercised even when clean
        assert "findings" in render_json(findings, counts, elapsed)

    def test_registry_metadata_complete(self):
        for kind in registry.kinds():
            meta = registry.meta(kind)
            assert meta.vjp is not None, kind
            assert meta.accum in registry.ACCUM_CONTRACTS, kind


# ---------------------------------------------------------------------------
# pinned regressions for what the analyzer flagged on the seed
# ---------------------------------------------------------------------------


class TestAccumDtypePolicyRegression:
    """Every pallas-backed impl must refuse a non-f32 policy accum dtype
    (the quant/conv paths silently ignored it before the analyzer)."""

    BAD = ExecutionPolicy(backend="pallas", force_interpret=True,
                          accum_dtype=jnp.bfloat16)

    def _expect_raise(self, fn, *args):
        with pytest.raises(NotImplementedError, match="accumulate"):
            jax.make_jaxpr(fn)(*args)

    def test_quant_gemm_checks_policy(self):
        self._expect_raise(
            lambda a, b, s: registry.get("quant_gemm")(
                a, b, s, self.BAD, jnp.float32),
            _i8(64, 64), _i8(64, 64), _f32(64))

    def test_int4_gemm_checks_policy(self):
        self._expect_raise(
            lambda a, b, s: registry.get("int4_gemm")(
                a, b, s, 64, self.BAD, jnp.float32),
            _f32(64, 64), _i8(32, 64), _f32(64))

    def test_fp8_gemm_checks_policy(self):
        self._expect_raise(
            lambda a, b, s: registry.get("fp8_gemm")(
                a, b, s, self.BAD, jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((64, 64), jnp.float8_e4m3fn), _f32(64))

    def test_quant_conv2d_checks_policy(self):
        self._expect_raise(
            lambda x, w, s: registry.get("quant_conv2d")(
                x, w, s, self.BAD, (1, 1), ((1, 1), (1, 1)), jnp.float32),
            _i8(1, 8, 8, 16), _i8(3, 3, 16, 16), _f32(16))

    def test_conv2d_checks_policy(self):
        self._expect_raise(
            lambda x, w: registry.get("conv2d")(
                x, w, self.BAD, (1, 1), ((1, 1), (1, 1)), 1, jnp.float32),
            _f32(1, 8, 8, 16), _f32(3, 3, 16, 16))

    def test_dwconv_checks_policy(self):
        self._expect_raise(
            lambda x, w: registry.get("dwconv")(
                x, w, self.BAD, (1, 1), ((1, 1), (1, 1)), jnp.float32),
            _f32(1, 8, 8, 16), _f32(3, 3, 16))


class TestStreamingOrderRegression:
    """WS/IS used to accumulate into a revisited output block with the K
    grid dim mid-grid -- non-consecutive revisits lose partial sums on real
    TPU.  Pin both the numerics (multi-K-slab grids) and the structural
    fix (per-slab partial planes: AXC004-clean)."""

    @pytest.mark.parametrize("order", [Dataflow.WS, Dataflow.IS])
    def test_multi_k_slab_numerics(self, order):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((96, 200)).astype(np.float32)
        b = rng.standard_normal((200, 80)).astype(np.float32)
        out = np.asarray(axon_gemm(
            jnp.asarray(a), jnp.asarray(b), block=(64, 64, 64),
            order=order, interpret=True))
        np.testing.assert_allclose(out, a @ b, rtol=2e-5, atol=2e-4)

    @pytest.mark.parametrize("order", [Dataflow.WS, Dataflow.IS])
    def test_streaming_orders_are_revisit_clean(self, order):
        def fn(a, b):
            return axon_gemm(a, b, block=(64, 64, 64), order=order,
                             interpret=True)
        eqn = _pallas_eqn(fn, _f32(192, 192), _f32(192, 192))
        fs = contracts.check_pallas_eqn(eqn, "gemm", f"ws-is-{order}")
        assert "AXC004" not in rules(fs)
        assert not [f for f in fs if f.severity == "ERROR"]


class TestOpsModuleDeprecation:
    def test_importing_ops_warns(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            import repro.kernels.ops as ops_mod
        with pytest.warns(DeprecationWarning, match="repro.axon"):
            importlib.reload(ops_mod)

    def test_no_in_repo_module_imports_ops(self):
        fs = [f for f in lint.run() if f.rule == "LNT001"]
        assert fs == []
