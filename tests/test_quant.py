"""``repro.quant``: differential validation of the int8 path.

The load-bearing property: everything the int8 kernels compute is pinned
against the *dequantize-then-float* reference -- the same QuantizedTensor
run through the float dispatch -- within a scale-derived tolerance (the
int32 accumulation is exact, so the two paths differ only by float-32
summation rounding).  On top of that: quantize/dequantize round-trip
bounds, calibration round trips, dispatch fallback routing (batched specs,
missing act scales, XLA backend, per-call overrides), and the acceptance
end-to-end -- quantized reduced ResNet50 top-1 agreement with the float
model, plus the engines' quantize-once-serve-many modes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import axon, quant
from repro.configs import get_config, get_vision_config
from repro.kernels.quant_gemm import quant_gemm, quant_im2col_conv, wq_gemv
from repro.kernels.ref import conv2d_ref
from repro.serve.engine import Request, ServeEngine, make_chunk_step
from repro.models import transformer as T
from repro.vision import models
from repro.vision.engine import ImageRequest, VisionEngine

KEY = jax.random.PRNGKey(0)


def _rand(shape, seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


def _with_act_scale(qt: quant.QuantizedTensor, x) -> quant.QuantizedTensor:
    amax = float(jnp.abs(x).max())
    return dataclasses.replace(
        qt, act_scale=jnp.full((1,) * qt.ndim, max(amax, 1e-12) / 127.0,
                               jnp.float32))


def _qtol(qt: quant.QuantizedTensor, K: int, act_scale=None) -> dict:
    """Scale-derived tolerance: both paths sum K products of magnitude
    <= 127^2 * s; only f32 rounding separates them."""
    s_w = float(jnp.max(qt.scale))
    s_a = float(act_scale) if act_scale is not None else 1.0
    return dict(rtol=1e-4, atol=max(127.0 * 127.0 * s_w * s_a * K * 1e-6,
                                    1e-6))


# ---------------------------------------------------------------------------
# quantize / dequantize properties
# ---------------------------------------------------------------------------


class TestQuantizeDequantize:
    def test_round_trip_bound(self):
        w = _rand((32, 24), 0, scale=3.0)
        qt = quant.quantize_weight(w)
        err = jnp.abs(quant.dequantize(qt) - w)
        # symmetric rounding: per-element error <= scale/2 per channel
        assert bool(jnp.all(err <= qt.scale * 0.5 + 1e-7))

    def test_layout(self):
        qt = quant.quantize_weight(_rand((3, 3, 6, 8), 1))
        assert qt.q.dtype == jnp.int8
        assert qt.scale.shape == (1, 1, 1, 8)
        assert qt.axis == -1 and qt.shape == (3, 3, 6, 8)
        assert qt.dtype == jnp.float32
        assert int(jnp.max(jnp.abs(qt.q.astype(jnp.int32)))) <= 127

    def test_zero_channel_is_safe(self):
        w = _rand((16, 4), 2).at[:, 1].set(0.0)
        qt = quant.quantize_weight(w)
        deq = quant.dequantize(qt)
        assert bool(jnp.all(jnp.isfinite(deq)))
        np.testing.assert_array_equal(np.asarray(deq[:, 1]), 0.0)

    def test_stacked_matches_per_layer(self):
        """reduce_axes=(-2,) on (L, d, e) == quantizing each layer alone."""
        w = _rand((3, 16, 8), 3, scale=2.0)
        stacked = quant.quantize_weight(w, reduce_axes=(-2,))
        for l in range(3):
            single = quant.quantize_weight(w[l])
            np.testing.assert_array_equal(np.asarray(stacked.q[l]),
                                          np.asarray(single.q))
            np.testing.assert_allclose(np.asarray(stacked.scale[l]),
                                       np.asarray(single.scale))

    def test_reduce_axes_cannot_cover_channel_axis(self):
        with pytest.raises(ValueError):
            quant.quantize_weight(_rand((4, 4), 4), axis=-1,
                                  reduce_axes=(-1,))

    def test_activation_quantization_clips(self):
        s = jnp.asarray(0.1, jnp.float32)
        x = jnp.asarray([-100.0, -0.05, 0.0, 0.05, 100.0])
        q = quant.quantize_activation(x, s)
        assert q.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(q), [-127, 0, 0, 0, 127])

    def test_is_quantized(self):
        p = {"a": {"w": quant.quantize_weight(_rand((4, 4), 5)),
                   "b": jnp.zeros(4)}}
        assert quant.is_quantized(p)
        assert not quant.is_quantized({"w": jnp.ones((4, 4))})

    @given(m=st.integers(1, 32), n=st.integers(1, 32),
           seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_round_trip_fuzz(self, m, n, seed):
        w = _rand((m, n), seed, scale=5.0)
        qt = quant.quantize_weight(w)
        err = jnp.abs(quant.dequantize(qt) - w)
        assert bool(jnp.all(err <= qt.scale * 0.5 + 1e-6))


# ---------------------------------------------------------------------------
# kernels, direct (interpret mode)
# ---------------------------------------------------------------------------


class TestQuantKernels:
    def test_quant_gemm_matches_integer_reference(self):
        M, K, N = 17, 33, 29
        a = _rand((M, K), 0)
        qt = quant.quantize_weight(_rand((K, N), 1))
        s_a = float(jnp.abs(a).max()) / 127.0
        aq = quant.quantize_activation(a, jnp.asarray(s_a))
        scale = qt.scale.reshape(-1) * s_a
        got = quant_gemm(aq, qt.q, scale, block=(8, 16, 16),
                         interpret=True)
        want = (aq.astype(jnp.int32) @ qt.q.astype(jnp.int32)
                ).astype(jnp.float32) * scale[None, :]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, K, s_a))

    def test_quant_gemm_weight_only(self):
        M, K, N = 12, 40, 24
        a = _rand((M, K), 2)
        qt = quant.quantize_weight(_rand((K, N), 3))
        got = quant_gemm(a, qt.q, qt.scale.reshape(-1), block=(8, 16, 16),
                         interpret=True)
        want = a @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_wq_gemv(self):
        K, N = 96, 130
        x = _rand((2, K), 4)
        qt = quant.quantize_weight(_rand((K, N), 5))
        got = wq_gemv(x, qt.q, qt.scale.reshape(-1), block_k=32, block_n=64,
                      interpret=True)
        want = x @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_quant_conv_matches_dequant_reference(self):
        x = _rand((2, 9, 11, 6), 6)
        qt = quant.quantize_weight(_rand((3, 3, 6, 8), 7))
        s_a = float(jnp.abs(x).max()) / 127.0
        xq = quant.quantize_activation(x, jnp.asarray(s_a))
        scale = qt.scale.reshape(-1) * s_a
        got = quant_im2col_conv(xq, qt.q, scale, stride=2, padding=1,
                                block_rows=4, block_cout=8, block_cin=4,
                                interpret=True)
        x_dq = xq.astype(jnp.float32) * s_a
        want = conv2d_ref(x_dq, quant.dequantize(qt), stride=2, padding=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, 3 * 3 * 6, s_a))

    @given(m=st.integers(1, 40), k=st.integers(1, 48), n=st.integers(1, 40),
           seed=st.integers(0, 100))
    @settings(max_examples=12, deadline=None)
    def test_quant_gemm_fuzz(self, m, k, n, seed):
        a = _rand((m, k), seed, scale=2.0)
        qt = quant.quantize_weight(_rand((k, n), seed + 1, scale=3.0))
        s_a = max(float(jnp.abs(a).max()), 1e-9) / 127.0
        aq = quant.quantize_activation(a, jnp.asarray(s_a))
        scale = qt.scale.reshape(-1) * s_a
        got = quant_gemm(aq, qt.q, scale, block=(16, 16, 16), interpret=True)
        want = (aq.astype(jnp.int32) @ qt.q.astype(jnp.int32)
                ).astype(jnp.float32) * scale[None, :]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, k, s_a))

    @given(h=st.integers(3, 12), w=st.integers(3, 12),
           cin=st.integers(1, 8), cout=st.integers(1, 10),
           kk=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
           pad=st.sampled_from([0, 1]), seed=st.integers(0, 100))
    @settings(max_examples=12, deadline=None)
    def test_quant_conv_fuzz(self, h, w, cin, cout, kk, stride, pad, seed):
        if (h + 2 * pad - kk) < 0 or (w + 2 * pad - kk) < 0:
            return
        x = _rand((1, h, w, cin), seed)
        qt = quant.quantize_weight(_rand((kk, kk, cin, cout), seed + 1))
        s_a = max(float(jnp.abs(x).max()), 1e-9) / 127.0
        xq = quant.quantize_activation(x, jnp.asarray(s_a))
        scale = qt.scale.reshape(-1) * s_a
        got = quant_im2col_conv(xq, qt.q, scale, stride=stride, padding=pad,
                                block_rows=4, block_cout=8, block_cin=4,
                                interpret=True)
        x_dq = xq.astype(jnp.float32) * s_a
        want = conv2d_ref(x_dq, quant.dequantize(qt), stride=stride,
                          padding=pad)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, kk * kk * cin, s_a))


# ---------------------------------------------------------------------------
# dispatch routing
# ---------------------------------------------------------------------------


class TestQuantDispatch:
    def _ref(self, spec, a, qt):
        return jnp.einsum(spec, a, quant.dequantize(qt))

    def test_weight_only_einsum(self):
        a = _rand((16, 32), 0)
        qt = quant.quantize_weight(_rand((32, 24), 1))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(self._ref("mk,kn->mn", a, qt)),
                                   rtol=1e-4, atol=1e-5)

    def test_full_int8_einsum(self):
        a = _rand((16, 32), 2)
        qt = _with_act_scale(quant.quantize_weight(_rand((32, 24), 3)), a)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        s_a = float(qt.act_scale.reshape(()))
        a_dq = quant.quantize_activation(a, qt.act_scale.reshape(())
                                         ).astype(jnp.float32) * s_a
        want = a_dq @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, 32, s_a))

    def test_gemv_shape_rides_weight_only_kernel(self):
        a = _rand((2, 64), 4)
        qt = quant.quantize_weight(_rand((64, 48), 5))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(self._ref("mk,kn->mn", a, qt)),
                                   rtol=1e-4, atol=1e-5)

    def test_model_spec_folds_batch(self):
        a = _rand((2, 5, 32), 6)
        qt = quant.quantize_weight(_rand((32, 24), 7))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("bsd,de->bse", a, qt)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(self._ref("bsd,de->bse", a, qt)),
            rtol=1e-4, atol=1e-5)

    def test_xla_backend_is_exact_dequant(self):
        a = _rand((8, 16), 8)
        qt = _with_act_scale(quant.quantize_weight(_rand((16, 12), 9)), a)
        with axon.policy(backend="xla", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(self._ref("mk,kn->mn", a, qt)))

    def test_float_precision_dequantizes(self):
        a = _rand((8, 16), 10)
        qt = quant.quantize_weight(_rand((16, 12), 11))
        with axon.policy(backend="xla"):          # default precision="float"
            got = axon.einsum("mk,kn->mn", a, qt)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(self._ref("mk,kn->mn", a, qt)))

    def test_per_call_override(self):
        a = _rand((8, 16), 12)
        qt = quant.quantize_weight(_rand((16, 12), 13))
        with axon.policy(backend="xla"):
            base = axon.einsum("mk,kn->mn", a, qt)
        with axon.policy(backend="pallas"):       # precision float ...
            got = axon.einsum("mk,kn->mn", a, qt, quantized=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-4, atol=1e-5)

    def test_shared_batch_spec_falls_back(self):
        """MoE-style shared-batch contraction: dequant reference path."""
        a = _rand((3, 4, 16), 14)
        qt = quant.quantize_weight(_rand((3, 16, 8), 15),
                                   reduce_axes=(-2,))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("ecd,edf->ecf", a, qt)
        want = jnp.einsum("ecd,edf->ecf", a, quant.dequantize(qt))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_three_operand_spec_dequantizes(self):
        a = _rand((4, 8), 30)
        qt = quant.quantize_weight(_rand((8, 6), 31))
        c = _rand((6, 5), 32)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn,np->mp", a, qt, c)
        want = jnp.einsum("mk,kn,np->mp", a, quant.dequantize(qt), c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_weight_on_lhs_falls_back(self):
        qt = quant.quantize_weight(_rand((24, 32), 16))
        b = _rand((24, 8), 17)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("nk,nm->km", qt, b)
        want = jnp.einsum("nk,nm->km", quant.dequantize(qt), b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_scale_on_contraction_axis_falls_back(self):
        """Per-channel scale on K cannot fold into a column epilogue."""
        qt = quant.quantize_weight(_rand((16, 12), 18), axis=0)
        assert qt.scale.shape == (16, 1)
        a = _rand((8, 16), 19)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(self._ref("mk,kn->mn", a, qt)),
            rtol=1e-4, atol=1e-5)

    def test_matmul_front_door(self):
        a = _rand((4, 6, 32), 20)
        qt = quant.quantize_weight(_rand((32, 16), 21))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.matmul(a, qt)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(a @ quant.dequantize(qt)),
            rtol=1e-4, atol=1e-5)

    def test_conv2d_int8(self):
        x = _rand((2, 8, 8, 6), 22)
        qt = _with_act_scale(quant.quantize_weight(_rand((3, 3, 6, 8), 23)),
                             x)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.conv2d(x, qt, stride=1, padding="SAME")
        s_a = float(qt.act_scale.reshape(()))
        x_dq = quant.quantize_activation(x, qt.act_scale.reshape(())
                                         ).astype(jnp.float32) * s_a
        want = conv2d_ref(x_dq, quant.dequantize(qt), stride=1,
                          padding=((1, 1), (1, 1)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, 3 * 3 * 6, s_a))

    def test_conv2d_without_act_scale_falls_back(self):
        x = _rand((1, 6, 6, 4), 24)
        qt = quant.quantize_weight(_rand((3, 3, 4, 8), 25))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.conv2d(x, qt, padding=1)
        with axon.policy(backend="pallas"):
            want = axon.conv2d(x, quant.dequantize(qt), padding=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_grouped_conv_falls_back(self):
        x = _rand((1, 6, 6, 8), 26)
        qt = _with_act_scale(quant.quantize_weight(_rand((3, 3, 4, 8), 27)),
                             x)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.conv2d(x, qt, padding=1, groups=2)
        want = conv2d_ref(x, quant.dequantize(qt), padding=1, groups=2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_depthwise_dequantizes(self):
        x = _rand((1, 6, 6, 4), 28)
        qt = quant.quantize_weight(_rand((3, 3, 4), 29))
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.depthwise_conv2d(x, qt, padding=1)
        with axon.policy(backend="pallas"):
            want = axon.depthwise_conv2d(x, quant.dequantize(qt), padding=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_bad_precision_rejected(self):
        with pytest.raises(ValueError):
            axon.ExecutionPolicy(precision="int4")

    @given(m=st.integers(1, 24), k=st.integers(1, 40), n=st.integers(1, 32),
           act=st.booleans(), seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_einsum_dispatch_fuzz(self, m, k, n, act, seed):
        """Fuzzed int8 dispatch vs the dequantized float reference."""
        a = _rand((m, k), seed, scale=2.0)
        qt = quant.quantize_weight(_rand((k, n), seed + 1, scale=3.0))
        if act:
            qt = _with_act_scale(qt, a)
        with axon.policy(backend="pallas", precision="int8"):
            got = axon.einsum("mk,kn->mn", a, qt)
        if act:
            s_a = float(qt.act_scale.reshape(()))
            a_ref = quant.quantize_activation(
                a, qt.act_scale.reshape(())).astype(jnp.float32) * s_a
        else:
            s_a = None
            a_ref = a
        want = a_ref @ quant.dequantize(qt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **_qtol(qt, k, s_a))


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------


class TestCalibration:
    def test_minmax_observer(self):
        obs = quant.MinMaxObserver()
        obs.observe(np.asarray([1.0, -3.0]))
        obs.observe(np.asarray([2.0]))
        np.testing.assert_allclose(float(obs.scale()), 3.0 / 127.0)

    def test_percentile_le_minmax(self):
        x = np.concatenate([np.ones(999), [100.0]])
        mm, pc = quant.MinMaxObserver(), quant.PercentileObserver(99.0)
        mm.observe(x)
        pc.observe(x)
        assert float(pc.scale()) < float(mm.scale())

    def test_bad_observer_rejected(self):
        with pytest.raises(ValueError):
            quant.Calibration("median")
        with pytest.raises(ValueError):
            quant.PercentileObserver(0.0)

    def test_quantize_model_round_trip(self):
        params = {"c": {"w": _rand((3, 3, 4, 8), 0)},
                  "d": {"w": _rand((8, 5), 1)}}

        def apply_fn(p, x):
            h = axon.conv2d(x, p["c"]["w"], padding=1)
            h = h.mean(axis=(1, 2))
            return axon.einsum("nd,df->nf", h, p["d"]["w"])

        batches = [_rand((2, 6, 6, 4), s) for s in (2, 3)]
        qp = quant.quantize_model(params, apply_fn, batches,
                                  observer="minmax")
        for leaf in (qp["c"]["w"], qp["d"]["w"]):
            assert isinstance(leaf, quant.QuantizedTensor)
            assert leaf.act_scale is not None
            assert float(leaf.act_scale.reshape(())) > 0
        # minmax scale of the conv input == max |batch| / 127 exactly
        amax = max(float(jnp.abs(b).max()) for b in batches)
        np.testing.assert_allclose(
            float(qp["c"]["w"].act_scale.reshape(())), amax / 127.0,
            rtol=1e-6)

    def test_quantize_model_requires_eager_axon_calls(self):
        params = {"d": {"w": _rand((8, 5), 4)}}

        def jitted_apply(p, x):     # traced: observers see only tracers
            return jax.jit(lambda p, x: axon.einsum(
                "nd,df->nf", x, p["d"]["w"]))(p, x)

        with pytest.raises(ValueError, match="no quantized call sites"):
            quant.quantize_model(params, jitted_apply, [_rand((2, 8), 5)])

    def test_lm_walk_targets_projections_only(self):
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        qp = quant.quantize_lm_weights(params)
        assert quant.is_quantized(qp)
        assert not isinstance(qp["embed"], quant.QuantizedTensor)
        leaves = jax.tree.leaves(
            qp, is_leaf=lambda x: isinstance(x, quant.QuantizedTensor))
        n_q = sum(isinstance(l, quant.QuantizedTensor) for l in leaves)
        assert n_q > 0


# ---------------------------------------------------------------------------
# end-to-end: quantized models and engines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def resnet_ptq():
    cfg = get_vision_config("resnet50", reduced=True)
    params = models.init(KEY, cfg)
    calib = _rand((4, *cfg.input_hw, cfg.in_channels), 100)
    qparams = quant.quantize_model(
        params, lambda p, b: models.apply(p, b, cfg), [calib])
    return cfg, params, qparams


class TestQuantizedResNet:
    def test_top1_agreement(self, resnet_ptq):
        """Acceptance: quantized reduced ResNet50 agrees with float top-1
        on a fixed random eval batch, through the int8 Pallas kernels."""
        cfg, params, qparams = resnet_ptq
        x = _rand((8, *cfg.input_hw, cfg.in_channels), 200)
        logits_f = models.apply(params, x, cfg)
        with axon.policy(backend="pallas", precision="int8"):
            logits_q = jax.jit(
                lambda p, b: models.apply(p, b, cfg))(qparams, x)
        rel = float(jnp.linalg.norm(logits_q - logits_f)
                    / jnp.linalg.norm(logits_f))
        agree = int((logits_q.argmax(-1) == logits_f.argmax(-1)).sum())
        assert rel < 0.15, rel
        assert agree >= 6, (agree, rel)

    def test_every_conv_and_dense_calibrated(self, resnet_ptq):
        _, _, qparams = resnet_ptq
        qleaves = [l for l in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, quant.QuantizedTensor))
            if isinstance(l, quant.QuantizedTensor)]
        assert qleaves and all(l.act_scale is not None for l in qleaves)


class TestQuantizedEngines:
    def test_vision_engine_serves_quantized(self, resnet_ptq):
        cfg, params, qparams = resnet_ptq
        reqs = [ImageRequest(image=np.asarray(
            _rand((*cfg.input_hw, cfg.in_channels), 300 + i)))
            for i in range(3)]
        with axon.policy(backend="pallas"):
            # no explicit policy: quantized params auto-select int8
            eng_q = VisionEngine(qparams, cfg, batch_slots=2)
        assert eng_q.policy.precision == "int8"
        out_q = eng_q.infer(reqs)
        eng_f = VisionEngine(params, cfg, batch_slots=2,
                             policy=axon.ExecutionPolicy(backend="pallas"))
        out_f = eng_f.infer(reqs)
        # an explicitly pinned float policy on the SAME qparams is the
        # dequantized reference path, not int8
        eng_ref = VisionEngine(qparams, cfg, batch_slots=2,
                               policy=axon.ExecutionPolicy(
                                   backend="pallas", precision="float"))
        assert eng_ref.policy.precision == "float"
        assert eng_q.last_stats["images"] == 3
        for q, f in zip(out_q, out_f):
            assert q.shape == f.shape
            assert np.argmax(q) == np.argmax(f)

    def test_serve_engine_weight_only(self):
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        reqs = [Request(prompt=[5, 6, 7], max_new_tokens=4, eos_id=1),
                Request(prompt=[9, 3], max_new_tokens=3, eos_id=1)]
        eng_f = ServeEngine(params, cfg, batch_slots=2, max_len=32)
        out_f = eng_f.generate(reqs)
        eng_q = ServeEngine(params, cfg, batch_slots=2, max_len=32,
                            quantized=True)
        assert quant.is_quantized(eng_q.params)
        out_q = eng_q.generate(reqs)
        assert [len(o) for o in out_q] == [len(o) for o in out_f]
        assert eng_q.last_stats["generated_tokens"] == sum(
            len(o) for o in out_q)

    def test_weight_only_decode_logits_close(self):
        """One chunk step through the int8 GEMV path vs the float step."""
        cfg = get_config("yi-9b", reduced=True)
        params = T.init_params(KEY, cfg)
        qparams = quant.quantize_lm_weights(params)
        caches = T.init_caches(cfg, batch=2, max_len=16, dtype=jnp.float32)
        toks = jnp.asarray([[5, 6, 7, 8], [9, 3, 2, 4]], jnp.int32)
        valid = jnp.ones((2, 4), bool)
        rng = jax.random.PRNGKey(1)
        step_f = jax.jit(make_chunk_step(cfg))
        tok_f, _ = step_f(params, caches, toks, valid, rng)
        step_q = jax.jit(make_chunk_step(
            cfg, policy=axon.ExecutionPolicy(backend="pallas",
                                             precision="int8")))
        tok_q, _ = step_q(qparams, caches, toks, valid, rng)
        np.testing.assert_array_equal(np.asarray(tok_q), np.asarray(tok_f))
