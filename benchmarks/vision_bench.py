"""Batched vision inference benchmark: Pallas im2col vs XLA backends.

Drives ResNet50 and YOLOv3-tiny through ``repro.vision.VisionEngine`` under
both execution backends on the same mixed-arrival image workload, and pairs
the measured throughput/latency with the analytic Axon-vs-conventional
comparison traced from the SAME executable models (``vision.trace``), so
the paper's modeled claims and the runnable engine share one artifact:

  BENCH_vision.json = {
    "<model>": {
      "pallas": {"img_per_s", "p99_latency_s", ...},
      "xla":    {...},
      "modeled": {"throughput_speedup", "energy_ratio",
                  "traffic_reduction", "kernel_hbm_cut"},
    }, ...}

``--smoke`` uses the reduced configs (CPU CI: kernels interpret-mode, small
inputs); the modeled section always comes from the FULL config since
tracing runs no compute.

Usage:
  PYTHONPATH=src python benchmarks/vision_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro import axon
from repro.configs import get_vision_config
from repro.kernels.im2col_conv import hbm_traffic_model
from repro.vision import models, trace
from repro.vision.engine import ImageRequest, VisionEngine

BENCH_MODELS = ("resnet50", "yolov3-tiny")


def build_workload(cfg, *, n_images: int, batch_arrival_s: float,
                   seed: int = 0) -> list[ImageRequest]:
    """Images arriving in bursts of 3 every ``batch_arrival_s`` seconds."""
    rng = np.random.default_rng(seed)
    return [
        ImageRequest(
            image=rng.normal(size=(*cfg.input_hw, cfg.in_channels))
            .astype(np.float32),
            arrival_s=batch_arrival_s * (i // 3))
        for i in range(n_images)
    ]


def run_backend(cfg, params, reqs, *, backend: str, slots: int) -> dict:
    eng = VisionEngine(params, cfg, batch_slots=slots,
                       policy=axon.ExecutionPolicy(backend=backend))
    eng.warmup()                       # compile outside the timed region
    eng.infer(reqs)
    st = eng.last_stats
    return {
        "img_per_s": round(st["img_per_s"], 2),
        "wall_s": round(st["wall_s"], 4),
        "steps": st["steps"],
        "p50_latency_s": round(st["p50_latency_s"], 4),
        "p99_latency_s": round(st["p99_latency_s"], 4),
        "mean_occupancy": round(st["mean_occupancy"], 3),
    }


def modeled_section(name: str) -> dict:
    """Paper-claim ratios traced from the FULL executable model."""
    full = get_vision_config(name)
    rep = trace.paper_report(full)
    # kernel-level HBM cut for the model's dominant 3x3 layer shape
    c3 = next((c for c in trace.conv_shapes(full) if c.n == 3), None)
    kern = hbm_traffic_model((1, c3.H, c3.W, c3.C_in),
                             (3, 3, c3.C_in, c3.C_out),
                             stride=c3.stride, padding=c3.padding) \
        if c3 else {"reduction": 0.0}
    return {
        "conv_layers": rep["conv_layers"],
        "macs": rep["macs"],
        "throughput_speedup": round(rep["throughput_speedup"], 4),
        "cycle_speedup": round(rep["cycle_speedup"], 4),
        "energy_ratio": round(rep["energy_ratio"], 4),
        "traffic_reduction": round(rep["traffic_bytes"]["reduction"], 4),
        "kernel_hbm_cut": round(kern["reduction"], 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + tiny workload for CPU CI")
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--out", default="benchmarks/results/BENCH_vision.json")
    args = ap.parse_args()

    result: dict = {"smoke": args.smoke, "slots": args.slots}
    for name in BENCH_MODELS:
        cfg = get_vision_config(name, reduced=args.smoke)
        params = models.init(jax.random.PRNGKey(0), cfg)
        n = min(args.images, 8) if args.smoke else args.images
        reqs = build_workload(cfg, n_images=n,
                              batch_arrival_s=0.002 if args.smoke else 0.01)
        entry = {"config": cfg.name, "images": n,
                 "input_hw": list(cfg.input_hw)}
        for backend in ("pallas", "xla"):
            entry[backend] = run_backend(cfg, params, reqs, backend=backend,
                                         slots=args.slots)
        entry["modeled"] = modeled_section(name)
        result[name] = entry
        print(f"{name}: pallas {entry['pallas']['img_per_s']} img/s "
              f"(p99 {entry['pallas']['p99_latency_s']}s) | "
              f"xla {entry['xla']['img_per_s']} img/s | modeled axon-vs-SA "
              f"energy {entry['modeled']['energy_ratio']}x, traffic cut "
              f"{entry['modeled']['traffic_reduction'] * 100:.1f}%")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
