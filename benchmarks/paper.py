"""One benchmark per paper table/figure.

Each ``bench_*`` returns a list of (name, us_per_call, derived) rows, where
``us_per_call`` times the model/kernel under test on this machine and
``derived`` is the paper-comparable number (speedup, reduction, ...).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cmsa_model import utilization_improvement_cmsa
from repro.core.dataflows import ALL_DATAFLOWS, Dataflow, GemmShape
from repro.core.energy_model import (
    DRAM_BANDWIDTH_BYTES,
    PAPER_ASIC,
    area_overhead_im2col,
    bounded_runtime_s,
    dram_energy_joules,
    power_overhead_im2col,
    zero_gating_power_reduction,
)
from repro.core.im2col_model import ConvShape, im2col_traffic, lower_to_gemm, model_traffic
from repro.core.runtime_model import (
    ArrayShape,
    fill_latency_axon,
    fill_latency_sa,
    runtime_scaleup,
)
from repro.core.utilization import utilization_improvement
from repro.core.workloads import GEMV, MOBILENET_DW, TABLE3, resnet50_convs, yolov3_convs


def _timeit(fn, n=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# -------------------------------------------------------------- Fig. 6
def bench_fig6_fill_latency():
    rows = []
    for r in (16, 64, 128, 256):
        arr = ArrayShape(r, r)
        us = _timeit(lambda: (fill_latency_sa(arr), fill_latency_axon(arr)))
        rows.append((f"fig6_fill_{r}x{r}_sa_vs_axon", us,
                     f"{fill_latency_sa(arr)}->{fill_latency_axon(arr)}"))
    return rows


# -------------------------------------------------------------- Fig. 12 / Table 3
def bench_fig12_runtime():
    rows = []
    for r in (64, 128, 256):
        arr = ArrayShape(r, r)
        speeds = []
        for name, shape in TABLE3.items():
            t_sa = runtime_scaleup(shape, arr, Dataflow.OS, axon=False,
                                   overlap_readout=True)
            t_ax = runtime_scaleup(shape, arr, Dataflow.OS, axon=True,
                                   overlap_readout=True)
            speeds.append(t_sa / t_ax)
        us = _timeit(lambda: [runtime_scaleup(s, arr, Dataflow.OS, axon=True)
                              for s in TABLE3.values()])
        rows.append((f"fig12_avg_speedup_{r}x{r}", us,
                     f"{np.mean(speeds):.3f}x (paper: 1.47x@64, 1.76x@256)"))
    # per-workload at 256 for the appendix table
    arr = ArrayShape(256, 256)
    for name, shape in TABLE3.items():
        t_sa = runtime_scaleup(shape, arr, Dataflow.OS, axon=False,
                               overlap_readout=True)
        t_ax = runtime_scaleup(shape, arr, Dataflow.OS, axon=True,
                               overlap_readout=True)
        rows.append((f"fig12_{name}_256", 0.0, f"{t_sa / t_ax:.3f}x"))
    return rows


# -------------------------------------------------------------- Fig. 13
def bench_fig13_utilization_cmsa():
    arr = ArrayShape(128, 128)
    ax, cm = [], []
    for shape in TABLE3.values():
        ax.append(utilization_improvement(shape, arr, axon=True))
        cm.append(utilization_improvement_cmsa(shape, arr))
    us = _timeit(lambda: [utilization_improvement(s, arr, axon=True)
                          for s in TABLE3.values()])
    return [
        ("fig13_axon_avg_UR_improvement", us, f"{np.mean(ax) * 100:.1f}%"),
        ("fig13_cmsa_avg_UR_improvement", 0.0, f"{np.mean(cm) * 100:.1f}%"),
        ("fig13_axon_over_cmsa", 0.0,
         f"{(np.mean(ax) - np.mean(cm)) * 100:.1f}pp (paper: ~27%)"),
    ]


# -------------------------------------------------------------- Fig. 14
def bench_fig14_gemv_dwconv():
    rows = []
    arr = ArrayShape(64, 64)
    speeds = []
    for name, shape in GEMV.items():
        df = Dataflow.IS  # T = M = 1: fill-dominated
        t_sa = runtime_scaleup(shape, arr, df, axon=False, overlap_readout=True)
        t_ax = runtime_scaleup(shape, arr, df, axon=True, overlap_readout=True)
        speeds.append(t_sa / t_ax)
        rows.append((f"fig14_{name}", 0.0, f"{t_sa / t_ax:.3f}x"))
    for conv in MOBILENET_DW[:4]:
        g = lower_to_gemm(ConvShape(conv.H, conv.W, 1, 1, conv.n,
                                    stride=conv.stride, padding=conv.padding))
        t_sa = runtime_scaleup(g, arr, Dataflow.IS, axon=False,
                               overlap_readout=True)
        t_ax = runtime_scaleup(g, arr, Dataflow.IS, axon=True,
                               overlap_readout=True)
        speeds.append(t_sa / t_ax)
        rows.append((f"fig14_dw_{conv.name}", 0.0, f"{t_sa / t_ax:.3f}x"))
    rows.append(("fig14_avg_speedup", 0.0,
                 f"{np.mean(speeds):.3f}x (paper: 1.8x avg, up to 2x)"))
    return rows


# -------------------------------------------------------------- Fig. 11 + §5.2.1
def bench_fig11_im2col_traffic():
    rows = []
    shapes = [ConvShape(56, 56, 64, 64, 3, 1, 1, "rn50_3x3_56"),
              ConvShape(28, 28, 128, 128, 3, 1, 1, "rn50_3x3_28"),
              ConvShape(208, 208, 64, 128, 3, 2, 1, "yolo_3x3_s2"),
              ConvShape(112, 112, 32, 32, 3, 1, 1, "mbnet_3x3_112")]
    for c in shapes:
        t = im2col_traffic(c, feeder_group=16)
        rows.append((f"fig11_{c.name}", 0.0,
                     f"{t.reduction * 100:.1f}% reduction"))
    for net, convs, paper in (("resnet50", resnet50_convs(), (261.2, 153.5)),
                              ("yolov3", yolov3_convs(), (2540.0, 1117.0))):
        us = _timeit(lambda: model_traffic(convs))
        sw, ax = model_traffic(convs)
        red = 1 - ax / sw
        paper_red = 1 - paper[1] / paper[0]
        rows.append((f"traffic_{net}", us,
                     f"{red * 100:.1f}% (paper {paper_red * 100:.1f}%)"))
        saved = sw - ax
        rows.append((f"energy_{net}_saved", 0.0,
                     f"{dram_energy_joules(saved) * 1e3:.2f} mJ"))
        # §5.2.1: ~1.25x speedup from reduced traffic at 6.4 GB/s.  Under OUR
        # batch-1 fp16 traffic both nets are compute-bound on the 256-PE
        # array, so the bounded model gives ~1x; with the paper's own (~5x
        # larger, accounting unstated) MB figures the same model lands in
        # the claimed regime -- report both (fidelity note, EXPERIMENTS.md).
        comp_cycles = int(sum(lower_to_gemm(c).macs / 256 for c in convs))
        t_sw = bounded_runtime_s(comp_cycles, sw)
        t_ax = bounded_runtime_s(comp_cycles, ax)
        p_sw = bounded_runtime_s(comp_cycles, paper[0] * 1e6)
        p_ax = bounded_runtime_s(comp_cycles, paper[1] * 1e6)
        rows.append((f"speedup_{net}_membound", 0.0,
                     f"ours {t_sw / t_ax:.2f}x; w/ paper-traffic "
                     f"{p_sw / p_ax:.2f}x (paper ~1.25x)"))
    return rows


# -------------------------------------------------------------- Fig. 10 / 15
def bench_fig10_15_asic():
    return [
        ("fig10_area_overhead_im2col", 0.0,
         f"{area_overhead_im2col() * 100:.3f}% (paper 0.2%)"),
        ("fig10_power_overhead_im2col", 0.0,
         f"{power_overhead_im2col() * 100:.3f}% (paper text 1.6%; its own "
         f"mW figures give 0.167%)"),
        ("fig10_peak_throughput", 0.0,
         f"{PAPER_ASIC.peak_flops / 1e9:.0f} GFLOP/s @550MHz FP16"),
        ("zero_gating_10pct_sparsity", 0.0,
         f"{zero_gating_power_reduction(0.10) * 100:.2f}% power (paper 5.3%)"),
        ("fig15_vs_sauria", 0.0,
         "axon 2:1-mux im2col vs SAURIA feeder: -3.93% area, -4.5% power "
         "(paper-reported deltas, encoded as calibration)"),
    ]


ALL_BENCHES = [
    bench_fig6_fill_latency,
    bench_fig12_runtime,
    bench_fig13_utilization_cmsa,
    bench_fig14_gemv_dwconv,
    bench_fig11_im2col_traffic,
    bench_fig10_15_asic,
]
