"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline/dry-run tables live in
``benchmarks.roofline`` (they read the dry-run JSON artifacts).  The static
analyzer's cost is tracked alongside the perf benches: ``bench_analysis``
times each pass and writes ``BENCH_analysis.json`` so a slow rule shows up
the same way a slow kernel does.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def bench_analysis(out_path: str | Path = "BENCH_analysis.json") -> dict:
    """Time the full analyzer suite; write wall-time + per-pass finding
    counts to ``out_path`` and return the document."""
    from repro.analysis import run_all

    findings, counts, elapsed = run_all()
    doc = {
        "wall_s": round(sum(elapsed.values()), 3),
        "per_pass_seconds": {k: round(v, 3) for k, v in elapsed.items()},
        "per_pass_findings": counts,
        "errors": sum(f.severity == "ERROR" for f in findings),
        "warnings": sum(f.severity == "WARNING" for f in findings),
    }
    Path(out_path).write_text(json.dumps(doc, indent=2, sort_keys=True))
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--analysis-out", default="BENCH_analysis.json",
                    help="where bench_analysis writes its JSON")
    args = ap.parse_args()

    from benchmarks.kernels_bench import bench_kernels
    from benchmarks.paper import ALL_BENCHES

    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}")
    for name, us, derived in bench_kernels():
        print(f"{name},{us:.1f},{derived}")
    doc = bench_analysis(args.analysis_out)
    for pass_name, secs in sorted(doc["per_pass_seconds"].items()):
        n = doc["per_pass_findings"][pass_name]
        print(f"analysis_{pass_name},{secs * 1e6:.1f},findings={n}")


if __name__ == "__main__":
    main()
