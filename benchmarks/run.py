"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Roofline/dry-run tables live in
``benchmarks.roofline`` (they read the dry-run JSON artifacts).
"""
from __future__ import annotations


def main() -> None:
    from benchmarks.kernels_bench import bench_kernels
    from benchmarks.paper import ALL_BENCHES

    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}")
    for name, us, derived in bench_kernels():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
