"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Reads ``benchmarks/results/dryrun/*.json`` (written by repro.launch.dryrun)
and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips * peak)   [per-device flops / peak]
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

plus MODEL_FLOPS = 6 * N(_active) * tokens (train) or 2 * N_active * tokens
(inference) with an explicit attention/SSM correction, and the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs * chips).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir ...] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.hw import TPU_V5E

CHIP = TPU_V5E


def model_flops(arch: str, shape_name: str) -> float:
    """Useful (model) FLOPs for one step of this cell, global."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        base = 6.0 * n_active * tokens
        attn = 3.0 * _attn_fwd_flops(cfg, shape.seq_len) * shape.global_batch
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens + _attn_fwd_flops(
            cfg, shape.seq_len) * shape.global_batch
    # decode: one token per sequence; attention reads the whole cache
    per_tok = 2.0 * n_active + _decode_attn_flops(cfg, shape.seq_len)
    return per_tok * shape.global_batch


def _attn_fwd_flops(cfg, S: int) -> float:
    """Softmax-attention QK^T + PV flops per sequence (causal ~ S^2/2 x2)."""
    total = 0.0
    for s in cfg.stages:
        if s.block in ("dense", "moe"):
            dh = (cfg.nope_head + cfg.rope_head) if s.attn == "mla" else cfg.d_head
            dv = cfg.v_head if s.attn == "mla" else cfg.d_head
            eff = min(S, s.window) if s.window else S
            per_layer = 2 * S * eff * cfg.n_heads * (dh + dv) / (1 if s.window else 2)
            total += s.n_layers * per_layer
        elif s.shared_attn_every:
            n_attn = s.n_layers // s.shared_attn_every
            total += n_attn * 2 * S * S * cfg.n_heads * 2 * cfg.d_head / 2
    return total


def _decode_attn_flops(cfg, S: int) -> float:
    total = 0.0
    for s in cfg.stages:
        if s.block in ("dense", "moe"):
            dh = (cfg.nope_head + cfg.rope_head) if s.attn == "mla" else cfg.d_head
            dv = cfg.v_head if s.attn == "mla" else cfg.d_head
            eff = min(S, s.window) if s.window else S
            total += s.n_layers * 2 * eff * cfg.n_heads * (dh + dv)
        elif s.shared_attn_every:
            n_attn = s.n_layers // s.shared_attn_every
            total += n_attn * 2 * S * cfg.n_heads * 2 * cfg.d_head
    return total


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    if "corrected" in rec:
        # loop-corrected HLO walker (XLA's cost_analysis counts while bodies
        # once; see repro.launch.hlo_cost)
        flops_dev = rec["corrected"]["dot_flops_per_device"]
        bytes_dev = rec["corrected"]["dot_bytes_per_device"]
        coll_dev = rec["corrected"]["collective_bytes_per_device"]
    else:
        flops_dev = rec["cost"]["flops_per_device"]
        bytes_dev = rec["cost"]["bytes_per_device"]
        coll_dev = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / CHIP.peak_flops
    memory_s = bytes_dev / CHIP.hbm_bw
    collective_s = coll_dev / CHIP.ici_bw_per_link
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * chips
    bound_s = max(terms.values())
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": round(mf / hlo_global, 4) if hlo_global else 0.0,
        "roofline_fraction": round(
            (mf / chips / CHIP.peak_flops) / bound_s, 4) if bound_s else 0.0,
        "step_lower_bound_s": round(bound_s, 6),
    }


def load(dir_: str, mesh: str = "16x16") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "error" in rec:
            out.append(rec)
            continue
        rec["roofline"] = analyze(rec)
        out.append(rec)
    return out


def as_markdown(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | GiB/dev | fits | compute s | memory s | "
           "collective s | dominant | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for r in recs:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r['error'][:60]} | | | | | | | |")
            continue
        rf = r["roofline"]
        mem = r["memory"]["live_bytes_per_device"] / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {mem:.2f} | "
            f"{'Y' if r['memory']['fits_16GiB'] else 'N'} | "
            f"{rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | {rf['dominant']} | "
            f"{rf['useful_ratio']:.3f} | {rf['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    if args.md:
        print(as_markdown(recs))
    else:
        for r in recs:
            if "error" in r:
                print(f"{r['arch']},{r['shape']},ERROR")
                continue
            rf = r["roofline"]
            print(f"{r['arch']},{r['shape']},{rf['dominant']},"
                  f"{rf['compute_s']:.5g},{rf['memory_s']:.5g},"
                  f"{rf['collective_s']:.5g},{rf['useful_ratio']},"
                  f"{rf['roofline_fraction']}")


if __name__ == "__main__":
    main()
