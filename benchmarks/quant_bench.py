"""Reduced-precision serving benchmark: the precision axis of the paper.

Three sections in one artifact (BENCH_quant.json):

  vision  : reduced/full ResNet50 through ``VisionEngine`` -- float params
            vs PTQ-calibrated int8 params on the SAME Pallas backend
            (interpret on CPU CI, real kernels on TPU) -- img/s, p99, and
            the top-1 agreement of the two paths on a fixed eval batch
            (the accuracy side of the accuracy-vs-speed trade).
  serve   : a reduced LM through ``ServeEngine`` -- float vs the whole
            width ladder: weight-only int8 (int8 GEMV decode), calibrated
            activation int8 (per-layer scan-threaded scales, full int8 x
            int8 GeMMs), packed int4 weight-only (0.5 B/elem weights), and
            fp8 (e4m3 both sides) -- tokens/s on a small mixed-length
            workload.
  modeled : the analytic counterpart from ``trace.paper_report`` on the
            FULL configs: int8/fp8/int4-vs-bf16 operand traffic, DRAM
            energy, and roofline runtime ratios for the Axon orchestration
            (tracing runs no compute, so full-size models are free).

Usage:
  PYTHONPATH=src python benchmarks/quant_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import axon, quant
from repro.configs import get_config, get_vision_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.vision import models, trace
from repro.vision.engine import ImageRequest, VisionEngine

VISION_MODEL = "resnet50"
SERVE_ARCH = "yi-9b"
MODELED = ("resnet50", "yolov3-tiny")


def bench_vision(*, smoke: bool, images: int, slots: int) -> dict:
    cfg = get_vision_config(VISION_MODEL, reduced=smoke)
    params = models.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    calib = jnp.asarray(rng.normal(
        size=(4, *cfg.input_hw, cfg.in_channels)).astype(np.float32))
    qparams = quant.quantize_model(
        params, lambda p, b: models.apply(p, b, cfg), [calib])

    n = min(images, 6) if smoke else images
    reqs = [ImageRequest(image=rng.normal(
        size=(*cfg.input_hw, cfg.in_channels)).astype(np.float32))
        for _ in range(n)]

    entry: dict = {"config": cfg.name, "images": n}
    outs = {}
    for label, p, prec in (("float", params, "float"),
                           ("int8", qparams, "int8")):
        pol = axon.ExecutionPolicy(backend="pallas", precision=prec)
        eng = VisionEngine(p, cfg, batch_slots=slots, policy=pol)
        eng.warmup()
        outs[label] = eng.infer(reqs)
        st = eng.last_stats
        entry[label] = {
            "img_per_s": round(st["img_per_s"], 2),
            "wall_s": round(st["wall_s"], 4),
            "p99_latency_s": round(st["p99_latency_s"], 4),
        }
    agree = sum(int(np.argmax(q) == np.argmax(f))
                for q, f in zip(outs["int8"], outs["float"]))
    entry["speedup_int8"] = round(
        entry["int8"]["img_per_s"] / max(entry["float"]["img_per_s"], 1e-9),
        3)
    entry["top1_agreement"] = round(agree / n, 3)
    return entry


def bench_serve(*, smoke: bool, n_requests: int, slots: int) -> dict:
    cfg = get_config(SERVE_ARCH, reduced=True)     # full LMs don't fit CPU CI
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    n = min(n_requests, 4) if smoke else n_requests
    reqs = [Request(prompt=[int(t) for t in rng.integers(
                2, cfg.vocab, rng.integers(2, 8))],
                    max_new_tokens=int(rng.integers(3, 7)), eos_id=1)
            for _ in range(n)]
    pol = axon.ExecutionPolicy(backend="pallas")

    # calibrated activation int8: per-layer scales threaded through lax.scan
    calib = [{"tokens": jnp.asarray(
        rng.integers(2, cfg.vocab, (2, 12)), jnp.int32)} for _ in range(2)]
    qparams_cal = quant.quantize_lm(params, cfg, calib)

    entry: dict = {"config": SERVE_ARCH + "-reduced", "requests": n}
    modes = (("float", params, {}),
             ("int8_weight_only", params, {"quantized": True}),
             ("int8_calibrated", qparams_cal, {"quantized": True}),
             ("int4_weight_only", params, {"quantized": "int4"}),
             ("fp8", params, {"quantized": "fp8"}))
    for label, p, kwargs in modes:
        eng = ServeEngine(p, cfg, batch_slots=slots, max_len=64,
                          policy=pol, **kwargs)
        eng.generate(reqs)                         # warm the two step shapes
        eng.generate(reqs)
        st = eng.last_stats
        entry[label] = {
            "tokens_per_s": round(st["tokens_per_s"], 2),
            "generated_tokens": st["generated_tokens"],
            "steps": st["steps"],
        }
    for label in ("int8_weight_only", "int8_calibrated", "int4_weight_only",
                  "fp8"):
        entry[f"speedup_{label}"] = round(
            entry[label]["tokens_per_s"]
            / max(entry["float"]["tokens_per_s"], 1e-9), 3)
    entry["speedup_int8"] = entry["speedup_int8_weight_only"]
    return entry


def modeled_section() -> dict:
    out = {}
    for name in MODELED:
        per = trace.paper_report(get_vision_config(name))["precision"]
        entry = {
            "bf16_operand_mb": round(per["bf16"]["operand_bytes"] / 1e6, 2),
        }
        for prec in ("int8", "fp8", "int4"):
            ratios = per[f"{prec}_vs_bf16"]
            entry[f"{prec}_operand_mb"] = round(
                per[prec]["operand_bytes"] / 1e6, 2)
            entry[prec] = {
                "traffic_ratio": round(ratios["traffic_ratio"], 4),
                "energy_ratio": round(ratios["energy_ratio"], 4),
                "throughput_speedup": round(ratios["throughput_speedup"], 4),
            }
        # back-compat aliases for the int8 headline figures
        entry.update({
            "traffic_ratio": entry["int8"]["traffic_ratio"],
            "energy_ratio": entry["int8"]["energy_ratio"],
            "throughput_speedup": entry["int8"]["throughput_speedup"],
        })
        out[name] = entry
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + tiny workload for CPU CI")
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--out", default="benchmarks/results/BENCH_quant.json")
    args = ap.parse_args()

    result = {"smoke": args.smoke, "slots": args.slots}
    result["vision"] = {VISION_MODEL: bench_vision(
        smoke=args.smoke, images=args.images, slots=args.slots)}
    v = result["vision"][VISION_MODEL]
    print(f"{VISION_MODEL}: float {v['float']['img_per_s']} img/s | int8 "
          f"{v['int8']['img_per_s']} img/s ({v['speedup_int8']}x, top-1 "
          f"agreement {v['top1_agreement'] * 100:.0f}%)")

    result["serve"] = {SERVE_ARCH: bench_serve(
        smoke=args.smoke, n_requests=args.requests, slots=args.slots)}
    s = result["serve"][SERVE_ARCH]
    print(f"{SERVE_ARCH}: float {s['float']['tokens_per_s']} tok/s | "
          f"int8 weight-only {s['int8_weight_only']['tokens_per_s']} "
          f"({s['speedup_int8_weight_only']}x) | calibrated "
          f"{s['int8_calibrated']['tokens_per_s']} "
          f"({s['speedup_int8_calibrated']}x) | int4 "
          f"{s['int4_weight_only']['tokens_per_s']} "
          f"({s['speedup_int4_weight_only']}x) | fp8 "
          f"{s['fp8']['tokens_per_s']} ({s['speedup_fp8']}x)")

    result["modeled"] = modeled_section()
    for name, m in result["modeled"].items():
        for prec in ("int8", "fp8", "int4"):
            p = m[prec]
            print(f"modeled {name} [{prec}]: traffic {p['traffic_ratio']}x, "
                  f"DRAM energy {p['energy_ratio']}x better, runtime "
                  f"{p['throughput_speedup']}x")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
