"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On-CPU wall times are NOT TPU predictions -- the derived column carries the
modeled TPU numbers (mapper traffic / roofline); the us column simply proves
the kernels run and tracks interpreter overhead regressions.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import axon
from repro.core import mapper
from repro.core.dataflows import Dataflow, GemmShape
from repro.core.mapper import select_tpu_blocking
from repro.kernels import ref
from repro.kernels.axon_gemm import axon_gemm
from repro.kernels.dwconv import dwconv
from repro.kernels.gemv import gemv
from repro.kernels.im2col_conv import hbm_traffic_model, im2col_conv
from repro.kernels.zero_gate_gemm import block_mask, skip_fraction, zero_gate_gemm


def _timeit(fn, n=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def bench_kernels():
    rows = []
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)

    for order in Dataflow:
        us = _timeit(lambda o=order: axon_gemm(a, b, block=(64, 64, 64),
                                               order=o, interpret=True))
        sel = select_tpu_blocking(GemmShape(256, 256, 256))
        rows.append((f"kernel_gemm_{order.value}_256", us,
                     f"mapper picks {sel.loop_order.value} "
                     f"bm{sel.bm}/bk{sel.bk}/bn{sel.bn}"))

    x = jax.random.normal(key, (1, 28, 28, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 32), jnp.float32) * 0.2
    us = _timeit(lambda: im2col_conv(x, w, stride=1, padding=1, block_rows=7,
                                     block_cout=32, block_cin=32,
                                     interpret=True))
    t = hbm_traffic_model((1, 28, 28, 32), (3, 3, 32, 32), stride=1, padding=1)
    rows.append(("kernel_im2col_conv_28x28x32", us,
                 f"{t['reduction'] * 100:.1f}% HBM traffic cut vs im2col"))

    wd = jax.random.normal(key, (3, 3, 32), jnp.float32) * 0.3
    us = _timeit(lambda: dwconv(x, wd, stride=1, padding=1, block_rows=7,
                                block_c=32, interpret=True))
    rows.append(("kernel_dwconv_28x28x32", us, "VPU path, no im2col"))

    xv = jax.random.normal(key, (2048,), jnp.float32)
    wv = jax.random.normal(jax.random.PRNGKey(1), (2048, 2048), jnp.float32)
    us = _timeit(lambda: gemv(xv, wv, block_k=512, block_n=512, interpret=True))
    rows.append(("kernel_gemv_2048", us, "W read exactly once (K innermost)"))

    import numpy as np
    a_sp = np.array(a)
    a_sp[:128] = 0.0
    a_sp = jnp.asarray(a_sp)
    mask = block_mask(a_sp, 64, 64)
    us = _timeit(lambda: zero_gate_gemm(a_sp, b, block=(64, 64, 64),
                                        interpret=True))
    rows.append(("kernel_zero_gate_50pct", us,
                 f"{skip_fraction(mask) * 100:.0f}% MXU passes skipped"))
    rows.append(bench_mapper_cache())
    return rows


def bench_mapper_cache(repeats: int = 20):
    """Repeated-shape dispatch through ``axon.einsum``: the mapper's
    candidate sweep must run ONCE per unique (shape, dtype) key, not per
    call.  The us column is the steady-state per-call dispatch time with a
    warm cache; the derived column reports sweep invocations."""
    mapper.mapper_cache_clear()
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (64, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    with axon.policy(backend="interpret"):
        axon.einsum("mk,kn->mn", a, b)          # cold call: pays the sweep
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(axon.einsum("mk,kn->mn", a, b))
        us = (time.perf_counter() - t0) / repeats * 1e6
    calls = 1 + repeats
    sweeps = mapper.sweep_calls()
    assert sweeps == 1, (
        f"mapper sweep ran {sweeps}x for {calls} same-shape calls")
    info = mapper.mapper_cache_info()
    return ("mapper_cache_64x256x128", us,
            f"{calls} calls -> {sweeps} sweep ({info.hits} cache hits)")
