"""Mixed-length serving benchmark: wave vs continuous batching.

Runs the same interleaved short/long workload (the shape that triggers wave
batching's head-of-line blocking) through ``WaveServeEngine`` and the
continuous ``ServeEngine``, and emits ``BENCH_serve.json``:

  {"workload": {...},
   "wave":       {"tokens_per_s", "wall_s", "p50_latency_s", "p99_latency_s"},
   "continuous": {... + "steps"},
   "speedup_tokens_per_s": ...}

Latency is per-request completion time from benchmark start (all requests
arrive at t=0).  For the wave engine, every request in a wave completes when
its wave does, so latency is measured per wave group.

Usage:
  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, WaveServeEngine


def build_workload(cfg, *, n_requests: int, short_len: int, long_len: int,
                   short_new: int, long_new: int, seed: int = 1
                   ) -> list[Request]:
    """Interleave short and long prompts (odd indices are long)."""
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        plen = long_len if i % 2 else short_len
        mnew = long_new if i % 2 else short_new
        prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
        reqs.append(Request(prompt=[int(t) for t in prompt],
                            max_new_tokens=mnew))
    return reqs


def run_wave(engine: WaveServeEngine, reqs) -> dict:
    slots = engine.batch_slots
    lat = np.zeros(len(reqs))
    outs = []
    t0 = time.perf_counter()
    for i in range(0, len(reqs), slots):
        outs.extend(engine.generate(reqs[i: i + slots]))
        lat[i: i + slots] = time.perf_counter() - t0   # wave-granular
    wall = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    return {
        "tokens": n_tok,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(n_tok / wall, 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
    }


def run_continuous(engine: ServeEngine, reqs) -> dict:
    engine.generate(reqs)
    st = engine.last_stats
    lat = np.array([r["latency_s"] for r in st["requests"]])
    return {
        "tokens": st["generated_tokens"],
        "wall_s": round(st["wall_s"], 4),
        "tokens_per_s": round(st["tokens_per_s"], 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
        "steps": st["steps"],
        "prefill_chunk": engine.prefill_chunk,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI (CPU, seconds not minutes)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--out", default="benchmarks/results/BENCH_serve.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.smoke:
        n_req, short_len, long_len = min(args.requests, 8), 4, 16
        short_new, long_new = 4, 12
    else:
        n_req, short_len, long_len = args.requests, 8, 48
        short_new, long_new = 8, 32
    reqs = build_workload(cfg, n_requests=n_req, short_len=short_len,
                          long_len=long_len, short_new=short_new,
                          long_new=long_new)
    max_len = long_len + long_new + 1

    wave_engine = WaveServeEngine(params, cfg, batch_slots=args.slots,
                                  max_len=max_len)
    cont_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                              max_len=max_len,
                              prefill_chunk=args.prefill_chunk)
    # warm both engines' jit caches (all step shapes) so compile time is
    # excluded from the comparison
    warm = reqs[: min(args.slots + 1, len(reqs))]
    run_wave(wave_engine, warm)
    run_continuous(cont_engine, warm)

    wave = run_wave(wave_engine, reqs)
    cont = run_continuous(cont_engine, reqs)
    result = {
        "arch": cfg.name,
        "workload": {
            "requests": n_req, "slots": args.slots,
            "short": {"prompt": short_len, "max_new": short_new},
            "long": {"prompt": long_len, "max_new": long_new},
            "interleaved": True, "smoke": args.smoke,
        },
        "wave": wave,
        "continuous": cont,
        "speedup_tokens_per_s": round(
            cont["tokens_per_s"] / wave["tokens_per_s"], 3),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps(result, indent=1))
    print(f"\nwrote {args.out}; continuous is "
          f"{result['speedup_tokens_per_s']:.2f}x wave tokens/s "
          f"(p99 latency {wave['p99_latency_s']:.2f}s -> "
          f"{cont['p99_latency_s']:.2f}s)")


if __name__ == "__main__":
    main()
