"""Mixed-length serving benchmark: wave vs continuous vs paged batching.

Runs the same interleaved short/long workload (the shape that triggers wave
batching's head-of-line blocking) through ``WaveServeEngine``, the
continuous ``ServeEngine``, and its paged-cache variants, and emits
``BENCH_serve.json``:

  {"workload": {...},
   "wave":        {"tokens_per_s", "wall_s", "p50/p99_latency_s"},
   "continuous":  {... + "steps", "cache_bytes_per_slot"},
   "paged":       {... + "pool" occupancy/prefix stats},
   "paged_int8":  {...},
   "paged_repeat": {...},    # same prompts again: prefix-cache hits
   "obs": {...},             # tokens/s with telemetry off vs on + overhead %
   "mesh_dp": {...},         # data-parallel mesh over all visible devices
   "speedup_tokens_per_s": ...,
   "cache_reduction_int8_vs_dense_f32": ...}

Latency is per-request completion time from benchmark start (all requests
arrive at t=0).  For the wave engine, every request in a wave completes when
its wave does, so latency is measured per wave group.  The paged rows share
the continuous engine's scheduler -- any throughput delta is pure cache
data movement -- and ``paged_repeat`` replays the identical prompt set so
the prefix index converts prefill steps into page sharing.

Usage:
  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, WaveServeEngine


def build_workload(cfg, *, n_requests: int, short_len: int, long_len: int,
                   short_new: int, long_new: int, seed: int = 1
                   ) -> list[Request]:
    """Interleave short and long prompts (odd indices are long)."""
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        plen = long_len if i % 2 else short_len
        mnew = long_new if i % 2 else short_new
        prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
        reqs.append(Request(prompt=[int(t) for t in prompt],
                            max_new_tokens=mnew))
    return reqs


def run_wave(engine: WaveServeEngine, reqs) -> dict:
    slots = engine.batch_slots
    lat = np.zeros(len(reqs))
    outs = []
    t0 = time.perf_counter()
    for i in range(0, len(reqs), slots):
        outs.extend(engine.generate(reqs[i: i + slots]))
        lat[i: i + slots] = time.perf_counter() - t0   # wave-granular
    wall = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    return {
        "tokens": n_tok,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(n_tok / wall, 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
    }


def run_continuous(engine: ServeEngine, reqs) -> dict:
    engine.generate(reqs)
    st = engine.last_stats
    lat = np.array([r["latency_s"] for r in st["requests"]])
    ttft = np.array([r["ttft_s"] for r in st["requests"]])
    out = {
        "tokens": st["generated_tokens"],
        "wall_s": round(st["wall_s"], 4),
        "tokens_per_s": round(st["tokens_per_s"], 2),
        "prefill_tokens_per_s": round(st["prefill_tokens_per_s"], 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lat, 99)), 4),
        "p99_ttft_s": round(float(np.percentile(ttft, 99)), 4),
        "steps": st["steps"],
        "prefill_chunk": engine.prefill_chunk,
        "cache_bytes_per_slot": st["cache_bytes_per_slot"],
    }
    if engine.pool is not None:
        out["pool"] = engine.pool.stats()
        out["prefix_hits"] = st["prefix_hits"]
        out["prefix_hit_tokens"] = st["prefix_hit_tokens"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI (CPU, seconds not minutes)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=4,
                    help="tokens per KV-cache page for the paged rows")
    ap.add_argument("--out", default="benchmarks/results/BENCH_serve.json")
    ap.add_argument("--check-overhead", action="store_true",
                    help="fail when telemetry overhead exceeds its budget: "
                         "5%% tokens/s for full tracing, 2%% for sampled "
                         "(sample_every=16) mode")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.smoke:
        n_req, short_len, long_len = min(args.requests, 8), 4, 16
        short_new, long_new = 4, 12
    else:
        n_req, short_len, long_len = args.requests, 8, 48
        short_new, long_new = 8, 32
    reqs = build_workload(cfg, n_requests=n_req, short_len=short_len,
                          long_len=long_len, short_new=short_new,
                          long_new=long_new)
    # both cache layouts address the same token capacity: a page pool can
    # only hold whole pages, so round max_len up to a page multiple
    page_size = args.page_size
    max_len = -(-(long_len + long_new + 1) // page_size) * page_size

    wave_engine = WaveServeEngine(params, cfg, batch_slots=args.slots,
                                  max_len=max_len)
    cont_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                              max_len=max_len,
                              prefill_chunk=args.prefill_chunk,
                              cache_dtype="float32")
    # memory/throughput rows: dense-equivalent pool, prefix index off (the
    # apples-to-apples cache-bytes comparison)
    paged_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                               max_len=max_len,
                               prefill_chunk=args.prefill_chunk,
                               paged=True, page_size=page_size,
                               prefix_cache=False)
    int8_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                              max_len=max_len,
                              prefill_chunk=args.prefill_chunk,
                              paged=True, page_size=page_size,
                              cache_fmt="int8", prefix_cache=False)
    # prefix row: 2x pool headroom so registered pages survive admission
    # pressure instead of being evicted before they can ever hit
    pps = -(-max_len // page_size)
    prefix_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                                max_len=max_len,
                                prefill_chunk=args.prefill_chunk,
                                paged=True, page_size=page_size,
                                cache_fmt="int8",
                                pool_pages=2 * args.slots * pps)
    # warm every engine's jit cache (all step shapes) so compile time is
    # excluded from the comparison; the prefix engine warms on a disjoint
    # prompt set so the measured runs start with a cold prefix index
    warm = reqs[: min(args.slots + 1, len(reqs))]
    warm_paged = build_workload(cfg, n_requests=len(warm),
                                short_len=short_len, long_len=long_len,
                                short_new=short_new, long_new=long_new,
                                seed=99)
    run_wave(wave_engine, warm)
    run_continuous(cont_engine, warm)
    run_continuous(paged_engine, warm)
    run_continuous(int8_engine, warm)
    run_continuous(prefix_engine, warm_paged)

    wave = run_wave(wave_engine, reqs)
    cont = run_continuous(cont_engine, reqs)
    paged = run_continuous(paged_engine, reqs)
    paged_int8 = run_continuous(int8_engine, reqs)
    # cold pass populates the prefix index, then the same prompts again:
    # the index hands their pages back and prefill steps disappear
    run_continuous(prefix_engine, reqs)
    paged_repeat = run_continuous(prefix_engine, reqs)
    # telemetry overhead row: the warmed continuous engine again, obs off
    # vs obs on (full optrace ring + span recording + metric publication);
    # best-of-3 per mode, since a smoke run's wall is tens of ms and a
    # single pass would measure scheduler noise, not instrumentation cost
    from repro.obs import optrace
    obs_off = max(
        (run_continuous(cont_engine, reqs)["tokens_per_s"]
         for _ in range(3)))
    optrace.enable()
    try:
        obs_on = max(
            (run_continuous(cont_engine, reqs)["tokens_per_s"]
             for _ in range(3)))
        spans_recorded = len(optrace.spans())
    finally:
        optrace.disable()
    obs_row = {
        "tokens_per_s_off": obs_off,
        "tokens_per_s_on": obs_on,
        "overhead_pct": round(100.0 * (1.0 - obs_on / obs_off), 2),
        "spans_recorded": spans_recorded,
    }
    # sampled telemetry row: production-rate mode (every 16th dispatch
    # into the ring, counters exact) must cost less than full tracing --
    # the budget is 2% vs the 5% full-tracing bound
    optrace.enable()
    optrace.configure(sample_every=16)
    try:
        obs_sampled = max(
            (run_continuous(cont_engine, reqs)["tokens_per_s"]
             for _ in range(3)))
        sampled_out = optrace.sampled_out_ops()
    finally:
        optrace.configure(sample_every=1)
        optrace.disable()
    obs_sampled_row = {
        "sample_every": 16,
        "tokens_per_s_off": obs_off,
        "tokens_per_s_on": obs_sampled,
        "overhead_pct": round(100.0 * (1.0 - obs_sampled / obs_off), 2),
        "sampled_out_ops": sampled_out,
    }
    # mesh row: same workload through a data-parallel mesh over every
    # visible device (model=1: CPU fake devices make TP all-reduces pure
    # overhead; the row exists to keep the sharded path measured and to
    # pin the token-identity guarantee, not to show CPU speedup)
    n_dev = jax.device_count()
    mesh_row = None
    if n_dev > 1:
        from repro.launch.mesh import make_debug_mesh
        mesh_engine = ServeEngine(params, cfg, batch_slots=args.slots,
                                  max_len=max_len,
                                  prefill_chunk=args.prefill_chunk,
                                  cache_dtype="float32",
                                  mesh=make_debug_mesh(n_dev, 1))
        run_continuous(mesh_engine, warm)
        mesh_row = run_continuous(mesh_engine, reqs)
        mesh_row["devices"] = n_dev
        mesh_row["mesh"] = {"data": n_dev, "model": 1}
        single = [list(map(int, o)) for o in cont_engine.generate(reqs)]
        meshed = [list(map(int, o)) for o in mesh_engine.generate(reqs)]
        mesh_row["tokens_match_single"] = single == meshed

    result = {
        "arch": cfg.name,
        "workload": {
            "requests": n_req, "slots": args.slots,
            "short": {"prompt": short_len, "max_new": short_new},
            "long": {"prompt": long_len, "max_new": long_new},
            "interleaved": True, "smoke": args.smoke,
        },
        "wave": wave,
        "continuous": cont,
        "paged": paged,
        "paged_int8": paged_int8,
        "paged_repeat": paged_repeat,
        "obs": obs_row,
        "obs_sampled": obs_sampled_row,
        **({"mesh_dp": mesh_row} if mesh_row is not None else {}),
        "speedup_tokens_per_s": round(
            cont["tokens_per_s"] / wave["tokens_per_s"], 3),
        "cache_reduction_int8_vs_dense_f32": round(
            cont["cache_bytes_per_slot"]
            / paged_int8["cache_bytes_per_slot"], 2),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps(result, indent=1))
    print(f"\nwrote {args.out}; continuous is "
          f"{result['speedup_tokens_per_s']:.2f}x wave tokens/s "
          f"(p99 latency {wave['p99_latency_s']:.2f}s -> "
          f"{cont['p99_latency_s']:.2f}s); int8 pages hold "
          f"{result['cache_reduction_int8_vs_dense_f32']:.1f}x less cache "
          f"per slot; repeat wave hit {paged_repeat.get('prefix_hits', 0)} "
          f"prefixes ({paged_repeat.get('prefix_hit_tokens', 0)} tokens); "
          f"obs overhead {obs_row['overhead_pct']:+.1f}% tokens/s "
          f"(sampled 1/16: {obs_sampled_row['overhead_pct']:+.1f}%)")
    if args.check_overhead:
        assert obs_row["overhead_pct"] <= 5.0, \
            f"full-tracing overhead {obs_row['overhead_pct']}% > 5% budget"
        assert obs_sampled_row["overhead_pct"] <= 2.0, \
            (f"sampled telemetry overhead {obs_sampled_row['overhead_pct']}%"
             " > 2% budget")


if __name__ == "__main__":
    main()
