"""Distribution: mesh-aware sharding rules and helpers."""
