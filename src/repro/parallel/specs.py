"""Parameter sharding specs: path/shape -> logical axis entries.

Every matrix is 2-D sharded (TP over 'model', FSDP over ('pod','data')) with
divisibility guards applied downstream by ``sharding.resolve``.  Stage params
carry a leading stacked-layer dim which stays unsharded.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

# weights whose OUTPUT dim is the TP axis
_TP_OUT = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "x_proj",
           "q_a", "q_b", "kv_a", "kv_b", "proj",
           "in_z", "in_x", "in_b", "in_c", "in_dt"}
# weights whose INPUT dim is the TP axis
_TP_IN = {"wo", "w_down", "out_proj", "dt_proj"}
_TP_BIAS = {"bq", "bk", "bv", "conv_b"}
_CHANNEL_1D = {"conv_b", "dt_bias", "D"}


def _leaf_name(path) -> str:
    for k in reversed(path):
        name = getattr(k, "key", None)
        if isinstance(name, str):
            return name
    return ""


def make_param_spec_fn(cfg: ModelConfig):
    ep = cfg.expert_shard == "ep"

    def spec_fn(path, shape):
        name = _leaf_name(path)
        nd = len(shape)
        lead = max(0, nd - 2)
        if name == "embed":
            return ("model", "fsdp")
        if name == "lm_head":
            return ("fsdp", "model")
        if name == "router":
            return (None,) * lead + ("fsdp", None)
        if nd >= 4 and name in ("w_gate", "w_up"):      # experts (L, E, D, F)
            return ((None, "model", "fsdp", None) if ep
                    else (None, None, "fsdp", "model"))
        if nd >= 4 and name == "w_down":                # experts (L, E, F, D)
            return ((None, "model", None, "fsdp") if ep
                    else (None, None, "model", "fsdp"))
        if name in _TP_OUT and nd >= 2:
            return (None,) * lead + ("fsdp", "model")
        if name in _TP_IN and nd >= 2:
            return (None,) * lead + ("model", "fsdp")
        if name.startswith("conv_") or name == "conv_w":   # (L, K, C)
            return (None,) * (nd - 1) + ("model",)
        if name == "A_log" and nd >= 2:                 # (L, di, n) mamba1
            return (None,) * (nd - 2) + ("model", None)
        if name in _TP_BIAS or name in _CHANNEL_1D:
            return (None,) * (nd - 1) + ("model",)
        return (None,) * nd                             # norms, scalars

    return spec_fn


def batch_spec_entries(ndim: int):
    """Activations / data batches: leading dim over (pod, data)."""
    return ("batch",) + (None,) * (ndim - 1)
