"""Mesh-aware sharding rules (FSDP + TP + SP + EP).

Conventions (single pod mesh ``(data=16, model=16)``; multi-pod adds a
leading ``pod`` axis that composes with ``data`` for FSDP/DP):

  * batch dims of activations  -> (pod, data)
  * attention heads / FFN hidden / vocab / experts -> model  (TP / EP)
  * parameters are 2-D sharded: TP axis over ``model`` AND the other large
    dim over ``fsdp`` = (pod, data), so per-chip bytes scale 1/(total chips)
  * sequence-parallel: activations in norm/residual regions may shard the
    sequence dim over ``model``

Every rule is divisibility-guarded: an axis is only applied when the dim is
divisible by the mesh axis size, so the same model code serves all ten
architectures (24-head models simply leave heads replicated).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def current_mesh() -> Mesh | None:
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return fsdp_axes(mesh)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve(mesh: Mesh, spec_entries: tuple, dims: tuple[int, ...]) -> P:
    """Build a PartitionSpec, dropping axes whose dim is not divisible."""
    out = []
    for entry, dim in zip(spec_entries, dims):
        if entry is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((entry,) if isinstance(entry, str) else entry)
                     if a in mesh.axis_names)
        if not axes or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint with divisibility-guarded logical entries.

    Entries use physical axis names ('data', 'model', 'pod') or the logical
    markers 'fsdp' / 'batch' which expand to (pod, data).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    expanded = []
    for e in entries:
        if e in ("fsdp", "batch"):
            expanded.append(fsdp_axes(mesh))
        else:
            expanded.append(e)
    spec = resolve(mesh, tuple(expanded), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *entries, dims: tuple[int, ...]) -> NamedSharding:
    expanded = []
    for e in entries:
        if e in ("fsdp", "batch"):
            expanded.append(fsdp_axes(mesh))
        else:
            expanded.append(e)
    return NamedSharding(mesh, resolve(mesh, tuple(expanded), dims))


def constrain_priority(x: jax.Array, batch_dims: int, candidates: list[int],
                       axis: str = "model") -> jax.Array:
    """Constrain ``x`` sharding ``axis`` onto the FIRST candidate dim whose
    size divides the axis (e.g. decode KV: prefer kv-heads, fall back to
    d_head).  Leading ``batch_dims`` dims shard over (pod, data)."""
    mesh = current_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return x
    size = mesh.shape[axis]
    entries: list = [fsdp_axes(mesh) if i < batch_dims else None
                     for i in range(x.ndim)]
    for dim in candidates:
        if x.shape[dim] % size == 0:
            entries[dim] = axis
            break
    spec = resolve(mesh, tuple(entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(params, mesh: Mesh, spec_fn) -> dict:
    """Map a pytree of (path, array/ShapeDtypeStruct) -> NamedSharding via
    ``spec_fn(path, shape) -> tuple of entries``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = []
    for path, leaf in flat:
        entries = spec_fn(path, leaf.shape)
        shardings.append(named_sharding(mesh, *entries, dims=leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, shardings)
