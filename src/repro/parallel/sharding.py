"""Mesh-aware sharding rules (FSDP + TP + SP + EP).

Conventions (single pod mesh ``(data=16, model=16)``; multi-pod adds a
leading ``pod`` axis that composes with ``data`` for FSDP/DP):

  * batch dims of activations  -> (pod, data)
  * attention heads / FFN hidden / vocab / experts -> model  (TP / EP)
  * parameters are 2-D sharded: TP axis over ``model`` AND the other large
    dim over ``fsdp`` = (pod, data), so per-chip bytes scale 1/(total chips)
  * sequence-parallel: activations in norm/residual regions may shard the
    sequence dim over ``model``

Every rule is divisibility-guarded: an axis is only applied when the dim is
divisible by the mesh axis size, so the same model code serves all ten
architectures (24-head models simply leave heads replicated).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def current_mesh() -> Mesh | None:
    """The ambient ``with mesh:`` context, or None off-mesh.

    Reads the public ``jax.interpreters.pxla`` thread resources (stable
    across 0.4.x); falls back to the private module only if a future jax
    moves the public alias, and degrades to "no mesh" rather than raising
    -- every caller treats None as single-device."""
    try:
        from jax.interpreters import pxla
        env = pxla.thread_resources.env
    except (ImportError, AttributeError):
        try:
            from jax._src import mesh as mesh_lib
            env = mesh_lib.thread_resources.env
        except (ImportError, AttributeError):
            return None
    m = getattr(env, "physical_mesh", None)
    if m is None or m.empty:
        return None
    return m


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return fsdp_axes(mesh)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve(mesh: Mesh, spec_entries: tuple, dims: tuple[int, ...]) -> P:
    """Build a PartitionSpec, dropping axes whose dim is not divisible."""
    out = []
    for entry, dim in zip(spec_entries, dims):
        if entry is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((entry,) if isinstance(entry, str) else entry)
                     if a in mesh.axis_names)
        if not axes or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint with divisibility-guarded logical entries.

    Entries use physical axis names ('data', 'model', 'pod') or the logical
    markers 'fsdp' / 'batch' which expand to (pod, data).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    expanded = []
    for e in entries:
        if e in ("fsdp", "batch"):
            expanded.append(fsdp_axes(mesh))
        else:
            expanded.append(e)
    spec = resolve(mesh, tuple(expanded), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *entries, dims: tuple[int, ...]) -> NamedSharding:
    expanded = []
    for e in entries:
        if e in ("fsdp", "batch"):
            expanded.append(fsdp_axes(mesh))
        else:
            expanded.append(e)
    return NamedSharding(mesh, resolve(mesh, tuple(expanded), dims))


def constrain_priority(x: jax.Array, batch_dims: int, candidates: list[int],
                       axis: str = "model") -> jax.Array:
    """Constrain ``x`` sharding ``axis`` onto the FIRST candidate dim whose
    size divides the axis (e.g. decode KV: prefer kv-heads, fall back to
    d_head).  Leading ``batch_dims`` dims shard over (pod, data)."""
    mesh = current_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return x
    size = mesh.shape[axis]
    entries: list = [fsdp_axes(mesh) if i < batch_dims else None
                     for i in range(x.ndim)]
    for dim in candidates:
        if x.shape[dim] % size == 0:
            entries[dim] = axis
            break
    spec = resolve(mesh, tuple(entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(params, mesh: Mesh, spec_fn) -> dict:
    """Map a pytree of (path, array/ShapeDtypeStruct) -> NamedSharding via
    ``spec_fn(path, shape) -> tuple of entries``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = []
    for path, leaf in flat:
        entries = spec_fn(path, leaf.shape)
        shardings.append(named_sharding(mesh, *entries, dims=leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def tree_shardings(tree, mesh: Mesh, spec_fn):
    """NamedSharding pytree for an arbitrary tree (caches, batches) via the
    same ``spec_fn(path, shape)`` protocol as :func:`param_sharding`."""
    return param_sharding(tree, mesh, spec_fn)


def make_cache_spec_fn(mesh: Mesh, cfg=None):
    """path/shape -> spec entries for the KV-cache pytree (dense AND paged).

    Dense K/V shard kv-heads over 'model' when divisible, else the sequence
    dim; paged pools shard the per-token kv-head axis the same way (pages
    and the slot->page table themselves are never split -- admission
    rewrites the table host-side and scatter/gather must see whole pages).
    Used by the serve engine for cache ``out_shardings`` and by the dry-run
    lowering; ``cfg`` is accepted for signature stability but the rules are
    shape/path-driven.
    """
    del cfg
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def entries(path, shape):
        name = None
        for k in reversed(path):
            kk = getattr(k, "key", None)
            if isinstance(kk, str):
                name = kk
                break
        names = [getattr(k, "key", None) for k in path]
        lead = 1 if "layers" in names else 0   # stacked per-layer caches
        core = shape[lead:]
        pre = (None,) * lead

        if isinstance(name, str) and name.endswith("_pages"):
            # page pools (pool, page_size, ...feat): shard the kv-head axis
            # of K/V payload pools; MLA latent/rope pools (3-d) replicate --
            # their feature dim contracts through the up-projection
            if len(core) == 4 and core[2] % msize == 0:
                return pre + (None, None, "model", None)
            return pre + (None,) * len(core)
        if isinstance(name, str) and name.endswith("_scales"):
            # per-token-per-head scale pools mirror their payload pool
            if len(core) == 3 and core[2] % msize == 0:
                return pre + (None, None, "model")
            return pre + (None,) * len(core)
        if name == "page_table":
            # owned by the host-side allocator mirror; every shard needs the
            # full slot->page mapping for gather/scatter index computation
            return (None,) * len(shape)
        if name in ("k", "v") and len(core) == 4:
            _, s, kvh, dh = core
            if kvh % msize == 0:
                return pre + ("batch", None, "model", None)
            if s % msize == 0:
                # sequence-sharded cache: scores come out S-sharded, softmax
                # reduces only (B,H) scalars cross-shard, PV psums (B,H,dv)
                # -- measured far cheaper than gathering the cache or
                # psum-ing dh-sharded scores (§Perf iteration 5)
                return pre + ("batch", "model", None, None)
            return pre + ("batch", None, None, None)
        if name == "c" and len(core) == 3:                 # MLA latent
            s = core[1]
            if s % msize == 0:
                return pre + ("batch", "model", None)
            return pre + ("batch", None, "model")
        if name == "k_pe":
            s = core[1]
            if s % msize == 0:
                return pre + ("batch", "model", None)
            return pre + ("batch", None, None)
        if name is not None and name.startswith("conv") and len(core) == 3:
            return pre + ("batch", None, "model")
        if name == "ssm" and len(core) == 3:               # mamba1 (B, di, N)
            return pre + ("batch", "model", None)
        if name == "ssm" and len(core) == 4:               # mamba2 (B, H, P, N)
            return pre + ("batch", "model", None, None)
        if name in ("len", "pos") and core:
            # per-slot position counters live with their slot's cache shard
            return pre + ("batch",) + (None,) * (len(core) - 1)
        if not core:
            return (None,) * len(shape)
        return pre + ("batch",) + (None,) * (len(core) - 1)

    return entries
