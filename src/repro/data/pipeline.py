"""Deterministic synthetic LM data pipeline.

Generates "documents": Zipf-distributed token runs separated by EOS, packed
into fixed-length sequences -- non-trivial enough that the loss actually
falls during the example training runs.  Determinism: batch ``i`` depends
only on (seed, i), so the iterator state is a single integer -- it rides
along in the checkpoint and a restart (even on a different mesh/host count)
resumes exactly.  ``host_slice`` carves the per-host shard of the global
batch for multi-host launches.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class SyntheticLMDataset:
    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frontend: str = "none", d_model: int = 0,
                 n_patches: int = 0,
                 host_index: int = 0, host_count: int = 1):
        assert global_batch % host_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.frontend = frontend
        self.d_model = d_model
        self.n_patches = n_patches
        self.host_index = host_index
        self.step = 0

    # ------------------------------------------------------------ state
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # ------------------------------------------------------------ batches
    def _tokens(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        # zipf-ish unigram stream with EOS-terminated runs; next-token
        # structure comes from a degree-2 markov twist so a model can learn.
        z = rng.zipf(1.3, size=(batch, self.seq_len + 1)).astype(np.int64)
        toks = z % (self.vocab - 2) + 2
        # inject short copy runs: token[t] == token[t-1] with p=0.25
        rep = rng.random((batch, self.seq_len + 1)) < 0.25
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        eos = rng.random((batch, self.seq_len + 1)) < 0.01
        toks[eos] = 1
        return toks

    def next(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step, self.host_index]))
        toks = self._tokens(rng, self.local_batch)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((self.local_batch, self.seq_len), np.float32),
        }
        if self.frontend == "audio":
            batch["embeds"] = rng.standard_normal(
                (self.local_batch, self.seq_len, self.d_model)
            ).astype(np.float32) * 0.02
        elif self.frontend == "vlm":
            batch["pixel_embeds"] = rng.standard_normal(
                (self.local_batch, self.n_patches, self.d_model)
            ).astype(np.float32) * 0.02
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next()
