"""Optimizers: pure-JAX AdamW + schedules + gradient compression."""
