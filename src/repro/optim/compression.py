"""Gradient compression: int8 quantization with error feedback.

Two entry points:

  * ``compress_with_feedback`` -- per-tensor symmetric int8 quantization plus
    an error-feedback residual carried across steps (Seide et al. / EF-SGD):
    the quantization error is added back to the next step's gradient, so the
    *accumulated* update is unbiased and convergence is preserved.

  * ``compressed_psum`` -- a shard_map-compatible all-reduce that ships int8
    instead of fp32 across the slow axis (the cross-pod DCN link in the
    multi-pod mesh): 4x less traffic on the DP gradient reduction.
    Protocol: psum(max|g|) to agree on a scale, quantize, psum(int32), scale.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, error: Any) -> tuple[Any, Any]:
    """-> (compressed_grads, new_error).  ``error`` pytree matches grads."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), corrected - dq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return comp, err


def init_error(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce for use inside shard_map over the cross-pod axis."""
    n = jax.lax.psum(1, axis_name)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    del n
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
