"""Pure-JAX AdamW with global-norm clipping, cosine schedule, and
configurable moment dtype (bf16 moments fit the 405B/671B training cells in
16 GiB/chip; fp32 is the default)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"

    @property
    def sdtype(self):
        return _DTYPES[self.state_dtype]


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.sdtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


_NO_DECAY_KEYS = ("scale", "bias", "dt_bias", "A_log", "conv_b",
                  "conv_x_b", "conv_b_b", "conv_c_b", "bq", "bk", "bv", "D")


def _decay_mask(path) -> bool:
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    return not any(str(n) in _NO_DECAY_KEYS for n in names)


def adamw_update(params: Any, grads: Any, opt_state: dict, cfg: OptConfig
                 ) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        update = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_mu.append(mu32.astype(cfg.sdtype))
        new_nu.append(nu32.astype(cfg.sdtype))

    params = jax.tree_util.tree_unflatten(treedef, new_p)
    opt_state = {
        "mu": jax.tree_util.tree_unflatten(treedef, new_mu),
        "nu": jax.tree_util.tree_unflatten(treedef, new_nu),
        "step": step,
    }
    return params, opt_state, {"lr": lr, "grad_norm": gnorm}
