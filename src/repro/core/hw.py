"""Hardware constants used by the mapper and the roofline analysis.

TPU v5e per chip (the framework's execution target):
  * 197 TFLOP/s bf16 peak (MXU)
  * 819 GB/s HBM bandwidth
  * ~50 GB/s/link ICI (per-direction, per-link)
  * ~16 GiB HBM, ~128 MiB VMEM budget per core is conservative; we tile for
    a 16 MiB working-set budget per kernel invocation.

The MXU is itself a 128x128 systolic array -- the natural "array shape" for
the paper's runtime model when reasoning about TPU GeMM mapping.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float          # FLOP/s at the compute dtype
    hbm_bw: float              # bytes/s
    ici_bw_per_link: float     # bytes/s per link
    ici_links: int             # usable links per chip (2-D torus: 4)
    hbm_bytes: float
    vmem_bytes: float
    mxu_shape: tuple[int, int]


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    mxu_shape=(128, 128),
)

# Working-set budget a single pallas_call block set should stay under.
VMEM_TILE_BUDGET = 16 * 1024**2
