"""Analytical runtime models: SCALE-SIM (Eq. 1-3) and Axon (Table 2).

The single-tile runtime decomposes into three components (paper §2.2):

  1. fill:    cycles for both operands to reach the farthest PE
              conventional SA:  R + C - 2        (Manhattan distance)
              Axon:             max(R, C) - 1    (diagonal feed, bi-directional)
  2. compute: T multiplications per PE (temporal dimension)
  3. readout: R cycles to drain outputs/partial sums

Conventional SA therefore costs ``2R + C + T - 2`` per mapped tile (Eq. 1 with
S_R=R, S_C=C) while Axon costs ``max(R, C) + R + T - 1``.  Large GeMMs tile
onto the array in scale-up (Eq. 2) or scale-out (Eq. 3) fashion.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.dataflows import ALL_DATAFLOWS, Dataflow, GemmShape, map_gemm


@dataclasses.dataclass(frozen=True)
class ArrayShape:
    """A systolic array with R rows and C columns."""

    R: int
    C: int

    def __post_init__(self) -> None:
        if self.R < 1 or self.C < 1:
            raise ValueError(f"array dims must be >= 1, got {self}")

    @property
    def pes(self) -> int:
        return self.R * self.C


def fill_latency_sa(array: ArrayShape) -> int:
    """Cycles for operands to reach the farthest PE, conventional orchestration."""
    return array.R + array.C - 2


def fill_latency_axon(array: ArrayShape) -> int:
    """Cycles for operands to reach the farthest PE, Axon orchestration.

    Operands enter at the principal diagonal and propagate bi-directionally;
    PE (i, j) starts after ``|i - j|`` cycles, so the farthest PE is at
    ``max(R, C) - 1``.
    """
    return max(array.R, array.C) - 1


def tile_runtime(array: ArrayShape, T: int, *, axon: bool,
                 overlap_readout: bool = False) -> int:
    """Runtime of one fully-mapped tile.

    ``overlap_readout=False`` is the strict Eq. 1/2 accounting
    (fill + T + readout R).  ``overlap_readout=True`` models the readout of
    tile *i* draining underneath the fill of tile *i + 1* (a standard systolic
    pipelining assumption); this is the accounting under which the paper's
    "up to 2x" fill-dominated headline holds exactly:
    ``(R + C - 2) / (max(R, C) - 1) == 2`` for square arrays.
    """
    fill = fill_latency_axon(array) if axon else fill_latency_sa(array)
    return fill + T + (0 if overlap_readout else array.R)


def _n_tiles(S_R: int, S_C: int, array: ArrayShape) -> int:
    return math.ceil(S_R / array.R) * math.ceil(S_C / array.C)


def runtime_scaleup(
    shape: GemmShape,
    array: ArrayShape,
    dataflow: Dataflow,
    *,
    axon: bool,
    overlap_readout: bool = False,
) -> int:
    """Eq. 2: one monolithic array processes all tiles serially."""
    st = map_gemm(shape, dataflow)
    per_tile = tile_runtime(array, st.T, axon=axon, overlap_readout=overlap_readout)
    total = per_tile * _n_tiles(st.S_R, st.S_C, array)
    if overlap_readout:
        total += array.R  # the last tile's drain is not hidden by anything
    return total


def runtime_scaleout(
    shape: GemmShape,
    array: ArrayShape,
    dataflow: Dataflow,
    *,
    partitions_r: int,
    partitions_c: int,
    axon: bool,
    overlap_readout: bool = False,
) -> int:
    """Eq. 3: P_R x P_C smaller arrays each process a slice of the tiles."""
    st = map_gemm(shape, dataflow)
    s_r = math.ceil(st.S_R / partitions_r)
    s_c = math.ceil(st.S_C / partitions_c)
    per_tile = tile_runtime(array, st.T, axon=axon, overlap_readout=overlap_readout)
    total = per_tile * _n_tiles(s_r, s_c, array)
    if overlap_readout:
        total += array.R
    return total


def runtime_table2(shape: GemmShape, dataflow: Dataflow, *, axon: bool) -> int:
    """Closed forms of paper Table 2 (full-size mapping, S_R = R, S_C = C).

    Only valid when the GeMM exactly fills the array (no tiling); used as a
    cross-check oracle against :func:`runtime_scaleup` in the tests.
    """
    M, K, N = shape.M, shape.K, shape.N
    if dataflow is Dataflow.OS:
        return (2 * M + N + K - 2) if not axon else (max(M, N) + M + K - 1)
    if dataflow is Dataflow.WS:
        return (2 * K + M + N - 2) if not axon else (max(M, K) + K + N - 1)
    if dataflow is Dataflow.IS:
        return (2 * K + N + M - 2) if not axon else (max(N, K) + K + M - 1)
    raise ValueError(dataflow)


def best_dataflow(
    shape: GemmShape, array: ArrayShape, *, axon: bool
) -> tuple[Dataflow, int]:
    """Pick the dataflow with the lowest scale-up runtime."""
    best: tuple[Dataflow, int] | None = None
    for df in ALL_DATAFLOWS:
        t = runtime_scaleup(shape, array, df, axon=axon)
        if best is None or t < best[1]:
            best = (df, t)
    assert best is not None
    return best


def speedup(shape: GemmShape, array: ArrayShape, dataflow: Dataflow) -> float:
    """Axon speedup over the conventional SA for the same mapping."""
    t_sa = runtime_scaleup(shape, array, dataflow, axon=False)
    t_ax = runtime_scaleup(shape, array, dataflow, axon=True)
    return t_sa / t_ax
