"""Cycle-level functional simulator for conventional and Axon orchestrations.

This validates the paper's core claim *functionally*: the Axon in-array data
orchestration (diagonal feed + bi-directional propagation, Fig. 3/4) computes
bit-exact GeMM results while filling the array in ``max(R, C) - 1`` cycles
instead of ``R + C - 2``.

The simulator models per-PE registers explicitly and advances them one cycle
at a time -- it is deliberately *not* index arithmetic, so that the register
movement rules themselves are what is under test.  Output-stationary dataflow
is simulated (the paper's hardware implementation is OS, §5.1); WS/IS runtimes
are covered by the analytical model (``runtime_model``) which the simulator
cross-checks for OS.

Also included: the on-chip im2col feeder (Fig. 3b) -- each feeder PE takes its
operand either from the SRAM buffer (1 of every ``n`` cycles) or from the
adjacent feeder PE via the 2-to-1 MUX (the other ``n - 1`` cycles), which is
what eliminates the im2col memory traffic.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SimResult:
    out: np.ndarray            # (M, N) result of the simulated tile(s)
    compute_cycles: int        # cycles until the last MAC fired
    total_cycles: int          # compute + readout (R)
    fill_cycles: int           # cycle at which the farthest PE first fired


def _stream(vec: np.ndarray, t: int, skew: int) -> float:
    """Value delivered by an operand stream at cycle ``t`` after ``skew`` zeros."""
    k = t - skew
    if 0 <= k < vec.shape[0]:
        return float(vec[k])
    return 0.0


def simulate_os(A: np.ndarray, B: np.ndarray, *, orchestration: str) -> SimResult:
    """Simulate one full-size OS tile: array shape (R, C) = (M, N).

    ``orchestration``: "sa" (left/top edge feed, uni-directional propagation)
    or "axon" (principal-diagonal feed, bi-directional propagation).
    """
    if orchestration not in ("sa", "axon"):
        raise ValueError(orchestration)
    M, K = A.shape
    K2, N = B.shape
    assert K == K2, (A.shape, B.shape)
    R, C = M, N  # full-size mapping

    acc = np.zeros((R, C), dtype=np.float64)
    a_reg = np.zeros((R, C))
    b_reg = np.zeros((R, C))
    a_valid = np.zeros((R, C), dtype=bool)
    b_valid = np.zeros((R, C), dtype=bool)

    horizon = 2 * (R + C) + K + 4  # safe upper bound; loop exits early
    mac_count = np.zeros((R, C), dtype=np.int64)
    last_mac_cycle = -1
    fill_cycle = -1
    # Farthest PE w.r.t. the feeders: bottom-right for SA; for Axon the
    # farthest is the corner maximizing |i - j| (bottom-left / top-right).
    if orchestration == "sa":
        far = (R - 1, C - 1)
    else:
        far = (R - 1, 0) if R >= C else (0, C - 1)

    diag = min(R, C)
    for t in range(horizon):
        new_a = np.zeros_like(a_reg)
        new_b = np.zeros_like(b_reg)
        new_av = np.zeros_like(a_valid)
        new_bv = np.zeros_like(b_valid)
        for i in range(R):
            for j in range(C):
                if orchestration == "sa":
                    # A enters at the left edge with row skew i, flows right.
                    if j == 0:
                        new_a[i, j] = _stream(A[i], t, skew=i)
                        new_av[i, j] = 0 <= t - i < K
                    else:
                        new_a[i, j] = a_reg[i, j - 1]
                        new_av[i, j] = a_valid[i, j - 1]
                    # B enters at the top edge with column skew j, flows down.
                    if i == 0:
                        new_b[i, j] = _stream(B[:, j], t, skew=j)
                        new_bv[i, j] = 0 <= t - j < K
                    else:
                        new_b[i, j] = b_reg[i - 1, j]
                        new_bv[i, j] = b_valid[i - 1, j]
                else:  # axon
                    # --- A: row i's stream enters at diagonal PE (i, i) and
                    # propagates bi-directionally along the row.  Rows with no
                    # diagonal PE (i >= C, tall arrays) are fed at the
                    # rightmost PE with zero padding (Fig. 5, mirrored).
                    if i < diag and j == i:
                        new_a[i, j] = _stream(A[i], t, skew=0)
                        new_av[i, j] = 0 <= t < K
                    elif i >= C and j == C - 1:
                        pad = i - (C - 1)
                        new_a[i, j] = _stream(A[i], t, skew=pad)
                        new_av[i, j] = 0 <= t - pad < K
                    elif j > i:
                        new_a[i, j] = a_reg[i, j - 1]
                        new_av[i, j] = a_valid[i, j - 1]
                    else:
                        new_a[i, j] = a_reg[i, j + 1]
                        new_av[i, j] = a_valid[i, j + 1]
                    # --- B: column j's stream enters at diagonal PE (j, j) and
                    # propagates bi-directionally along the column.  Columns
                    # with no diagonal PE (j >= R, wide arrays) are fed at the
                    # bottom PE with zero padding (Fig. 5).
                    if j < diag and i == j:
                        new_b[i, j] = _stream(B[:, j], t, skew=0)
                        new_bv[i, j] = 0 <= t < K
                    elif j >= R and i == R - 1:
                        pad = j - (R - 1)
                        new_b[i, j] = _stream(B[:, j], t, skew=pad)
                        new_bv[i, j] = 0 <= t - pad < K
                    elif i > j:
                        new_b[i, j] = b_reg[i - 1, j]
                        new_bv[i, j] = b_valid[i - 1, j]
                    else:
                        new_b[i, j] = b_reg[i + 1, j]
                        new_bv[i, j] = b_valid[i + 1, j]
        a_reg, b_reg, a_valid, b_valid = new_a, new_b, new_av, new_bv

        fire = a_valid & b_valid
        if fire.any():
            acc[fire] += a_reg[fire] * b_reg[fire]
            mac_count[fire] += 1
            last_mac_cycle = t
            if fill_cycle < 0 and fire[far]:
                fill_cycle = t
        if (mac_count == K).all():
            break

    compute_cycles = last_mac_cycle + 1
    return SimResult(
        out=acc,
        compute_cycles=compute_cycles,
        total_cycles=compute_cycles + R,  # drain/readout
        fill_cycles=fill_cycle,
    )


def full_tile_cycles(R: int, C: int, K: int, orchestration: str) -> int:
    """Closed-form total cycles of one full OS tile (fill + K + readout)."""
    if orchestration == "sa":
        return (R + C - 2) + K + R
    return (max(R, C) - 1) + K + R


def simulate_os_tiled(
    A: np.ndarray, B: np.ndarray, R: int, C: int, *, orchestration: str
) -> SimResult:
    """Scale-up simulation: tile (M, N) onto an (R, C) array, serially.

    Edge tiles still occupy a full array pass (paper Eq. 2 uses ceil factors),
    so cycle accounting always charges the full-tile cost.
    """
    M, K = A.shape
    _, N = B.shape
    out = np.zeros((M, N))
    total = 0
    compute = 0
    for i0 in range(0, M, R):
        for j0 in range(0, N, C):
            a = A[i0 : i0 + R]
            b = B[:, j0 : j0 + C]
            res = simulate_os(a, b, orchestration=orchestration)
            out[i0 : i0 + R, j0 : j0 + C] = res.out
            total += full_tile_cycles(R, C, K, orchestration)
            compute += res.compute_cycles
    return SimResult(out=out, compute_cycles=compute, total_cycles=total, fill_cycles=-1)


# ---------------------------------------------------------------------------
# On-chip im2col feeder (Fig. 3b / §3.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Im2colFeedResult:
    windows: np.ndarray   # (group, n*n) streamed conv windows, im2col order
    sram_reads: int       # elements fetched from the SRAM buffer
    mux_reads: int        # elements taken from the adjacent feeder PE


def simulate_im2col_feeders(
    ifmap: np.ndarray, n: int, *, group: int, row0: int = 0, col0: int = 0
) -> Im2colFeedResult:
    """Simulate the MUX-based feeders for ``group`` consecutive conv windows.

    ``group`` stride-1 conv windows of one OFMAP row map to ``group`` feeder
    PEs.  Each flattened window streams over ``n * n`` cycles, *rightmost
    element first* (paper Fig. 7d).  Feeder ``w > 0`` reads SRAM only on
    cycles ``t % n == 0`` (MUX control 0) and otherwise latches feeder
    ``w - 1``'s previous-cycle value (MUX control 1) -- the §3.2 schedule.
    Feeder 0 always reads SRAM.

    Returns the streamed windows re-ordered to standard im2col layout so the
    caller can verify them against a reference im2col, plus read counters.
    """
    assert ifmap.ndim == 2
    streams = np.zeros((group, n * n))
    sram_reads = 0
    mux_reads = 0

    def stream_elem(w: int, t: int) -> float:
        # Stream order: reversed row-major flattening of the window.
        flat = ifmap[row0 : row0 + n, col0 + w : col0 + w + n].reshape(-1)
        return float(flat[n * n - 1 - t])

    for t in range(n * n):
        for w in range(group):
            if w == 0 or t % n == 0:
                streams[w, t] = stream_elem(w, t)   # SRAM fetch
                sram_reads += 1
            else:
                streams[w, t] = streams[w - 1, t - 1]  # 2-to-1 MUX, neighbor
                mux_reads += 1

    # Undo the reversed stream order -> standard im2col rows.
    windows = streams[:, ::-1]
    return Im2colFeedResult(windows=windows, sram_reads=sram_reads, mux_reads=mux_reads)
