"""Workload suites from the paper (Table 3, Fig. 14, Fig. 11 / §5.2.1).

GeMM workloads are ``(M, K, N)`` exactly as printed in Table 3.  The GEMV and
depth-wise-conv suites follow Fig. 14's description (MobileNet DW layers and
selected matrix-vector shapes).  The ResNet50 / YOLOv3 conv layer lists are the
standard public architectures (He et al. 2016 @224x224; Redmon & Farhadi 2018
@416x416) used for the Fig. 11 / §5.2.1 traffic & energy numbers.

Every conv table here is cross-validated against shapes traced from the
*runnable* models in ``repro.vision`` (``vision/trace.py``, exercised by
``tests/test_vision.py``), so a transcription error in the paper-figure
inputs fails CI instead of silently skewing the analytic results.
"""
from __future__ import annotations

from repro.core.dataflows import GemmShape
from repro.core.im2col_model import ConvShape

# --- Table 3 -----------------------------------------------------------------
TABLE3: dict[str, GemmShape] = {
    "TF0": GemmShape(31999, 84, 1024),
    "TF1": GemmShape(84, 4096, 1024),
    "GNMT0": GemmShape(128, 4096, 2048),
    "GNMT1": GemmShape(2048, 32, 4096),
    "GPT3_0": GemmShape(1024, 1024, 80),
    "GPT3_1": GemmShape(1024, 2560, 7680),
    "GPT3_2": GemmShape(1024, 2560, 10240),
    "GPT3_3": GemmShape(1024, 2560, 50257),
    "NCF0": GemmShape(2048, 128, 1),
    "NCF1": GemmShape(256, 2048, 256),
    "DB0": GemmShape(1024, 50000, 16),
    "DB1": GemmShape(35, 2560, 4096),
    "Resnet50_0_conv2d": GemmShape(64, 147, 62500),
    "Resnet50_1_conv2d": GemmShape(512, 4608, 676),
    "YOLO_v3_0_conv2d": GemmShape(64, 288, 42436),
    "YOLO_v3_1_conv2d": GemmShape(128, 576, 10404),
    "GEMM_0": GemmShape(128, 10, 128),
    "GEMM_1": GemmShape(2048, 10, 2048),
    "GEMM_2": GemmShape(1024, 1024, 128),
    "GEMM_3": GemmShape(64, 2560, 2560),
}

# --- Fig. 14: memory-bound suites ---------------------------------------------
GEMV: dict[str, GemmShape] = {
    "MV_0": GemmShape(1, 1024, 4096),
    "MV_1": GemmShape(1, 4096, 4096),
    "MV_2": GemmShape(1, 2560, 7680),
    "MV_3": GemmShape(1, 8192, 1024),
}

# MobileNetV1 depth-wise layers (Howard et al. 2017, 224x224): each DW conv is
# C_in == C_out groups of 3x3x1 filters -> per-channel GeMM (1, 9, H_out*W_out).
MOBILENET_DW: list[ConvShape] = [
    ConvShape(112, 112, 32, 32, 3, stride=1, padding=1, name="dw1"),
    ConvShape(112, 112, 64, 64, 3, stride=2, padding=1, name="dw2"),
    ConvShape(56, 56, 128, 128, 3, stride=1, padding=1, name="dw3"),
    ConvShape(56, 56, 128, 128, 3, stride=2, padding=1, name="dw4"),
    ConvShape(28, 28, 256, 256, 3, stride=1, padding=1, name="dw5"),
    ConvShape(28, 28, 256, 256, 3, stride=2, padding=1, name="dw6"),
    ConvShape(14, 14, 512, 512, 3, stride=1, padding=1, name="dw7"),
    ConvShape(14, 14, 512, 512, 3, stride=2, padding=1, name="dw8"),
    ConvShape(7, 7, 1024, 1024, 3, stride=1, padding=1, name="dw9"),
]

# --- ResNet50 conv stack @224 (conv layers only; He et al. 2016) --------------
def _bottleneck(h: int, c_in: int, c_mid: int, c_out: int, stride: int,
                tag: str) -> list[ConvShape]:
    h2 = h // stride
    layers = [
        ConvShape(h, h, c_in, c_mid, 1, stride=1, padding=0, name=f"{tag}.conv1"),
        ConvShape(h, h, c_mid, c_mid, 3, stride=stride, padding=1, name=f"{tag}.conv2"),
        ConvShape(h2, h2, c_mid, c_out, 1, stride=1, padding=0, name=f"{tag}.conv3"),
    ]
    if stride != 1 or c_in != c_out:
        layers.append(
            ConvShape(h, h, c_in, c_out, 1, stride=stride, padding=0, name=f"{tag}.down")
        )
    return layers


def resnet50_convs() -> list[ConvShape]:
    convs = [ConvShape(224, 224, 3, 64, 7, stride=2, padding=3, name="conv1")]
    spec = [  # (blocks, c_mid, c_out, first_stride, in_hw)
        (3, 64, 256, 1, 56),
        (4, 128, 512, 2, 56),
        (6, 256, 1024, 2, 28),
        (3, 512, 2048, 2, 14),
    ]
    c_in = 64
    for si, (blocks, c_mid, c_out, stride0, hw) in enumerate(spec):
        h = hw
        for b in range(blocks):
            stride = stride0 if b == 0 else 1
            convs.extend(_bottleneck(h, c_in, c_mid, c_out, stride, f"l{si+1}b{b+1}"))
            h = h // stride
            c_in = c_out
    return convs


# --- YOLOv3 conv stack @416 (Darknet-53 backbone + head; Redmon 2018) ---------
def yolov3_convs() -> list[ConvShape]:
    convs: list[ConvShape] = []

    def add(h, c_in, c_out, n, stride, name):
        convs.append(ConvShape(h, h, c_in, c_out, n, stride=stride,
                               padding=n // 2, name=name))

    add(416, 3, 32, 3, 1, "conv0")
    # darknet-53 residual stages: (downsample, then `reps` x [1x1 half, 3x3 full])
    stages = [(416, 32, 64, 1), (208, 64, 128, 2), (104, 128, 256, 8),
              (52, 256, 512, 8), (26, 512, 1024, 4)]
    for h, c_in, c_out, reps in stages:
        add(h, c_in, c_out, 3, 2, f"down{c_out}")
        h2 = h // 2
        for r in range(reps):
            add(h2, c_out, c_out // 2, 1, 1, f"res{c_out}.{r}.a")
            add(h2, c_out // 2, c_out, 3, 1, f"res{c_out}.{r}.b")
    # detection head (scale 1: 13x13)
    for r in range(3):
        add(13, 1024, 512, 1, 1, f"head1.{r}.a")
        add(13, 512, 1024, 3, 1, f"head1.{r}.b")
    add(13, 1024, 255, 1, 1, "det1")
    # scale 2: upsample + concat(256+512) @26
    add(13, 512, 256, 1, 1, "up1")
    add(26, 768, 256, 1, 1, "head2.0.a")
    add(26, 256, 512, 3, 1, "head2.0.b")
    for r in range(1, 3):
        add(26, 512, 256, 1, 1, f"head2.{r}.a")
        add(26, 256, 512, 3, 1, f"head2.{r}.b")
    add(26, 512, 255, 1, 1, "det2")
    # scale 3: upsample + concat(128+256) @52
    add(26, 256, 128, 1, 1, "up2")
    add(52, 384, 128, 1, 1, "head3.0.a")
    add(52, 128, 256, 3, 1, "head3.0.b")
    for r in range(1, 3):
        add(52, 256, 128, 1, 1, f"head3.{r}.a")
        add(52, 128, 256, 3, 1, f"head3.{r}.b")
    add(52, 256, 255, 1, 1, "det3")
    return convs


# --- YOLOv3-tiny conv stack @416 (2-scale head; Redmon 2018) ------------------
def yolov3_tiny_convs() -> list[ConvShape]:
    """The 13 convs of YOLOv3-tiny (maxpools between backbone convs carry no
    weights and are excluded, like ResNet50's pool above)."""
    convs: list[ConvShape] = []

    def add(h, c_in, c_out, n, name):
        convs.append(ConvShape(h, h, c_in, c_out, n, stride=1,
                               padding=n // 2, name=name))

    backbone = [(416, 3, 16), (208, 16, 32), (104, 32, 64), (52, 64, 128),
                (26, 128, 256), (13, 256, 512)]
    for i, (h, c_in, c_out) in enumerate(backbone):
        add(h, c_in, c_out, 3, f"conv{i + 1}")
    add(13, 512, 1024, 3, "conv7")
    add(13, 1024, 256, 1, "neck")
    add(13, 256, 512, 3, "head1")
    add(13, 512, 255, 1, "det1")
    add(13, 256, 128, 1, "up1")
    add(26, 384, 256, 3, "head2")      # concat(128 upsampled + 256 route)
    add(26, 256, 255, 1, "det2")
    return convs
