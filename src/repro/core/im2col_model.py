"""Convolution lowering (im2col) and the memory-traffic model (Fig. 7 / 11).

Terminology follows the paper: a conv layer with IFMAP ``(H, W, C_in)``,
FILTER ``(n, n, C_in, C_out)``, stride ``s`` and padding ``p`` produces OFMAP
``(H_out, W_out, C_out)`` and lowers to the GeMM

    M = C_out,  K = n * n * C_in,  N = H_out * W_out            (Table 3)

Software im2col streams every element of every conv window from memory:
``N * K`` operand elements, even though consecutive stride-1 windows share
``n * (n - 1)`` of their ``n * n`` elements.  Axon's MUX chain reuses the
shared elements directly from the adjacent feeder PE, so only ``n * s``
fresh elements per window are fetched (the new columns), with the first
window of each feeder group paying the full ``n * n``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.dataflows import GemmShape


@dataclasses.dataclass(frozen=True)
class ConvShape:
    """A 2-D convolution layer."""

    H: int
    W: int
    C_in: int
    C_out: int
    n: int              # square filter size
    stride: int = 1
    padding: int = 0
    name: str = ""

    @property
    def H_out(self) -> int:
        return (self.H + 2 * self.padding - self.n) // self.stride + 1

    @property
    def W_out(self) -> int:
        return (self.W + 2 * self.padding - self.n) // self.stride + 1

    @property
    def windows(self) -> int:
        return self.H_out * self.W_out

    @property
    def macs(self) -> int:
        return self.windows * self.n * self.n * self.C_in * self.C_out


def lower_to_gemm(conv: ConvShape) -> GemmShape:
    """im2col lowering: conv -> GeMM per the paper's Table 3 convention."""
    return GemmShape(M=conv.C_out, K=conv.n * conv.n * conv.C_in, N=conv.windows)


def shared_elements(n: int) -> int:
    """Elements shared between consecutive stride-1 conv windows: n*(n-1)."""
    return n * (n - 1)


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    sw_im2col_elems: int    # operand elements streamed by software im2col
    axon_elems: int         # operand elements fetched with the MUX feeders
    filter_elems: int
    ofmap_elems: int
    reduction: float        # 1 - axon/sw (ifmap operand traffic only)


def im2col_traffic(conv: ConvShape, *, feeder_group: int = 16) -> TrafficReport:
    """Memory traffic of the lowered operand stream (Fig. 11 model).

    ``feeder_group``: how many consecutive windows share a MUX chain (the
    array dimension along which windows are mapped; 16 for the paper's
    16x16 implementation).  The first window of each group fetches all
    ``n*n*C_in`` elements; subsequent windows fetch ``n*min(s, n)*C_in``.
    """
    n, s, C = conv.n, conv.stride, conv.C_in
    sw = conv.windows * n * n * C

    fresh_follow = n * min(s, n) * C if s < n else n * n * C
    per_row = 0
    w_out = conv.W_out
    groups = math.ceil(w_out / feeder_group)
    # windows in a row are chained group by group
    full_groups, rem = divmod(w_out, feeder_group)
    sizes = [feeder_group] * full_groups + ([rem] if rem else [])
    assert len(sizes) == groups
    for g in sizes:
        per_row += n * n * C + (g - 1) * fresh_follow
    axon = conv.H_out * per_row

    return TrafficReport(
        sw_im2col_elems=sw,
        axon_elems=axon,
        filter_elems=conv.n * conv.n * conv.C_in * conv.C_out,
        ofmap_elems=conv.windows * conv.C_out,
        reduction=1.0 - axon / sw,
    )


def model_traffic(
    convs: list[ConvShape],
    *,
    bytes_per_elem: int = 2,
    feeder_group: int = 16,
    include_filter_ofmap: bool = False,
) -> tuple[float, float]:
    """Total (sw, axon) traffic in bytes over a conv layer list.

    The paper's Fig. 11 / §5.2.1 reductions count the lowered *operand
    stream* (the part im2col repeats); filters and OFMAP writes are identical
    under both schemes and excluded by default.
    """
    sw = 0
    ax = 0
    for c in convs:
        t = im2col_traffic(c, feeder_group=feeder_group)
        extra = (t.filter_elems + t.ofmap_elems) if include_filter_ofmap else 0
        sw += (t.sw_im2col_elems + extra) * bytes_per_elem
        ax += (t.axon_elems + extra) * bytes_per_elem
    return float(sw), float(ax)
