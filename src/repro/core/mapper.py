"""The Axon mapper: the paper's runtime model promoted to a framework feature.

Two roles:

1. **ASIC mapping** (faithful reproduction): given a GeMM and an array shape,
   pick the dataflow (OS/WS/IS) and scale-up/out partitioning minimizing the
   analytical runtime -- with or without the Axon orchestration.

2. **TPU mapping** (hardware adaptation): given a GeMM and the TPU's VMEM /
   MXU constraints, pick Pallas block shapes ``(bm, bk, bn)`` and the grid
   loop order.  The paper's insight transfers as follows:

   * the *fill latency* term maps to the pipeline prologue of the blocked
     kernel -- the number of HBM->VMEM block DMAs that must complete before
     the MXU can start.  Axon's diagonal feed halves it in the array; on TPU
     we minimize it by double-buffered prefetch and by choosing the loop
     order whose *stationary* operand is the largest (fewest re-fetches).
   * OS/WS/IS map to which operand block stays VMEM-resident across the
     innermost grid dimension:  OS = accumulator resident (K innermost),
     WS = B-block resident (M innermost), IS = A-block resident (N innermost).

   The selection minimizes modeled HBM traffic, which on a 197 TF / 819 GB/s
   chip is the binding constraint for everything but large square GeMMs.
"""
from __future__ import annotations

import dataclasses
import functools
import math

from repro.core import hw
from repro.core.dataflows import ALL_DATAFLOWS, Dataflow, GemmShape
from repro.core.runtime_model import ArrayShape, runtime_scaleup
from repro.obs import metrics as _obs_metrics, optrace as _obs


# ---------------------------------------------------------------------------
# Role 1: ASIC mapping (paper-faithful)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsicMapping:
    dataflow: Dataflow
    cycles: int
    axon: bool
    array: ArrayShape


def select_asic_mapping(shape: GemmShape, array: ArrayShape, *, axon: bool) -> AsicMapping:
    best: AsicMapping | None = None
    for df in ALL_DATAFLOWS:
        t = runtime_scaleup(shape, array, df, axon=axon)
        if best is None or t < best.cycles:
            best = AsicMapping(dataflow=df, cycles=t, axon=axon, array=array)
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Role 2: TPU / Pallas mapping
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuBlocking:
    bm: int
    bk: int
    bn: int
    loop_order: Dataflow       # which operand is stationary (see module doc)
    hbm_traffic_bytes: int     # modeled operand traffic for this blocking
    vmem_bytes: int            # resident working set


def _round_block(dim: int, target: int, multiple: int) -> int:
    """Largest multiple of ``multiple`` <= min(dim_padded, target)."""
    b = min(dim, target)
    b = max(multiple, (b // multiple) * multiple)
    return b


def modeled_traffic(shape: GemmShape, bm: int, bk: int, bn: int,
                    loop_order: Dataflow, bytes_per_elem: int = 2) -> int:
    """HBM operand traffic of a blocked GeMM under a given loop order.

    grid = (Mt, Nt, Kt) tiles.  The stationary operand is read once; the
    streaming operands are re-read once per tile of the outer dims:

      OS (K innermost):  A read Nt times, B read Mt times, C written once.
      WS (M innermost):  B read once,    A read Nt times, C written Kt times
                         (partial sums re-materialized unless Kt == 1).
      IS (N innermost):  A read once,    B read Mt times, C written Kt times.
    """
    Mt = math.ceil(shape.M / bm)
    Nt = math.ceil(shape.N / bn)
    Kt = math.ceil(shape.K / bk)
    a = shape.M * shape.K * bytes_per_elem
    b = shape.K * shape.N * bytes_per_elem
    c = shape.M * shape.N * bytes_per_elem
    if loop_order is Dataflow.OS:
        return a * Nt + b * Mt + c
    if loop_order is Dataflow.WS:
        return b + a * Nt + c * max(Kt, 1)
    if loop_order is Dataflow.IS:
        return a + b * Mt + c * max(Kt, 1)
    raise ValueError(loop_order)


def select_tpu_blocking(
    shape: GemmShape,
    *,
    bytes_per_elem: int = 2,
    vmem_budget: int = hw.VMEM_TILE_BUDGET,
    chip: hw.ChipSpec = hw.TPU_V5E,
) -> TpuBlocking:
    """Pick (bm, bk, bn) + loop order minimizing modeled HBM traffic.

    Blocks are multiples of the MXU tile (128) where the dim allows; the
    fp32 accumulator (bm x bn x 4B) plus both operand blocks (double
    buffered) must fit the VMEM budget.

    Decisions are LRU-cached per (shape, bytes_per_elem, budget, chip): the
    exhaustive candidate sweep runs once per unique key per process, not per
    call (``repro.axon`` dispatches every model contraction through here).
    """
    return _select_tpu_blocking_cached(shape, bytes_per_elem, vmem_budget,
                                       chip)


def mapper_cache_info():
    """(hits, misses, maxsize, currsize) of the blocking-decision cache."""
    return _select_tpu_blocking_cached.cache_info()


def mapper_cache_stats() -> dict:
    """The cache counters as a stats dict (what the engines report):
    a falling ``hit_rate`` or rising ``entries`` across a fixed-shape run
    is a retrace/shape-churn regression showing up in numbers."""
    hits, misses, _, currsize = mapper_cache_info()
    total = hits + misses
    return {"hits": hits, "misses": misses,
            "hit_rate": hits / total if total else 0.0,
            "entries": currsize, "sweeps": _sweep_calls}


def mapper_cache_clear() -> None:
    """Drop cached decisions and reset the sweep counter (for tests/benches)."""
    global _sweep_calls
    _select_tpu_blocking_cached.cache_clear()
    _sweep_calls = 0


_sweep_calls = 0


def sweep_calls() -> int:
    """How many times the candidate sweep actually ran (cache misses)."""
    return _sweep_calls


@functools.lru_cache(maxsize=4096)
def _select_tpu_blocking_cached(
    shape: GemmShape,
    bytes_per_elem: int,
    vmem_budget: int,
    chip: hw.ChipSpec,
) -> TpuBlocking:
    global _sweep_calls
    _sweep_calls += 1
    if _obs.enabled():
        _obs_metrics.counter(
            "mapper_sweeps_total",
            "analytic blocking sweeps (mapper cache misses)").inc()
    lane = chip.mxu_shape[0]
    candidates = []
    for bm in (128, 256, 512):
        for bn in (128, 256, 512):
            for bk in (128, 256, 512, 1024, 2048):
                bm_ = _round_block(shape.M, bm, min(lane, _pow2_floor(shape.M)))
                bn_ = _round_block(shape.N, bn, min(lane, _pow2_floor(shape.N)))
                bk_ = _round_block(shape.K, bk, min(lane, _pow2_floor(shape.K)))
                acc = bm_ * bn_ * 4
                operands = 2 * (bm_ * bk_ + bk_ * bn_) * bytes_per_elem  # 2x: dbl buffer
                vmem = acc + operands
                if vmem > vmem_budget:
                    continue
                for order in ALL_DATAFLOWS:
                    traffic = modeled_traffic(shape, bm_, bk_, bn_, order,
                                              bytes_per_elem)
                    candidates.append(
                        TpuBlocking(bm=bm_, bk=bk_, bn=bn_, loop_order=order,
                                    hbm_traffic_bytes=traffic, vmem_bytes=vmem)
                    )
    if not candidates:
        # degenerate small problem: single block
        bm_, bk_, bn_ = shape.M, shape.K, shape.N
        return TpuBlocking(bm=bm_, bk=bk_, bn=bn_, loop_order=Dataflow.OS,
                           hbm_traffic_bytes=modeled_traffic(
                               shape, bm_, bk_, bn_, Dataflow.OS, bytes_per_elem),
                           vmem_bytes=0)
    # prefer lowest traffic; tie-break towards larger blocks (fewer grid steps)
    candidates.sort(key=lambda c: (c.hbm_traffic_bytes, -(c.bm * c.bn * c.bk)))
    return candidates[0]


def _pow2_floor(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return min(p, 128) if p >= 1 else 1
