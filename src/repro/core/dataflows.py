"""Dataflow definitions and GeMM-dimension mappings (paper Table 1).

A GeMM multiplies ``A (M, K) @ B (K, N) -> C (M, N)``.  A systolic array of
shape ``(R, C)`` maps two of the three dimensions spatially (``S_R``, ``S_C``)
and streams the third temporally (``T``):

    OS:  (S_R = M, S_C = N, T = K)   outputs stay in PEs
    WS:  (S_R = K, S_C = M, T = N)   weights preloaded, stay in PEs
    IS:  (S_R = K, S_C = N, T = M)   inputs preloaded, stay in PEs
"""
from __future__ import annotations

import dataclasses
import enum


class Dataflow(enum.Enum):
    OS = "os"  # output stationary
    WS = "ws"  # weight stationary
    IS = "is"  # input stationary


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """A GeMM problem ``(M, K) @ (K, N)``."""

    M: int
    K: int
    N: int

    def __post_init__(self) -> None:
        if min(self.M, self.K, self.N) < 1:
            raise ValueError(f"GeMM dims must be >= 1, got {self}")

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def flops(self) -> int:
        return 2 * self.macs


@dataclasses.dataclass(frozen=True)
class SpatioTemporal:
    """Projection of a GeMM onto (spatial-rows, spatial-cols, temporal)."""

    S_R: int
    S_C: int
    T: int


def map_gemm(shape: GemmShape, dataflow: Dataflow) -> SpatioTemporal:
    """Paper Table 1: project GeMM dims onto the array's spatiotemporal dims."""
    if dataflow is Dataflow.OS:
        return SpatioTemporal(S_R=shape.M, S_C=shape.N, T=shape.K)
    if dataflow is Dataflow.WS:
        return SpatioTemporal(S_R=shape.K, S_C=shape.M, T=shape.N)
    if dataflow is Dataflow.IS:
        return SpatioTemporal(S_R=shape.K, S_C=shape.N, T=shape.M)
    raise ValueError(f"unknown dataflow {dataflow}")


ALL_DATAFLOWS = (Dataflow.OS, Dataflow.WS, Dataflow.IS)
