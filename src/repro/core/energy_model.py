"""Energy / power / area models calibrated to the paper's measurements.

Calibration points (paper §5.1, §5.2, Figs. 10 & 15):

  * ASAP7 16x16 FP16 array @ 550 MHz, 0.7 V:
      - conventional SA:        0.9992 mm^2, 59.88 mW
      - Axon (no im2col):       0.9931 mm^2 (buffer sharing on the diagonal)
      - Axon + im2col support:  0.9951 mm^2, 59.98 mW
        => 0.211 % area and 1.6 % power overhead vs conventional SA's area
           baseline; im2col adds 0.2 % area on top of Axon.
      - peak 284 GFLOP/s, 4.73 TFLOP/sW
  * DRAM: 32-bit LPDDR3 @ 800 MHz, 6.4 GB/s, 120 pJ/byte (DRAMPower).
  * Zero gating: 5.3 % total power reduction at 10 % sparsity
        => the MAC datapath is ~53 % of total power (skip rate x 0.53).
  * vs SAURIA im2col feeder: Axon is 3.93 % smaller and burns 4.5 % less
    power on average across nodes/shapes (Fig. 15).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AsicSpec:
    """The paper's implemented 16x16 Axon chip (Fig. 10)."""

    technology: str = "ASAP7"
    array: tuple[int, int] = (16, 16)
    freq_hz: float = 550e6
    voltage_v: float = 0.7
    area_sa_mm2: float = 0.9992
    area_axon_mm2: float = 0.9931
    area_axon_im2col_mm2: float = 0.9951
    power_sa_w: float = 59.88e-3
    power_axon_im2col_w: float = 59.98e-3
    peak_flops: float = 284e9
    peak_eff_flops_per_w: float = 4.73e12


PAPER_ASIC = AsicSpec()

DRAM_ENERGY_PJ_PER_BYTE = 120.0
DRAM_BANDWIDTH_BYTES = 6.4e9

MAC_POWER_FRACTION = 0.53  # calibrated: 10 % sparsity -> 5.3 % power reduction

# Operand width per precision: the paper's traffic/energy accounting is per
# DRAM byte, so switching the serving dtype rescales traffic (and the
# memory-bound side of the runtime roofline) by these ratios directly.
# fp8 (e4m3) streams at int8 width; int4 packs two operands per byte
# (``repro.quant``'s nibble packing), hence the half-byte entry.
OPERAND_BYTES = {"fp32": 4, "fp16": 2, "bf16": 2, "int8": 1, "fp8": 1,
                 "int4": 0.5}


def operand_bytes(precision: str) -> float:
    """Bytes per operand element for a serving precision (0.5 for packed
    int4)."""
    try:
        return OPERAND_BYTES[precision]
    except KeyError:
        raise ValueError(
            f"precision must be one of {sorted(OPERAND_BYTES)}, "
            f"got {precision!r}") from None


def precision_traffic_ratio(precision: str, baseline: str = "bf16") -> float:
    """DRAM-traffic (= DRAM-energy) scale factor of ``precision`` operands
    relative to ``baseline`` operands for the same layer stream."""
    return operand_bytes(precision) / operand_bytes(baseline)


def area_overhead_im2col() -> float:
    """Fractional area overhead of Axon+im2col vs the conventional SA."""
    s = PAPER_ASIC
    return (s.area_axon_im2col_mm2 - s.area_axon_mm2) / s.area_axon_mm2


def power_overhead_im2col() -> float:
    s = PAPER_ASIC
    return (s.power_axon_im2col_w - s.power_sa_w) / s.power_sa_w


def zero_gating_power_reduction(sparsity_ifmap: float, sparsity_filter: float = 0.0) -> float:
    """Fraction of total power saved by skipping MACs with a zero operand.

    A MAC is skipped when either operand is zero; assuming independence the
    skip rate is ``1 - (1 - s_a) * (1 - s_w)``.
    """
    if not (0 <= sparsity_ifmap <= 1 and 0 <= sparsity_filter <= 1):
        raise ValueError("sparsity must be in [0, 1]")
    skip = 1.0 - (1.0 - sparsity_ifmap) * (1.0 - sparsity_filter)
    return MAC_POWER_FRACTION * skip


def dram_energy_joules(traffic_bytes: float) -> float:
    return traffic_bytes * DRAM_ENERGY_PJ_PER_BYTE * 1e-12


def memory_bound_time_s(traffic_bytes: float, bandwidth: float = DRAM_BANDWIDTH_BYTES) -> float:
    return traffic_bytes / bandwidth


def bounded_runtime_s(compute_cycles: int, traffic_bytes: float,
                      freq_hz: float = PAPER_ASIC.freq_hz,
                      bandwidth: float = DRAM_BANDWIDTH_BYTES) -> float:
    """max(compute, memory) roofline-style bound used for the 1.25x claim."""
    return max(compute_cycles / freq_hz, memory_bound_time_s(traffic_bytes, bandwidth))
