"""Axon core: the paper's contribution as reusable models + mapper.

Public surface:
  dataflows      -- OS/WS/IS GeMM projections (Table 1)
  runtime_model  -- SCALE-SIM Eq.1-3 + Axon Table 2 runtimes
  axon_sim       -- cycle-level functional simulator (Fig. 3/4 validation)
  im2col_model   -- conv lowering + memory-traffic model (Fig. 7/11)
  energy_model   -- ASIC power/area/DRAM-energy calibration (Fig. 10/15)
  cmsa_model     -- CMSA comparison model (Fig. 13)
  utilization    -- PE utilization-rate model
  mapper         -- dataflow/tiling selection (ASIC + TPU/Pallas roles)
  workloads      -- Table 3 / Fig. 14 / Fig. 11 workload suites
  hw             -- TPU v5e hardware constants
"""
from repro.core.dataflows import ALL_DATAFLOWS, Dataflow, GemmShape, map_gemm
from repro.core.runtime_model import (
    ArrayShape,
    best_dataflow,
    fill_latency_axon,
    fill_latency_sa,
    runtime_scaleout,
    runtime_scaleup,
    runtime_table2,
    speedup,
)

__all__ = [
    "ALL_DATAFLOWS",
    "ArrayShape",
    "Dataflow",
    "GemmShape",
    "best_dataflow",
    "fill_latency_axon",
    "fill_latency_sa",
    "map_gemm",
    "runtime_scaleout",
    "runtime_scaleup",
    "runtime_table2",
    "speedup",
]
