"""PE utilization-rate model (paper §5.2.2, Fig. 13).

Utilization rate (UR) = useful MAC-cycles / (PEs x total runtime cycles).
The useful work for a GeMM is exactly ``M * K * N`` MACs regardless of the
orchestration, so UR differences come entirely from the runtime denominator
(fill latency, skew, tiling slack).
"""
from __future__ import annotations

from repro.core.dataflows import Dataflow, GemmShape
from repro.core.runtime_model import ArrayShape, runtime_scaleup


def utilization(
    shape: GemmShape,
    array: ArrayShape,
    dataflow: Dataflow = Dataflow.OS,
    *,
    axon: bool,
) -> float:
    cycles = runtime_scaleup(shape, array, dataflow, axon=axon)
    return shape.macs / (array.pes * cycles)


def utilization_improvement(
    shape: GemmShape,
    array: ArrayShape,
    dataflow: Dataflow = Dataflow.OS,
    *,
    axon: bool,
) -> float:
    """UR improvement over the conventional SA (what Fig. 13 plots)."""
    base = utilization(shape, array, dataflow, axon=False)
    ur = utilization(shape, array, dataflow, axon=axon)
    return (ur - base) / base
