"""Approximate analytical model of CMSA (Xu et al., TACO 2021) for Fig. 13.

CMSA augments a conventional systolic array with an *additional datapath* so
one operand can stream from both opposing edges (the "multi-directional"
modes).  Data still enters at the array edges -- not at the diagonal -- so the
fill latency improves in one dimension only:

    fill_cmsa = R/2 + C - 2        (vs  R + C - 2  conventional,
                                    vs  max(R, C) - 1  Axon)

For a square array this sits exactly between the conventional SA and Axon,
which is the qualitative relationship Fig. 13 reports (Axon's utilization-rate
improvement exceeds CMSA's by ~27 % on average at 128x128).  We document this
as an approximation of the published design (DESIGN.md §7): we do not model
CMSA's per-mode control or its tile-packing for sub-array workloads.
"""
from __future__ import annotations

import math

from repro.core.dataflows import Dataflow, GemmShape, map_gemm
from repro.core.runtime_model import ArrayShape, _n_tiles


def fill_latency_cmsa(array: ArrayShape) -> int:
    return math.ceil(array.R / 2) + array.C - 2


def runtime_cmsa(
    shape: GemmShape,
    array: ArrayShape,
    dataflow: Dataflow = Dataflow.OS,
    *,
    overlap_readout: bool = False,
) -> int:
    st = map_gemm(shape, dataflow)
    per_tile = fill_latency_cmsa(array) + st.T + (0 if overlap_readout else array.R)
    total = per_tile * _n_tiles(st.S_R, st.S_C, array)
    if overlap_readout:
        total += array.R
    return total


def utilization_cmsa(shape: GemmShape, array: ArrayShape,
                     dataflow: Dataflow = Dataflow.OS) -> float:
    return shape.macs / (array.pes * runtime_cmsa(shape, array, dataflow))


def utilization_improvement_cmsa(shape: GemmShape, array: ArrayShape,
                                 dataflow: Dataflow = Dataflow.OS) -> float:
    from repro.core.utilization import utilization

    base = utilization(shape, array, dataflow, axon=False)
    ur = utilization_cmsa(shape, array, dataflow)
    return (ur - base) / base
