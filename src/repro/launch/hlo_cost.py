"""Loop-corrected HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified on this jax/XLA build), so any scanned program -- layers,
microbatches, attention chunks -- is undercounted by orders of magnitude.

This walker parses the optimized HLO text and:
  1. builds the computation tree and a trip-count multiplier per computation
     (while bodies multiply by their trip count, parsed from the loop
     condition's comparison constant; conditional branches inherit the
     parent multiplier -- an upper bound for data-dependent branches),
  2. sums dot FLOPs (2 x output elems x contraction size) and dot operand
     bytes with those multipliers -- dots dominate both compute and HBM
     traffic in these models,
  3. sums collective operand bytes (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute) with the same multipliers.

Everything is per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COMP_RE = re.compile(r"^(?:ENTRY )?%([\w.\-]+) \(.*\{\s*$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT )?%([\w.\-]+) = (.+)$")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_COND_RE = re.compile(r"conditional\(")
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
# operands may carry inline types: dot(f32[128,64]{1,0} %lhs, f32[...] %rhs)
_OPERAND = r"(?:\w+\[[\d,]*\](?:\{[^}]*\})? )?%?([\w.\-]+)"
_DOT_RE = re.compile(
    r"= (\w+)\[([\d,]*)\][^=]*? dot\(" + _OPERAND + r", " + _OPERAND
    + r"\)(.*)$")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_RE = re.compile(
    r"= (\w+)\[([\d,]*)\][^=]*? (all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(([^)]*)\)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: dict | None = None
    while_loops: int = 0


def _split_computations(text: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.startswith("}"):
                current = None
            else:
                comps[current].append(line)
    return comps


def _shape_map(text: str) -> dict[str, tuple[str, int]]:
    shapes = {}
    for line in text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        sm = _SHAPE_RE.match(rhs)
        if sm:
            dt, dims = sm.groups()
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            shapes[name] = (dt, n)
        # parameters: "%p = bf16[...]{...} parameter(0)" matched above too
    return shapes


def _trip_count(cond_lines: list[str]) -> int:
    consts = []
    for line in cond_lines:
        consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    shapes = _shape_map(text)

    # ---- multipliers: BFS from the entry computation -----------------------
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY "):
            m = re.match(r"ENTRY %?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: computation with the most instructions
        entry = max(comps, key=lambda k: len(comps[k]))

    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    n_while = 0
    seen = {entry}
    while order:
        cur = order.pop(0)
        m_cur = mult[cur]
        for line in comps.get(cur, []):
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                n_while += 1
                for target in (body, cond):
                    if target in comps:
                        mult[target] = max(mult.get(target, 0.0), m_cur * trips)
                        if target not in seen:
                            seen.add(target)
                            order.append(target)
                continue
            bm = _BRANCHES_RE.search(line)
            if bm:
                for br in bm.group(1).split(","):
                    br = br.strip().lstrip("%")
                    if br in comps:
                        mult[br] = max(mult.get(br, 0.0), m_cur)
                        if br not in seen:
                            seen.add(br)
                            order.append(br)
                continue
            cm = _CALLED_RE.search(line)
            if cm and "fusion" in line or cm and "call(" in line:
                tgt = cm.group(1)
                if tgt in comps:
                    mult[tgt] = max(mult.get(tgt, 0.0), m_cur)
                    if tgt not in seen:
                        seen.add(tgt)
                        order.append(tgt)

    # ---- cost sweep ---------------------------------------------------------
    cost = HloCost(collective_bytes_by_kind={})
    for comp, lines in comps.items():
        m_comp = mult.get(comp)
        if m_comp is None:
            # not reachable from entry via while/cond/fusion edges: reductions
            # etc. -- count once if referenced at all
            m_comp = 1.0
        for line in lines:
            dm = _DOT_RE.search(line)
            if dm:
                out_dt, out_dims, lhs, rhs, tail = dm.groups()
                out_n = 1
                for d in out_dims.split(","):
                    if d:
                        out_n *= int(d)
                k = 1
                cm = _CONTRACT_RE.search(tail)
                lhs_shape = _find_operand_dims(lines, shapes, lhs, line)
                if cm and lhs_shape:
                    for idx in cm.group(1).split(","):
                        if idx:
                            k *= lhs_shape[int(idx)]
                cost.dot_flops += m_comp * 2.0 * out_n * k
                lhs_n = shapes.get(lhs, ("f32", 0))[1]
                rhs_n = shapes.get(rhs, ("f32", 0))[1]
                lhs_b = _DTYPE_BYTES.get(shapes.get(lhs, ("f32", 0))[0], 4)
                rhs_b = _DTYPE_BYTES.get(shapes.get(rhs, ("f32", 0))[0], 4)
                out_b = _DTYPE_BYTES.get(out_dt, 4)
                cost.dot_bytes += m_comp * (lhs_n * lhs_b + rhs_n * rhs_b
                                            + out_n * out_b)
                continue
            cm2 = _COLL_RE.search(line)
            if cm2:
                res_dt, res_dims, kind, operands = cm2.groups()
                b = 0
                found = False
                for op in operands.split(","):
                    op = op.strip().lstrip("%")
                    if op in shapes:
                        dt, n = shapes[op]
                        b += n * _DTYPE_BYTES.get(dt, 4)
                        found = True
                if not found:
                    n = 1
                    for d in res_dims.split(","):
                        if d:
                            n *= int(d)
                    b = n * _DTYPE_BYTES.get(res_dt, 4)
                cost.collective_bytes += m_comp * b
                kinds = cost.collective_bytes_by_kind
                kinds[kind] = kinds.get(kind, 0.0) + m_comp * b
    cost.while_loops = n_while
    return cost


def _find_operand_dims(lines, shapes, name, line) -> list[int] | None:
    # dims of an operand, from the global def map (shape list, not count)
    for ln in lines:
        m = re.match(rf"^\s+(?:ROOT )?%{re.escape(name)} = (\w+)\[([\d,]*)\]", ln)
        if m:
            return [int(d) for d in m.group(2).split(",") if d]
    # global search fallback
    if name in shapes:
        # only element count known; reconstruct not possible -> None
        pass
    return None
