"""Serving launcher: continuous-batching (or wave-batched) generation demo."""
from __future__ import annotations

import argparse
import os
import sys


def _apply_fake_devices(argv) -> None:
    """``--fake-devices N`` must take effect before jax initialises its
    backend (XLA reads the flag exactly once), so it is applied here at
    import time from the raw argv, ahead of the ``import jax`` below."""
    for i, a in enumerate(argv):
        if a == "--fake-devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif a.startswith("--fake-devices="):
            n = a.split("=", 1)[1]
        else:
            continue
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={int(n)}")
        return


_apply_fake_devices(sys.argv)

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serve.engine import (
    QUEUE_POLICIES,
    Request,
    ServeEngine,
    WaveServeEngine,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--engine", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--mixed", action="store_true",
                    help="alternate short/3x-long prompts (shows the "
                         "head-of-line win of continuous batching)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--queue-policy", choices=QUEUE_POLICIES, default="fifo")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="shard the engine over a device mesh, e.g. 2x4 "
                         "(data=2, model=4); requires data*model <= "
                         "jax.device_count()")
    ap.add_argument("--decouple-prefill", action="store_true",
                    help="run prompts through a dedicated prefill step and "
                         "hand the cache to a decode slot via a jitted "
                         "insert (dense caches only)")
    ap.add_argument("--fake-devices", type=int, default=None,
                    help="fake N host devices (XLA "
                         "--xla_force_host_platform_device_count; applied "
                         "before jax backend init) for trying --mesh on CPU")
    ap.add_argument("--trace-out", default=None,
                    help="enable repro.obs and write a Chrome-trace JSON "
                         "(load at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable repro.obs and write a metrics snapshot")
    ap.add_argument("--prom-out", default=None,
                    help="enable repro.obs and write the Prometheus text "
                         "exposition")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace into this "
                         "directory (named scopes nest under serve steps)")
    ap.add_argument("--sample-every", type=int, default=1,
                    help="op-ring sampling stride (counters stay exact)")
    ap.add_argument("--stream-dir", default=None,
                    help="stream periodic metric snapshots (JSONL + prom "
                         "textfile) into this directory during the serve")
    ap.add_argument("--stream-interval", type=float, default=None,
                    help="seconds between streaming snapshots")
    args = ap.parse_args()

    obs_on = bool(args.trace_out or args.metrics_out or args.prom_out
                  or args.stream_dir or args.profile_dir
                  or args.sample_every > 1)
    if obs_on:
        import repro.obs as obs
        from repro.obs import profiler, streaming
        obs.enable()
        obs.configure(sample_every=args.sample_every)
        if args.profile_dir:
            profiler.start(args.profile_dir)
        if args.stream_dir:
            interval = args.stream_interval \
                if args.stream_interval is not None \
                else streaming.DEFAULT_INTERVAL_S
            streaming.start(args.stream_dir, interval_s=interval)

    from repro.launch.mesh import parse_mesh
    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {mesh.size} of "
              f"{jax.device_count()} device(s)")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    slots = min(args.batch_slots, args.requests)
    max_prompt = args.prompt_len * (3 if args.mixed else 1)
    max_len = max_prompt + args.max_new + 1
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        plen = (args.prompt_len * (3 if args.mixed and i % 2 else 1))
        prompt = jax.random.randint(sub, (plen,), 2, cfg.vocab)
        reqs.append(Request(prompt=[int(t) for t in prompt],
                            max_new_tokens=args.max_new))

    if args.engine == "wave":
        engine = WaveServeEngine(params, cfg, batch_slots=slots,
                                 max_len=max_len,
                                 temperature=args.temperature)
    else:
        engine = ServeEngine(params, cfg, batch_slots=slots, max_len=max_len,
                             prefill_chunk=args.prefill_chunk,
                             queue_policy=args.queue_policy,
                             temperature=args.temperature, mesh=mesh,
                             decouple_prefill=args.decouple_prefill)
    outs = engine.generate(reqs)
    for i, o in enumerate(outs):
        print(f"req{i} ({len(reqs[i].prompt)}-token prompt): {o}")
    stats = getattr(engine, "last_stats", None)
    if stats:
        lat = [r["latency_s"] for r in stats["requests"]]
        print(f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tokens_per_s']:.1f} tok/s, "
              f"{stats['steps']} steps, p50 latency {np.percentile(lat, 50):.2f}s, "
              f"p99 {np.percentile(lat, 99):.2f}s)")

    if stats and "attribution" in stats:
        att = stats["attribution"]
        print(f"attribution: modeled {att['modeled_flops']:.3g} FLOPs, "
              f"step coverage {att['modeled_step_coverage']:.0%}, "
              f"roofline {att['roofline']}")

    if obs_on:
        import repro.obs as obs
        from repro.obs import profiler, streaming
        if args.profile_dir and profiler.stop():
            print(f"profiler trace in {args.profile_dir}")
        if args.stream_dir:
            streaming.stop()
            print(f"streamed snapshots in {args.stream_dir}")
        if args.trace_out:
            obs.write_chrome_trace(args.trace_out, process_name="serve")
            print(f"trace written to {args.trace_out}")
        if args.metrics_out:
            obs.REGISTRY.write_json(args.metrics_out)
            print(f"metrics written to {args.metrics_out}")
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(obs.metrics.prometheus_text())
            print(f"prometheus exposition written to {args.prom_out}")


if __name__ == "__main__":
    main()
