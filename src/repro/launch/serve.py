"""Serving launcher: batched greedy/temperature generation demo."""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_slots=min(8, args.requests),
                         max_len=args.prompt_len + args.max_new + 1,
                         temperature=args.temperature)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (args.prompt_len,), 2, cfg.vocab)
        reqs.append(Request(prompt=[int(t) for t in prompt],
                            max_new_tokens=args.max_new))
    outs = engine.generate(reqs)
    for i, o in enumerate(outs):
        print(f"req{i}: {o}")


if __name__ == "__main__":
    main()
