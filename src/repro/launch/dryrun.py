import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init).  For each cell this script:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod)
  2. constructs ShapeDtypeStruct stand-ins (no allocation) for the train /
     prefill / decode step's inputs, with NamedShardings from the framework's
     sharding rules
  3. ``jit(step).lower(...).compile()`` -- sharding mismatches, compile-time
     OOM and unsupported collectives all surface here
  4. records ``memory_analysis()`` (per-device bytes: proves it fits),
     ``cost_analysis()`` (per-device FLOPs/bytes) and the per-collective
     byte totals parsed from the optimized HLO -> JSON for §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out benchmarks/results/dryrun]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeCfg
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.memory_model import expected_device_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.specs import make_param_spec_fn
from repro.train.train_step import init_train_state, make_train_step

# ---------------------------------------------------------------------------
# cell policy
# ---------------------------------------------------------------------------

# pure full-attention archs skip long_500k (DESIGN.md §4); SSM/hybrid/SWA run.
LONG_OK = {"falcon-mamba-7b", "zamba2-7b", "mixtral-8x7b"}

# per-(arch, shape) training microbatch counts sized for 16 GiB/chip
MICROBATCH = {
    ("llama3-405b", "train_4k"): 16,
    ("deepseek-v3-671b", "train_4k"): 8,
    ("deepseek-coder-33b", "train_4k"): 4,
    ("qwen2.5-14b", "train_4k"): 2,
    ("yi-9b", "train_4k"): 2,
    ("mixtral-8x7b", "train_4k"): 4,
    ("zamba2-7b", "train_4k"): 2,
    ("falcon-mamba-7b", "train_4k"): 4,
}

# archs whose optimizer moments are kept in bf16 to fit 16 GiB/chip
BF16_OPT = {"llama3-405b", "deepseek-v3-671b"}


OVERRIDES: dict = {}   # hillclimb levers, set by --set key=value


def dryrun_config(arch: str) -> ModelConfig:
    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg, param_dtype="bfloat16", compute_dtype="bfloat16", remat=True)
    if OVERRIDES:
        cfg = dataclasses.replace(cfg, **OVERRIDES)
    return cfg


def cells(multi_pod: bool) -> list[tuple[str, str]]:
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            out.append((arch, shape))
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------


def batch_sharding(mesh, shape_dims):
    return shd.named_sharding(mesh, "batch", *([None] * (len(shape_dims) - 1)),
                              dims=shape_dims)


def input_specs(cfg: ModelConfig, shape: ShapeCfg, mesh, *,
                seq: int | None = None) -> dict:
    """ShapeDtypeStructs for the data batch of one step.

    ``seq`` overrides the token width (e.g. the continuous engine's chunked
    prefill step feeds C tokens/slot into a decode-shaped cell)."""
    B = shape.global_batch
    S = seq if seq is not None else (
        shape.seq_len if shape.kind != "decode" else 1)

    def sds(dims, dtype):
        return jax.ShapeDtypeStruct(dims, dtype,
                                    sharding=batch_sharding(mesh, dims))

    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            batch["tokens"] = sds((B, S), jnp.int32)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
    if cfg.frontend == "vlm" and shape.kind != "decode":
        batch["pixel_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
        batch["mask"] = sds((B, S), jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# cache sharding specs
# ---------------------------------------------------------------------------


# ``make_cache_spec_fn`` / ``tree_shardings`` moved to
# ``repro.parallel.sharding`` when the serve engines went mesh-parallel
# (the rules now cover paged pools too); re-exported here for callers of
# the dry-run module.
make_cache_spec_fn = shd.make_cache_spec_fn
tree_shardings = shd.tree_shardings


def opt_spec_fn(param_spec_fn):
    """Optimizer state mirrors the parameter sharding; step is replicated."""

    def fn(path, shape):
        names = [getattr(k, "key", None) for k in path]
        if "step" in names:
            return (None,) * len(shape)
        # strip the leading {'mu'|'nu'} key and delegate
        return param_spec_fn(path[1:], shape)

    return fn


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               serve_chunk: int = 0) -> dict:
    cfg = dryrun_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.flatten())
    spec_fn = make_param_spec_fn(cfg)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips, "kind": shape.kind,
    }
    if serve_chunk and shape.kind == "decode":
        # clamp to the smallest sliding window, as the engine does -- a
        # chunk wider than a rolling SWA cache is a shape production never
        # runs (its scatter would collide modulo the cache size)
        windows = [min(s.window, shape.seq_len)
                   for s in cfg.stages if s.window]
        serve_chunk = max(1, min([serve_chunk, *windows]))
        result["serve_chunk"] = serve_chunk
    t0 = time.time()

    with mesh:
        params_shape = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        params_shardings = shd.param_sharding(params_shape, mesh, spec_fn)
        params_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            params_shape, params_shardings)

        if shape.kind == "train":
            opt_cfg = adamw.OptConfig(
                state_dtype="bfloat16" if arch in BF16_OPT else "float32")
            micro = MICROBATCH.get((arch, shape_name), 1)
            accum = jnp.bfloat16 if arch in BF16_OPT else jnp.float32
            step_fn = make_train_step(cfg, opt_cfg, microbatches=micro,
                                      accum_dtype=accum)
            result["microbatches"] = micro

            state_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg))
            state_shardings = {
                "params": params_shardings,
                "opt": tree_shardings(
                    state_shape["opt"], mesh, opt_spec_fn(spec_fn)),
                "step": shd.named_sharding(mesh, dims=()),
            }
            state_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                state_shape, state_shardings)
            batch_sds = input_specs(cfg, shape, mesh)
            result["expected_memory"] = expected_device_bytes(
                cfg, shape, mesh, state_sds=state_sds,
                params_sds=state_sds["params"], microbatches=micro)
            lowered = jax.jit(
                step_fn, donate_argnums=(0,),
                out_shardings=(state_shardings, None),
            ).lower(state_sds, batch_sds)

        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                hidden, _ = T.forward(params, batch, cfg)
                head = (params["embed"].T if cfg.tie_embeddings
                        else params["lm_head"])
                return jnp.einsum("bd,dv->bv", hidden[:, -1], head)

            batch_sds = input_specs(cfg, shape, mesh)
            result["expected_memory"] = expected_device_bytes(
                cfg, shape, mesh, params_sds=params_sds)
            lowered = jax.jit(prefill_step).lower(params_sds, batch_sds)

        else:  # decode
            caches_shape = jax.eval_shape(
                lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len,
                                      dtype=jnp.bfloat16))
            cache_shardings = tree_shardings(caches_shape, mesh,
                                             make_cache_spec_fn(mesh, cfg))
            caches_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                caches_shape, cache_shardings)
            result["expected_memory"] = expected_device_bytes(
                cfg, shape, mesh, params_sds=params_sds, cache_sds=caches_sds)
            if serve_chunk:
                # the continuous engine's chunked-prefill step: C teacher-
                # forced tokens per slot with a per-slot validity mask
                B, C = shape.global_batch, serve_chunk

                def chunk_step(params, caches, batch, valid):
                    logits, caches = T.prefill_step(params, caches, batch,
                                                    valid, cfg)
                    return jnp.argmax(logits[:, -1], axis=-1), caches

                batch_sds = input_specs(cfg, shape, mesh, seq=C)
                valid_sds = jax.ShapeDtypeStruct(
                    (B, C), jnp.bool_,
                    sharding=batch_sharding(mesh, (B, C)))
                lowered = jax.jit(
                    chunk_step, donate_argnums=(1,),
                    out_shardings=(None, cache_shardings),
                ).lower(params_sds, caches_sds, batch_sds, valid_sds)
            else:
                def serve_step(params, caches, batch):
                    logits, caches = T.decode_step(params, caches, batch, cfg)
                    return jnp.argmax(logits[:, -1], axis=-1), caches

                batch_sds = input_specs(cfg, shape, mesh)
                lowered = jax.jit(
                    serve_step, donate_argnums=(1,),
                    out_shardings=(None, cache_shardings),
                ).lower(params_sds, caches_sds, batch_sds)

        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        result["memory"]["live_bytes_per_device"] = int(live)
        result["memory"]["fits_16GiB"] = bool(live < 16 * 1024**3)

        hlo_text = compiled.as_text()
        # XLA:CPU has no native bf16 dots: it inserts fp32 converts of the
        # bf16 operands (weights/caches), inflating temp vs a real TPU
        # compile where the MXU consumes bf16 directly.  Quantify those
        # converts so the table can report a TPU-adjusted estimate.
        upcast = _cpu_upcast_bytes(hlo_text)
        result["memory"]["cpu_upcast_f32_bytes"] = upcast
        adj = max(0, live - upcast)
        result["memory"]["live_bytes_tpu_adjusted"] = int(adj)
        result["memory"]["fits_16GiB_tpu_adjusted"] = bool(adj < 16 * 1024**3)

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):       # jax < 0.5: one dict/device
            ca = ca[0] if ca else {}
        result["cost"] = {
            # NOTE: XLA counts while bodies once -- see 'corrected' below.
            "flops_per_device": float(ca.get("flops", -1)),
            "bytes_per_device": float(ca.get("bytes accessed", -1)),
        }
        result["collectives"] = collective_bytes(hlo_text)
        # loop-corrected walker (trip-count multipliers; dots + collectives)
        hc = analyze_hlo(hlo_text)
        result["corrected"] = {
            "dot_flops_per_device": hc.dot_flops,
            "dot_bytes_per_device": hc.dot_bytes,
            "collective_bytes_per_device": hc.collective_bytes,
            "collective_by_kind": hc.collective_bytes_by_kind,
            "while_loops": hc.while_loops,
        }
    return result


_CONVERT_RE = re.compile(
    r"%[\w.\-]+ = f32\[([\d,]+)\][^=]*? convert\(%([\w.\-]+)\)")


def _cpu_upcast_bytes(hlo_text: str, min_bytes: int = 16 * 1024**2) -> int:
    """Estimated bytes of bf16->f32 convert results >= min_bytes (the
    XLA:CPU bf16-dot-upcast artifact; ~0 on a TPU compile)."""
    shapes: dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, dt, _ = m.groups()
        shapes[name] = dt
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dims, operand = m.groups()
        if shapes.get(operand) != "bf16":
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return int(total)


_DEF_RE = re.compile(r"%([\w.\-]+) = (\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective in the optimized HLO."""
    shapes: dict[str, tuple[str, int]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        shapes[name] = (dt, n)

    totals = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        mm = re.search(r"%([\w.\-]+) = (\w+)\[([\d,]*)\][^=]*? "
                       r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                       r"collective-permute)(?:-start)?\(([^)]*)\)", line)
        if not mm:
            continue
        _, res_dt, res_dims, kind, operands = mm.groups()
        done = False
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            if op in shapes:
                dt, n = shapes[op]
                totals[kind] += n * _DTYPE_BYTES.get(dt, 4)
                done = True
        if not done:
            n = 1
            for d in res_dims.split(","):
                if d:
                    n *= int(d)
            totals[kind] += n * _DTYPE_BYTES.get(res_dt, 4)
        counts[kind] += 1
    totals = {k: int(v) for k, v in totals.items()}
    return {"bytes": totals, "counts": counts,
            "total_bytes": int(sum(totals.values()))}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--serve-chunk", type=int, default=0,
                    help="decode cells: lower the continuous engine's "
                         "chunked prefill step (C tokens/slot) instead of "
                         "the one-token decode step")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override, e.g. --set seq_shard=False "
                         "--set exact_causal=True (hillclimb levers)")
    ap.add_argument("--trace-out", default=None,
                    help="enable repro.obs and write per-cell lower/compile "
                         "spans as a Chrome-trace JSON")
    ap.add_argument("--metrics-out", default=None,
                    help="enable repro.obs and write a metrics snapshot")
    ap.add_argument("--stream-dir", default=None,
                    help="stream periodic metric snapshots while a full "
                         "--all sweep lowers (long runs: watch progress "
                         "from another terminal)")
    ap.add_argument("--stream-interval", type=float, default=None)
    args = ap.parse_args()

    if args.trace_out or args.metrics_out or args.stream_dir:
        import repro.obs as obs
        from repro.obs import streaming
        obs.enable()
        if args.stream_dir:
            streaming.start(args.stream_dir,
                            interval_s=args.stream_interval
                            if args.stream_interval is not None
                            else streaming.DEFAULT_INTERVAL_S)

    for kv in args.set:
        key, val = kv.split("=", 1)
        OVERRIDES[key] = {"True": True, "False": False}.get(val) \
            if val in ("True", "False") else (
                int(val) if val.lstrip("-").isdigit() else val)

    os.makedirs(args.out, exist_ok=True)
    todo = (cells(args.multi_pod) if args.all
            else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in todo:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.serve_chunk and SHAPES[shape].kind == "decode":
            tag += f"_chunk{args.serve_chunk}"
        path = os.path.join(args.out, tag + ".json")
        try:
            from repro.obs import optrace
            with optrace.span(f"lower_cell:{tag}", cat="launch",
                              arch=arch, shape=shape):
                res = lower_cell(arch, shape, multi_pod=args.multi_pod,
                                 serve_chunk=args.serve_chunk)
            print(f"[ok] {tag}: compile={res['compile_s']}s "
                  f"live={res['memory']['live_bytes_per_device']/2**30:.2f}GiB "
                  f"coll={res['collectives']['total_bytes']/2**20:.1f}MiB")
        except Exception as e:  # noqa: BLE001
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    if args.trace_out or args.metrics_out or args.stream_dir:
        import repro.obs as obs
        if args.stream_dir:
            from repro.obs import streaming
            streaming.stop()
            print(f"streamed snapshots in {args.stream_dir}")
        if args.trace_out:
            obs.write_chrome_trace(args.trace_out, process_name="dryrun")
            print(f"trace written to {args.trace_out}")
        if args.metrics_out:
            obs.REGISTRY.write_json(args.metrics_out)
            print(f"metrics written to {args.metrics_out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
