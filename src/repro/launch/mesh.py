"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state -- the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import and
only then calls this.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where this jax has it (>= 0.5); older versions
    treat every mesh axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod's worth of chips) or 2x16x16 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small ``(data, model)`` mesh for CPU tests.

    Built from an explicit device subset: ``jax.make_mesh`` insists on
    consuming EVERY addressable device, which made a (1, 1) debug mesh
    impossible under ``--xla_force_host_platform_device_count=8`` -- the
    exact configuration the mesh-vs-single-device identity tests need."""
    import numpy as np

    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh ({data}, {model}) needs {n} devices, have "
            f"{len(devices)} -- on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax starts")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(data, model),
                             ("data", "model"))


def parse_mesh(spec: str | None):
    """``"DATAxMODEL"`` (e.g. ``"2x4"``) -> debug mesh; None/"" -> None."""
    if not spec:
        return None
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"mesh spec must be DATAxMODEL, got {spec!r}")
    return make_debug_mesh(data=int(parts[0]), model=int(parts[1]))
