"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state -- the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import and
only then calls this.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where this jax has it (>= 0.5); older versions
    treat every mesh axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod's worth of chips) or 2x16x16 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires enough host devices)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
