"""Training launcher.

Example (CPU debug, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real TPU slice the same entry point runs with --mesh data,model sizes
matching the slice; data/model axis sizes of 1 disable the corresponding
parallelism (CPU default).
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch, reduced=args.reduced)
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                              total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg,
                             grad_compression=args.grad_compression)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    step = jax.jit(make_train_step(cfg, opt_cfg,
                                   microbatches=args.microbatches,
                                   grad_compression=args.grad_compression))
    data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch, seed=args.seed,
                              frontend=cfg.frontend, d_model=cfg.d_model,
                              n_patches=cfg.n_patches)
    trainer = Trainer(train_step=step, state=state, dataset=data,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    if trainer.maybe_resume():
        print(f"resumed at step {int(trainer.state['step'])}")
    history = trainer.run(args.steps)
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"done: loss {first:.4f} -> {last:.4f} over {len(history)} steps; "
          f"median step {np.median([h['step_time_s'] for h in history]):.3f}s")


if __name__ == "__main__":
    main()
