"""Analytic per-device memory model for the dry-run table.

XLA:CPU inserts fp32 copies of bf16 dot operands (no native bf16 matmul), so
``memory_analysis()`` on this container systematically overstates what a TPU
compile would allocate.  This module computes the TPU-expected per-device
bytes from ground truth:

  * state/cache bytes: EXACT -- summed over the real sharding tree
    (every leaf's global size / its sharding's device coverage)
  * activations: a coarse structural model of the remat scan (carry per
    layer, one layer's recompute working set, loss-chunk logits)

Reported next to the measured CPU numbers in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def sharded_bytes(sds_tree) -> int:
    """Exact per-device bytes of a tree of sharded ShapeDtypeStructs."""
    total = 0
    for leaf in jax.tree.leaves(sds_tree):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        sh = getattr(leaf, "sharding", None)
        if sh is not None and leaf.shape:
            shard_shape = sh.shard_shape(leaf.shape)
            n = int(np.prod(shard_shape))
        total += n * leaf.dtype.itemsize
    return total


def activation_estimate(cfg, shape, mesh, microbatches: int) -> int:
    """Coarse per-device activation bytes for one step."""
    axes = dict(mesh.shape)
    data = axes.get("data", 1) * axes.get("pod", 1)
    model = axes.get("model", 1)
    d = cfg.d_model

    def div(x, m):
        return x // m if m and x % m == 0 else x

    if shape.kind == "train":
        rows = max(1, shape.global_batch // microbatches // data)
        S = shape.seq_len
        tokens = rows * S
        seqfac = model if (cfg.seq_shard and S % model == 0) else 1
        carry = tokens * d * 2 // seqfac * cfg.n_layers          # remat carries
        ff = max(cfg.d_ff, cfg.d_ff_expert * max(cfg.top_k, 1), 2 * cfg.d_inner)
        trans = tokens * (div(ff, model) + d) * 4 * 3            # 1-layer bwd
        loss = rows * min(cfg.loss_chunk, S) * div(cfg.vocab_pad, model) * 4 * 2
        grads = 0  # counted with state
        return carry + trans + loss + grads
    if shape.kind == "prefill":
        rows = max(1, shape.global_batch // data)
        tokens = rows * shape.seq_len
        seqfac = model if (cfg.seq_shard and shape.seq_len % model == 0) else 1
        stream = tokens * d * 2 // seqfac * 2
        ff = max(cfg.d_ff, cfg.d_ff_expert * max(cfg.top_k, 1), 2 * cfg.d_inner)
        layer = tokens * div(ff, model) * 2
        return stream + layer
    # decode
    rows = max(1, shape.global_batch // data)
    logits = rows * div(cfg.vocab_pad, model) * 4
    attn = rows * div(max(cfg.n_heads, 1), model) * shape.seq_len * 4
    return (rows * d * 2 * cfg.n_layers // max(cfg.n_layers, 1)
            + logits + attn * 2)


def expected_device_bytes(cfg, shape, mesh, *, state_sds=None, cache_sds=None,
                          params_sds=None, microbatches: int = 1) -> dict:
    state = sharded_bytes(state_sds) if state_sds is not None else 0
    params = sharded_bytes(params_sds) if params_sds is not None else 0
    caches = sharded_bytes(cache_sds) if cache_sds is not None else 0
    acts = activation_estimate(cfg, shape, mesh, microbatches)
    # training: gradient accumulation buffer mirrors params (accum dtype ~2B
    # for the bf16-opt archs, 4B otherwise) -- approximate with param bytes.
    grad_buf = params if shape.kind == "train" and microbatches > 1 else 0
    total = state + params + caches + acts + grad_buf
    return {
        "state_bytes": int(state),
        "params_bytes": int(params),
        "cache_bytes": int(caches),
        "activation_est_bytes": int(acts),
        "grad_buffer_bytes": int(grad_buf),
        "expected_total_bytes": int(total),
        "fits_16GiB_expected": bool(total < 16 * 1024**3),
    }
