"""Atomic, hash-verified, keep-N checkpointing with async save and elastic
restore.

Layout: ``<dir>/step_<N>/`` containing ``arrays.npz`` (logical, unsharded
tensors -- so a restart may use a different mesh shape: elastic restore) and
``meta.json`` (step, tree structure, sha256 of the npz, data-iterator state).
Writes go to ``step_<N>.tmp`` and are renamed into place only after fsync,
so a crash mid-save never corrupts the latest checkpoint.  ``keep_n`` old
checkpoints are garbage-collected after each successful save.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(state: Any) -> tuple[list[str], list[np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    keys, arrs = [], []
    for path, leaf in flat:
        keys.append(jax.tree_util.keystr(path))
        arrs.append(np.asarray(leaf))
    return keys, arrs


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        keys, arrs = _flatten(state)   # materialize on the main thread
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, keys, arrs, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, keys, arrs, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, keys, arrs, extra: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **{f"a{i}": a for i, a in enumerate(arrs)})
        meta = {
            "step": step,
            "keys": keys,
            "sha256": _sha256(npz),
            "extra": extra,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                *, shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding matching template --
        arrays are device_put with them (elastic: the mesh may differ from
        the one that saved).  Verifies the content hash before loading.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        npz_path = os.path.join(d, "arrays.npz")
        if _sha256(npz_path) != meta["sha256"]:
            raise IOError(f"checkpoint {d} failed hash verification")
        data = np.load(npz_path)
        arrs = [data[f"a{i}"] for i in range(len(meta["keys"]))]

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys_t = [jax.tree_util.keystr(p) for p, _ in flat_t]
        if keys_t != meta["keys"]:
            raise ValueError("checkpoint tree structure mismatch")
        leaves = []
        flat_s = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(arrs))
        for (path, tmpl), arr, shd in zip(flat_t, arrs, flat_s):
            arr = arr.astype(tmpl.dtype)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta["extra"]
