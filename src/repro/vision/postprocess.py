"""YOLO head postprocessing: box decode + class-aware NMS, on-accelerator.

Everything here is jnp with static output shapes, so the whole pipeline
jits and runs on the same device as the model -- detection maps never
round-trip to the host for the O(H*W*anchors) decode, only the final
``max_det`` rows do.

Decode follows YOLOv3: per cell/anchor ``xy = (sigmoid(t_xy) + cell) /
grid``, ``wh = anchor * exp(t_wh)``, objectness/class scores via sigmoid,
boxes emitted as normalized xyxy.  NMS is greedy and *class-aware* via the
coordinate-offset trick (each class's boxes are shifted to a disjoint
region, so one IoU pass never suppresses across classes) with fixed-size
outputs (``max_det`` rows, invalid rows flagged) so the whole thing is one
compiled program per geometry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["YOLO_ANCHORS", "decode_scale", "decode_outputs", "nms",
           "postprocess_yolo"]

# COCO anchors (pixels on the nominal 416x416 canvas), per detection head,
# keyed by the model-zoo output names (det1 = coarsest grid).
YOLO_ANCHORS = {
    "yolov3": {
        "det1": ((116, 90), (156, 198), (373, 326)),
        "det2": ((30, 61), (62, 45), (59, 119)),
        "det3": ((10, 13), (16, 30), (33, 23)),
    },
    "yolov3_tiny": {
        "det1": ((81, 82), (135, 169), (344, 319)),
        "det2": ((10, 14), (23, 27), (37, 58)),
    },
}
_NOMINAL_CANVAS = 416.0


def decode_scale(det: jax.Array, anchors, *, num_classes: int,
                 canvas: float = _NOMINAL_CANVAS
                 ) -> tuple[jax.Array, jax.Array]:
    """One detection map -> (boxes (N, h*w*A, 4) xyxy in [0, 1],
    scores (N, h*w*A, C) = sigmoid(obj) * sigmoid(cls))."""
    N, h, w, _ = det.shape
    A = len(anchors)
    det = det.reshape(N, h, w, A, 5 + num_classes).astype(jnp.float32)
    cell_x = jnp.arange(w, dtype=jnp.float32)[None, None, :, None]
    cell_y = jnp.arange(h, dtype=jnp.float32)[None, :, None, None]
    cx = (jax.nn.sigmoid(det[..., 0]) + cell_x) / w
    cy = (jax.nn.sigmoid(det[..., 1]) + cell_y) / h
    anc = jnp.asarray(anchors, jnp.float32) / canvas          # (A, 2)
    # clip t_wh so exp() of random/garbage heads cannot overflow
    bw = anc[:, 0] * jnp.exp(jnp.clip(det[..., 2], -10.0, 10.0))
    bh = anc[:, 1] * jnp.exp(jnp.clip(det[..., 3], -10.0, 10.0))
    obj = jax.nn.sigmoid(det[..., 4])
    cls = jax.nn.sigmoid(det[..., 5:])
    boxes = jnp.stack([cx - bw / 2, cy - bh / 2, cx + bw / 2, cy + bh / 2],
                      axis=-1)
    scores = obj[..., None] * cls
    return (boxes.reshape(N, h * w * A, 4),
            scores.reshape(N, h * w * A, num_classes))


def decode_outputs(outputs: dict, anchors: dict, *, num_classes: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Decode and concatenate every scale of a YOLO model-zoo output dict."""
    boxes, scores = [], []
    for name in sorted(outputs):
        b, s = decode_scale(outputs[name], anchors[name],
                            num_classes=num_classes)
        boxes.append(b)
        scores.append(s)
    return jnp.concatenate(boxes, axis=1), jnp.concatenate(scores, axis=1)


def _iou(box: jax.Array, boxes: jax.Array) -> jax.Array:
    """IoU of one xyxy box against (P, 4)."""
    lt = jnp.maximum(box[:2], boxes[:, :2])
    rb = jnp.minimum(box[2:], boxes[:, 2:])
    inter = jnp.prod(jnp.maximum(rb - lt, 0.0), axis=-1)
    area = jnp.maximum(jnp.prod(box[2:] - box[:2]), 0.0)
    areas = jnp.maximum(jnp.prod(boxes[:, 2:] - boxes[:, :2], axis=-1), 0.0)
    return inter / jnp.maximum(area + areas - inter, 1e-9)


@functools.partial(jax.jit, static_argnames=("max_det",))
def nms(boxes: jax.Array, scores: jax.Array, classes: jax.Array, *,
        iou_thresh: float = 0.45, score_thresh: float = 0.25,
        max_det: int = 100):
    """Greedy class-aware NMS with fixed-shape outputs.

    ``boxes (P, 4)`` normalized xyxy, ``scores (P,)``, ``classes (P,)``.
    Returns ``(boxes (max_det, 4), scores (max_det,), classes (max_det,),
    valid (max_det,) bool)`` -- invalid rows are zeroed.  Class-aware via
    coordinate offsetting: per-class shifted copies never overlap, so one
    greedy pass suppresses within classes only.
    """
    live = jnp.where(scores >= score_thresh, scores, 0.0)
    # suppression geometry is clipped to the canvas first, so an offset of
    # 2/class fully separates classes even for degenerate oversized boxes
    # (decode clamps t_wh, but garbage heads can still overshoot [0, 1])
    shifted = (jnp.clip(boxes, 0.0, 1.0)
               + (classes.astype(jnp.float32) * 2.0)[:, None])

    def body(i, carry):
        live, picks = carry
        j = jnp.argmax(live)
        ok = live[j] > 0.0
        picks = picks.at[i].set(jnp.where(ok, j, -1))
        iou = _iou(shifted[j], shifted)
        suppress = ok & (iou > iou_thresh)       # includes j (IoU 1 > thresh)
        live = jnp.where(suppress, 0.0, live)
        return live, picks

    _, picks = jax.lax.fori_loop(
        0, max_det, body,
        (live, jnp.full((max_det,), -1, jnp.int32)))
    valid = picks >= 0
    take = jnp.maximum(picks, 0)
    return (jnp.where(valid[:, None], boxes[take], 0.0),
            jnp.where(valid, scores[take], 0.0),
            jnp.where(valid, classes[take], 0),
            valid)


def postprocess_yolo(outputs: dict, *, arch: str, num_classes: int,
                     anchors: dict | None = None, iou_thresh: float = 0.45,
                     score_thresh: float = 0.25,
                     max_det: int = 100) -> dict:
    """Model-zoo YOLO outputs -> batched fixed-shape detections.

    Returns ``{"boxes" (N, max_det, 4), "scores" (N, max_det),
    "classes" (N, max_det), "valid" (N, max_det)}``, all on-device.
    """
    anchors = anchors if anchors is not None else YOLO_ANCHORS[arch]
    if set(anchors) != set(outputs):
        raise ValueError(
            f"anchor scales {sorted(anchors)} do not match model outputs "
            f"{sorted(outputs)}")
    boxes, scores = decode_outputs(outputs, anchors,
                                   num_classes=num_classes)
    best = scores.max(axis=-1)                            # (N, P)
    cls = scores.argmax(axis=-1).astype(jnp.int32)

    run = functools.partial(nms, iou_thresh=iou_thresh,
                            score_thresh=score_thresh, max_det=max_det)
    b, s, c, v = jax.vmap(run)(boxes, best, cls)
    return {"boxes": b, "scores": s, "classes": c, "valid": v}
