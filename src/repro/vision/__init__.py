"""``repro.vision`` -- the runnable conv-net model zoo + inference engine.

Closes the loop with the paper's ResNet50/YOLOv3 claims: the same networks
the analytic models score are executable here through the Axon operator API
(``blocks`` / ``models``), servable under continuous batching (``engine``)
with on-accelerator letterboxing (``preprocess``) and YOLO NMS
(``postprocess``), and traceable back into the analytic runtime/energy
models (``trace``).
"""
from repro.vision.engine import ImageRequest, VisionEngine, make_infer_step
from repro.vision.models import ARCHS, VisionConfig, apply, init
from repro.vision.postprocess import YOLO_ANCHORS, nms, postprocess_yolo
from repro.vision.preprocess import letterbox, unletterbox_boxes
from repro.vision.trace import (
    TracedConv,
    conv_shapes,
    lowered_gemms,
    paper_report,
    precision_report,
    to_conv_shape,
    trace_model,
)

__all__ = [
    "ARCHS",
    "ImageRequest",
    "TracedConv",
    "VisionConfig",
    "VisionEngine",
    "YOLO_ANCHORS",
    "apply",
    "conv_shapes",
    "init",
    "letterbox",
    "lowered_gemms",
    "make_infer_step",
    "nms",
    "paper_report",
    "postprocess_yolo",
    "precision_report",
    "to_conv_shape",
    "trace_model",
    "unletterbox_boxes",
]
