"""Layer tracer: conv shapes *from the executable models*, not hand tables.

``trace_model`` walks a model-zoo network under ``jax.eval_shape`` with the
``repro.vision.blocks`` trace tap active: every ``axon.conv2d`` /
``depthwise_conv2d`` call site records its resolved geometry without running
any compute, so tracing full-size ResNet50/YOLOv3 at 224/416 input costs
milliseconds.  The records convert to the ``ConvShape`` / ``GemmShape``
types the analytic models consume, which is how ``paper_report`` reproduces
the paper's Axon-vs-conventional throughput/energy comparison end-to-end
from the runnable models -- and how the tests cross-validate the
hand-transcribed tables in ``repro.core.workloads``.
"""
from __future__ import annotations

import functools

import jax

from repro.core.dataflows import GemmShape
from repro.core.energy_model import (DRAM_BANDWIDTH_BYTES, PAPER_ASIC,
                                     bounded_runtime_s, dram_energy_joules,
                                     operand_bytes)
from repro.core.im2col_model import ConvShape, lower_to_gemm, model_traffic
from repro.core.runtime_model import ArrayShape, best_dataflow
from repro.vision import models
from repro.vision.blocks import TracedConv, trace_taps
from repro.vision.models import VisionConfig

__all__ = ["TracedConv", "trace_model", "to_conv_shape", "conv_shapes",
           "lowered_gemms", "paper_report", "precision_report"]


def trace_model(cfg: VisionConfig, *, batch: int = 1) -> list[TracedConv]:
    """Every conv executed by ``models.apply``, in execution order, with
    geometry as resolved by the ``axon`` front door.  Runs no compute."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(functools.partial(models.init, cfg=cfg), key)
    x = jax.ShapeDtypeStruct((batch, *cfg.input_hw, cfg.in_channels),
                             cfg.pdtype)
    records: list[TracedConv] = []
    with trace_taps(records):
        jax.eval_shape(functools.partial(models.apply, cfg=cfg), params, x)
    return records


def to_conv_shape(tc: TracedConv) -> ConvShape:
    """Convert a traced record to the analytic-model ``ConvShape``.

    The analytic im2col model speaks square filters / symmetric padding /
    uniform stride / dense channels (every zoo layer qualifies); anything
    else is a hard error rather than a silent approximation.  Depthwise
    records follow the ``MOBILENET_DW`` convention -- ``C_in == C_out`` with
    per-channel semantics understood by the Fig. 14 accounting -- but
    general grouped convs have no ConvShape encoding (a dense conversion
    would overstate K and MACs by ``groups``x)."""
    (sh, sw) = tc.stride
    (pt, pb), (pl, pr) = tc.padding
    if tc.kh != tc.kw or sh != sw or len({pt, pb, pl, pr}) != 1:
        raise ValueError(
            f"{tc.name}: non-square/asymmetric conv {tc} has no ConvShape "
            "equivalent (extend repro.core.im2col_model first)")
    if tc.groups != 1 and not tc.depthwise:
        raise ValueError(
            f"{tc.name}: grouped conv (groups={tc.groups}) has no ConvShape "
            "equivalent (extend repro.core.im2col_model first)")
    return ConvShape(H=tc.H, W=tc.W, C_in=tc.C_in, C_out=tc.C_out, n=tc.kh,
                     stride=sh, padding=pt, name=tc.name)


def conv_shapes(cfg: VisionConfig, *, include_depthwise: bool = False
                ) -> list[ConvShape]:
    """Traced dense-conv layers as ``ConvShape`` records (execution order).

    Depthwise layers are excluded by default: they skip im2col entirely
    (VPU path / Fig. 14) so they don't belong in the Fig. 11 traffic
    accounting."""
    return [to_conv_shape(r) for r in trace_model(cfg)
            if include_depthwise or not r.depthwise]


def lowered_gemms(cfg: VisionConfig) -> list[tuple[str, GemmShape]]:
    """(name, GeMM) per dense conv, via the paper's Table 3 im2col lowering
    ``M = C_out, K = n*n*C_in, N = H_out*W_out``."""
    return [(c.name, lower_to_gemm(c)) for c in conv_shapes(cfg)]


def precision_report(cfg: VisionConfig, *,
                     array: tuple[int, int] = (16, 16),
                     feeder_group: int = 16,
                     precisions: tuple[str, ...] = ("bf16", "int8", "fp8",
                                                    "int4")) -> dict:
    """Modeled operand-precision sweep for the Axon orchestration.

    Compute cycles are precision-independent (same MAC count); DRAM traffic
    -- and with it DRAM energy and the memory-bound side of the runtime
    roofline -- scales with bytes per operand.  The first precision is the
    baseline the ``*_vs_*`` ratios compare against: int8 and fp8 operands
    halve the bf16 stream (2x less DRAM energy, runtime speedup wherever
    the layer stream is memory-bound); packed int4 quarters it."""
    arr = ArrayShape(*array)
    convs = conv_shapes(cfg)
    gemms = [lower_to_gemm(c) for c in convs]
    cycles_ax = sum(best_dataflow(g, arr, axon=True)[1] for g in gemms)
    per: dict[str, dict] = {}
    for prec in precisions:
        _, ax_bytes = model_traffic(convs,
                                    bytes_per_elem=operand_bytes(prec),
                                    feeder_group=feeder_group)
        per[prec] = {
            "operand_bytes": ax_bytes,
            "dram_energy_j": dram_energy_joules(ax_bytes),
            "runtime_s": bounded_runtime_s(cycles_ax, ax_bytes),
        }
    base = precisions[0]
    for prec in precisions[1:]:
        per[f"{prec}_vs_{base}"] = {
            "traffic_ratio": per[prec]["operand_bytes"]
            / per[base]["operand_bytes"],
            "energy_ratio": per[base]["dram_energy_j"]
            / per[prec]["dram_energy_j"],
            "throughput_speedup": per[base]["runtime_s"]
            / per[prec]["runtime_s"],
        }
    return per


def paper_report(cfg: VisionConfig, *, array: tuple[int, int] = (16, 16),
                 bytes_per_elem: int = 2, feeder_group: int = 16) -> dict:
    """The paper's Axon-vs-conventional comparison from the runnable model.

    For every traced conv layer, lower to GeMM and take the best-dataflow
    scale-up runtime on the given array (Eq. 2 / Table 2) for both
    orchestrations, and the Fig. 11 operand-traffic model for both im2col
    schemes; combine into roofline-bounded runtimes (compute cycles vs DRAM
    bandwidth) and DRAM energy.  Returns the throughput and energy ratios
    the paper headlines, plus per-layer detail and the operand-precision
    sweep (``"precision"``: int8 vs bf16 traffic/energy/runtime for the
    Axon orchestration -- the modeled counterpart of ``repro.quant``).

    The ``"attribution"`` section tethers the analytic numbers above to
    measurement: when telemetry has measured dispatch walls (repro.obs
    with ``measure_dispatch`` on), it carries per-kernel-kind achieved
    FLOP/s and modeled-vs-measured error; otherwise it says why it is
    empty.  The analytic report never depends on telemetry being on."""
    arr = ArrayShape(*array)
    convs = conv_shapes(cfg)
    gemms = [lower_to_gemm(c) for c in convs]
    cycles_sa = sum(best_dataflow(g, arr, axon=False)[1] for g in gemms)
    cycles_ax = sum(best_dataflow(g, arr, axon=True)[1] for g in gemms)
    sw_bytes, ax_bytes = model_traffic(convs, bytes_per_elem=bytes_per_elem,
                                       feeder_group=feeder_group)
    t_sa = bounded_runtime_s(cycles_sa, sw_bytes)
    t_ax = bounded_runtime_s(cycles_ax, ax_bytes)
    e_sa = dram_energy_joules(sw_bytes)
    e_ax = dram_energy_joules(ax_bytes)
    return {
        "model": cfg.name,
        "array": list(array),
        "conv_layers": len(convs),
        "macs": sum(c.macs for c in convs),
        "cycles": {"conventional": cycles_sa, "axon": cycles_ax},
        "traffic_bytes": {"sw_im2col": sw_bytes, "axon": ax_bytes,
                          "reduction": 1.0 - ax_bytes / sw_bytes},
        "runtime_s": {"conventional": t_sa, "axon": t_ax,
                      "freq_hz": PAPER_ASIC.freq_hz,
                      "dram_bw": DRAM_BANDWIDTH_BYTES},
        "throughput_speedup": t_sa / t_ax,
        "cycle_speedup": cycles_sa / cycles_ax,   # fill-latency-only view
        "dram_energy_j": {"conventional": e_sa, "axon": e_ax},
        "energy_ratio": e_sa / e_ax,
        "precision": precision_report(cfg, array=array,
                                      feeder_group=feeder_group),
        "attribution": _attribution_section(),
    }


def _attribution_section() -> dict:
    # lazy import: repro.obs must stay optional for the pure-analytic path
    from repro.obs import attribution
    return attribution.paper_section()
