"""Batched vision inference engine (the PR-2 serving pattern, for images).

``VisionEngine`` drives a model-zoo network with slot-level scheduling: an
admission queue feeds ``batch_slots`` image lanes, and ALL compute flows
through ONE fixed-shape jitted step per model -- always ``(batch_slots, H,
W, C)``, with partial batches zero-padded and their lanes discarded -- so
recompilation never happens mid-serve regardless of arrival pattern.

Unlike the LM engine there is no decode loop: vision inference is a single
forward pass, so a slot's lifetime is exactly one step and every slot is
backfilled from the queue on the next step.  Requests carry an
``arrival_s`` offset (relative to ``infer()`` start) so mixed-arrival
traffic can be replayed: the engine admits only requests whose arrival time
has passed, sleeping until the next arrival when all lanes would otherwise
be empty.

Variable-size images are admitted through the on-accelerator
:func:`repro.vision.preprocess.letterbox` helper (aspect-preserving resize +
centered pad, one compile per unique input geometry), so the jitted step
shape stays fixed regardless of what arrives.  Quantized parameter pytrees
(``repro.quant`` -- QuantizedTensor leaves) are served as-is: the engine
flips the step policy to ``precision="int8"`` so every conv/dense dispatches
the int8 kernels -- quantize once, serve many.

``last_stats`` reports throughput (img/s), per-request latency percentiles,
and mean batch occupancy for the most recent ``infer`` call.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import axon
from repro.core.mapper import mapper_cache_stats
from repro.obs import annotate as _ann
from repro.obs import attribution as _attr
from repro.obs import metrics as _obs_metrics, optrace as _obs
from repro.obs import streaming as _streaming
from repro.quant import is_quantized
from repro.vision import models, preprocess
from repro.vision.models import VisionConfig

QUEUE_POLICIES = ("fifo",)


def step_batch(n_admitted: int, batch_slots: int) -> int:
    """Batch dimension the vision engine feeds for one step, as a pure
    function of how many lanes were admitted.

    Always ``batch_slots``: partial batches are zero-padded, never fed at
    their own size -- that is the ONE-fixed-shape promise that keeps the
    jitted step from recompiling mid-serve.  The static analyzer
    (``repro.analysis.retrace``) enumerates every admission count against
    :func:`declared_step_batches` to prove it."""
    del n_admitted
    return batch_slots


def declared_step_batches(batch_slots: int) -> tuple[int, ...]:
    """The complete set of batch dims the infer step is traced at."""
    return (batch_slots,)


def make_infer_step(cfg: VisionConfig,
                    policy: axon.ExecutionPolicy | None = None):
    """(params, images (B, H, W, C)) -> model outputs, policy pinned at
    trace time (the engine jits exactly one instance of this)."""
    pol = policy if policy is not None else axon.current_policy()

    def infer_step(params, images):
        with axon.policy(pol):
            return models.apply(params, images, cfg)

    return infer_step


@dataclasses.dataclass
class ImageRequest:
    image: np.ndarray            # (H, W, C); any H, W when letterboxing is on
    arrival_s: float = 0.0       # offset from infer() start (0 = already here)


class VisionEngine:
    """Continuous-batching single-pass inference over ``batch_slots`` lanes.

    ``letterbox=True`` (default) admits images of any spatial size by
    letterboxing them onto ``cfg.input_hw`` at admission; ``False`` restores
    the strict exact-shape contract.  Passing a quantized params pytree
    (QuantizedTensor leaves) with no explicit ``policy`` serves through the
    int8 kernels automatically; an explicitly supplied policy is respected
    verbatim (``precision="float"`` gives the dequantized reference path on
    the same quantized params).
    """

    def __init__(self, params, cfg: VisionConfig, *, batch_slots: int = 8,
                 policy: axon.ExecutionPolicy | None = None,
                 letterbox: bool = True, mesh=None):
        self.params = params
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.letterbox = letterbox
        pol = policy if policy is not None else axon.current_policy()
        if policy is None and is_quantized(params) \
                and pol.precision == "float":
            pol = dataclasses.replace(pol, precision="int8")
        self.policy = pol
        # Vision serving is data-parallel: one forward pass per image, no
        # KV state, so the mesh shards the batch dim over every 'data'-like
        # axis and replicates the (small) conv/dense params everywhere.
        self.mesh = mesh
        self._batch_sharding = None
        step_out = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.parallel import sharding as shd
            repl = NamedSharding(mesh, PartitionSpec())
            self.params = jax.device_put(self.params, repl)
            self._batch_sharding = shd.named_sharding(
                mesh, "batch", None, None, None,
                dims=(batch_slots, *cfg.input_hw, cfg.in_channels))
            step_out = NamedSharding(mesh, PartitionSpec())
        jitted = jax.jit(make_infer_step(cfg, policy=pol),
                         out_shardings=step_out)
        self._step = self._under_mesh(jitted)
        self.last_stats: dict[str, Any] | None = None
        # modeled cost of one traced infer step (single fixed batch shape),
        # captured from the traced-cost ledger like the serve engine's
        self._traced_step_cost: dict[str, float] | None = None

    def declared_step_batches(self) -> tuple[int, ...]:
        """Batch dims this engine's infer step will ever be traced at."""
        return declared_step_batches(self.batch_slots)

    def _under_mesh(self, fn):
        """Wrap a jitted callable so every call (and hence every trace)
        runs inside ``with mesh:`` -- arming the model-level ``constrain``
        annotations without touching the scheduling loop."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def wrapped(*args, **kwargs):
            with mesh:
                return fn(*args, **kwargs)

        return wrapped

    def _stack_batch(self, lane_imgs: list[jax.Array]) -> jax.Array:
        """Stack admitted lanes into the step batch, committed to the
        mesh's data-parallel batch sharding when one is configured."""
        batch = jnp.stack(lane_imgs)
        if self._batch_sharding is not None:
            batch = jax.device_put(batch, self._batch_sharding)
        return batch

    def _validate(self, requests: list[ImageRequest]) -> None:
        want = (*self.cfg.input_hw, self.cfg.in_channels)
        for idx, req in enumerate(requests):
            shape = tuple(req.image.shape)
            if self.letterbox:
                ok = (len(shape) == 3 and shape[2] == self.cfg.in_channels
                      and min(shape[:2]) >= 1)
            else:
                ok = shape == want
            if not ok:
                raise ValueError(
                    f"request {idx}: image shape {shape} not servable for "
                    f"model input {want} (letterbox={self.letterbox})")
            if req.arrival_s < 0:
                raise ValueError(f"request {idx}: negative arrival_s")

    def _admit_image(self, image: np.ndarray) -> jax.Array:
        """Admit one image as a device array at the model input shape --
        letterboxed images never round-trip back to the host."""
        want = (*self.cfg.input_hw, self.cfg.in_channels)
        if tuple(image.shape) == want:
            return jnp.asarray(image, self.cfg.pdtype)
        return preprocess.letterbox(image, self.cfg.input_hw,
                                    dtype=self.cfg.pdtype)

    def _zero_lane(self) -> jax.Array:
        return jnp.zeros((*self.cfg.input_hw, self.cfg.in_channels),
                         self.cfg.pdtype)

    def warmup(self) -> None:
        """Compile the (single) step shape outside any timed region."""
        zero = jnp.zeros((self.batch_slots, *self.cfg.input_hw,
                          self.cfg.in_channels), self.cfg.pdtype)
        if self._batch_sharding is not None:
            zero = jax.device_put(zero, self._batch_sharding)
        jax.block_until_ready(self._step(self.params, zero))

    def _warm_geometries(self, requests: list[ImageRequest]) -> int:
        """Pre-trace the letterbox resize for every input geometry in the
        request set.  ``preprocess.letterbox`` compiles once per unique
        input shape; without this, each first-seen geometry paid its
        compile inside the timed loop and polluted the latency percentiles
        with one-time compilation.  Returns the number of distinct
        letterboxed geometries."""
        want = (*self.cfg.input_hw, self.cfg.in_channels)
        seen: set[tuple] = set()
        for req in requests:
            shape = tuple(req.image.shape)
            key = (shape, np.dtype(req.image.dtype).name)  # jit retraces
            if shape == want or key in seen:               # per input dtype
                continue
            seen.add(key)
            jax.block_until_ready(preprocess.letterbox(
                np.zeros(shape, req.image.dtype), self.cfg.input_hw,
                dtype=self.cfg.pdtype))
        return len(seen)

    def infer(self, requests: list[ImageRequest]) -> list:
        """Run all requests; returns per-request model outputs in request
        order (logits row, or dict of detection-map slices for YOLO)."""
        self._validate(requests)
        if self.letterbox:
            # compile-per-geometry happens HERE, before the clock starts
            self._warm_geometries(requests)
        B = self.batch_slots
        order = sorted(range(len(requests)),
                       key=lambda i: requests[i].arrival_s)
        pending = collections.deque(order)
        outputs: list[Any | None] = [None] * len(requests)
        lat = np.zeros(len(requests))
        queue_delay = np.zeros(len(requests))
        compute_s = np.zeros(len(requests))
        steps = 0
        occupancy = 0
        obs_on = _obs.enabled()     # snapshot: one boolean read per call
        modeled = {"flops": 0.0, "bytes": 0.0, "energy_j": 0.0}
        covered_steps = 0
        streaming_on = obs_on and _streaming.add_collector(
            self._stream_collector)
        t0 = time.perf_counter()

        while pending:
            now = time.perf_counter() - t0
            next_arrival = requests[pending[0]].arrival_s
            if next_arrival > now:        # nothing admissible: idle until then
                time.sleep(next_arrival - now)
                now = time.perf_counter() - t0
            lanes: list[int] = []
            while pending and len(lanes) < B \
                    and requests[pending[0]].arrival_s <= now:
                lanes.append(pending.popleft())
            lane_imgs = []
            for ridx in lanes:
                lane_imgs.append(self._admit_image(requests[ridx].image))
                queue_delay[ridx] = now - requests[ridx].arrival_s
            nB = step_batch(len(lane_imgs), B)
            if len(lane_imgs) < nB:            # pad empty lanes on device
                lane_imgs.extend([self._zero_lane()] * (nB - len(lane_imgs)))
            t_compute = time.perf_counter()
            ledger0 = (_obs.traced_totals()
                       if obs_on and self._traced_step_cost is None else None)
            with _ann.host_scope("vision_step", enabled=obs_on):
                out = self._step(self.params, self._stack_batch(lane_imgs))
                out = jax.block_until_ready(out)
            if ledger0 is not None:
                after = _obs.traced_totals()
                if after["count"] > ledger0["count"]:
                    self._traced_step_cost = {
                        k: after[k] - ledger0[k]
                        for k in ("flops", "bytes", "energy_j")}
            if obs_on and self._traced_step_cost is not None:
                for k in modeled:
                    modeled[k] += self._traced_step_cost[k]
                covered_steps += 1
            done = time.perf_counter() - t0
            steps += 1
            occupancy += len(lanes)
            if obs_on:
                # batch_compute nests inside the vision_step slice (the
                # step also covers admission/letterboxing)
                _obs.add_span("vision_step", t0 + now, done - now,
                              cat="vision", args={"step": steps - 1,
                                                  "images": len(lanes)})
                _obs.add_span("batch_compute", t_compute,
                              t0 + done - t_compute, cat="vision",
                              args={"step": steps - 1, "batch": nB})
            for b, ridx in enumerate(lanes):
                outputs[ridx] = jax.tree.map(lambda a, b=b: np.asarray(a[b]),
                                             out)
                lat[ridx] = done - requests[ridx].arrival_s
                compute_s[ridx] = done - now
                if obs_on:
                    tid = _obs.TID_REQUEST_BASE + ridx
                    args = {"request": ridx, "lane": b}
                    if queue_delay[ridx] > 0:
                        _obs.add_span("queue",
                                      t0 + requests[ridx].arrival_s,
                                      queue_delay[ridx], cat="vision",
                                      tid=tid, args=args)
                    _obs.add_span("compute", t0 + now, done - now,
                                  cat="vision", tid=tid, args=args)

        wall = time.perf_counter() - t0
        n = len(requests)

        def _pct(arr, q):
            return float(np.percentile(arr, q)) if n else 0.0

        self.last_stats = {
            "images": n,
            "steps": steps,
            "wall_s": wall,
            "img_per_s": n / wall if wall > 0 else 0.0,
            "p50_latency_s": _pct(lat, 50),
            "p99_latency_s": _pct(lat, 99),
            # queue wait vs compute reported separately (the serve-engine
            # convention): latency = queue_s + compute_s per image
            "mean_queue_s": float(queue_delay.mean()) if n else 0.0,
            "p50_queue_s": _pct(queue_delay, 50),
            "p99_queue_s": _pct(queue_delay, 99),
            "mean_compute_s": float(compute_s.mean()) if n else 0.0,
            "p50_compute_s": _pct(compute_s, 50),
            "p99_compute_s": _pct(compute_s, 99),
            "mean_occupancy": occupancy / (steps * B) if steps else 0.0,
            "mapper_cache": mapper_cache_stats(),
        }
        if self.mesh is not None:
            self.last_stats["mesh"] = {
                "devices": int(self.mesh.size),
                "axes": dict(self.mesh.shape),
            }
        if obs_on:
            self.last_stats["attribution"] = _attr.engine_row(
                wall_s=wall, modeled=modeled, steps=steps,
                covered_steps=covered_steps)
            self._publish_metrics(lat, queue_delay, compute_s)
        if streaming_on:
            _streaming.remove_collector(self._stream_collector)
        return outputs

    def _publish_metrics(self, lat, queue_delay, compute_s) -> None:
        """Push this call's stats into the repro.obs registry (telemetry
        enabled only)."""
        st = self.last_stats
        _obs_metrics.counter(
            "vision_images_total", "images inferred").inc(st["images"])
        _obs_metrics.counter(
            "vision_steps_total", "vision engine steps").inc(st["steps"])
        _obs_metrics.gauge(
            "vision_img_per_s", "last call's image throughput").set(
                st["img_per_s"])
        h_lat = _obs_metrics.histogram(
            "vision_image_latency_seconds", "per-image completion latency")
        h_q = _obs_metrics.histogram(
            "vision_image_queue_seconds", "per-image queue wait")
        h_c = _obs_metrics.histogram(
            "vision_image_compute_seconds", "per-image batch compute time")
        for i in range(len(lat)):
            h_lat.observe(float(lat[i]))
            h_q.observe(float(queue_delay[i]))
            h_c.observe(float(compute_s[i]))
        self._publish_resource_gauges()

    def _publish_resource_gauges(self) -> None:
        mc = mapper_cache_stats()
        _obs_metrics.gauge(
            "mapper_cache_hit_rate", "blocking-decision cache hit rate").set(
                mc["hit_rate"])
        _obs_metrics.gauge(
            "mapper_cache_entries", "blocking-decision cache entries").set(
                mc["entries"])

    def _stream_collector(self) -> None:
        """Streaming-exporter callback: refresh mapper gauges mid-run."""
        if _obs.enabled():
            self._publish_resource_gauges()
