"""On-accelerator letterbox/resize preprocessing.

The engine's jitted step has ONE fixed shape; real traffic has images of
every size.  ``letterbox`` bridges the two: aspect-preserving resize onto
the model's input canvas with centered constant-fill padding, compiled once
per *input* geometry (LRU on the static shape) while the engine step's
shape never changes.  The YOLO convention (fill 0.5 on normalized inputs,
centered offsets) is the default; :func:`unletterbox_boxes` maps detections
back to the original image frame for the postprocess pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["letterbox", "letterbox_geometry", "unletterbox_boxes"]


def letterbox_geometry(in_hw: tuple[int, int], target_hw: tuple[int, int]
                       ) -> tuple[tuple[int, int], tuple[int, int]]:
    """((resized_h, resized_w), (pad_top, pad_left)) for an aspect-
    preserving fit of ``in_hw`` into ``target_hw``."""
    h, w = in_hw
    th, tw = target_hw
    if min(h, w) < 1 or min(th, tw) < 1:
        raise ValueError(f"degenerate letterbox geometry {in_hw}->{target_hw}")
    scale = min(th / h, tw / w)
    nh = max(1, min(th, round(h * scale)))
    nw = max(1, min(tw, round(w * scale)))
    return (nh, nw), ((th - nh) // 2, (tw - nw) // 2)


@functools.lru_cache(maxsize=512)
def _letterbox_jit(in_shape: tuple[int, int, int],
                   target_hw: tuple[int, int], fill: float, dtype_name: str):
    (nh, nw), (pt, pl) = letterbox_geometry(in_shape[:2], target_hw)
    th, tw = target_hw
    C = in_shape[2]

    def fn(image):
        img = image.astype(jnp.float32)
        resized = jax.image.resize(img, (nh, nw, C), method="linear")
        canvas = jnp.full((th, tw, C), fill, jnp.float32)
        canvas = jax.lax.dynamic_update_slice(canvas, resized, (pt, pl, 0))
        return canvas.astype(jnp.dtype(dtype_name))

    return jax.jit(fn)


def letterbox(image, target_hw: tuple[int, int], *, fill: float = 0.5,
              dtype=jnp.float32) -> jax.Array:
    """Aspect-preserving resize + centered pad to ``target_hw`` (H, W, C).

    Jit-compiled per distinct (input shape, target, fill) -- serving a
    stream of arbitrary sizes costs one compile per unique geometry, and
    the downstream model step shape stays fixed."""
    image = jnp.asarray(image)
    if image.ndim != 3:
        raise ValueError(f"letterbox expects (H, W, C), got {image.shape}")
    fn = _letterbox_jit(tuple(image.shape), tuple(target_hw), float(fill),
                        jnp.dtype(dtype).name)
    return fn(image)


def unletterbox_boxes(boxes, in_hw: tuple[int, int],
                      target_hw: tuple[int, int]):
    """Map normalized xyxy boxes on the letterboxed canvas back to
    normalized coordinates on the original ``in_hw`` image."""
    (nh, nw), (pt, pl) = letterbox_geometry(in_hw, target_hw)
    th, tw = target_hw
    boxes = jnp.asarray(boxes)
    x = (boxes[..., 0::2] * tw - pl) / nw
    y = (boxes[..., 1::2] * th - pt) / nh
    out = jnp.stack([x[..., 0], y[..., 0], x[..., 1], y[..., 1]], axis=-1)
    return jnp.clip(out, 0.0, 1.0)
