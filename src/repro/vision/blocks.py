"""Conv-net building blocks on the Axon operator API.

Pure-functional, matching ``repro.models``: ``init_*`` builds parameter
pytrees (plain dicts), the forward functions consume them.  Every
convolution flows through ``axon.conv2d`` / ``axon.depthwise_conv2d`` and
every dense layer through ``axon.einsum``, so the whole model zoo rides the
policy-dispatched Pallas im2col path (or XLA, bit-for-bit, under
``backend="xla"``).

BatchNorm is *folded*: these are inference-mode blocks, so each conv carries
the BN scale pre-multiplied into its weights and the BN shift as a plain
bias -- one conv + bias + activation, exactly what the paper benchmarks.

Every conv call site also reports itself to the layer tracer (see
``repro.vision.trace``): under ``jax.eval_shape`` inside a ``trace_taps``
scope the records materialize without running any compute, which is how the
analytic runtime/energy models get their shapes *from the executable
models* instead of hand-written tables.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import axon
from repro.kernels.ref import conv_out_hw

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# layer tracing tap
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TracedConv:
    """One conv call site as executed (input geometry + resolved attrs)."""

    name: str
    H: int
    W: int
    C_in: int
    C_out: int
    kh: int
    kw: int
    stride: tuple[int, int]
    padding: tuple[tuple[int, int], tuple[int, int]]
    groups: int = 1
    depthwise: bool = False

    @property
    def H_out(self) -> int:
        return conv_out_hw(self.H, self.W, self.kh, self.kw, self.stride,
                           self.padding)[0]

    @property
    def W_out(self) -> int:
        return conv_out_hw(self.H, self.W, self.kh, self.kw, self.stride,
                           self.padding)[1]

    @property
    def macs(self) -> int:
        return (self.H_out * self.W_out * self.kh * self.kw
                * (self.C_in // self.groups) * self.C_out)


_TRACE: contextvars.ContextVar[list[TracedConv] | None] = \
    contextvars.ContextVar("vision_trace", default=None)


@contextlib.contextmanager
def trace_taps(records: list[TracedConv]):
    """Collect a ``TracedConv`` for every conv executed (or eval_shape'd)
    in scope."""
    token = _TRACE.set(records)
    try:
        yield records
    finally:
        _TRACE.reset(token)


def _tap(name, x, *, c_out, kh, kw, stride, padding, groups=1,
         depthwise=False) -> None:
    sink = _TRACE.get()
    if sink is None:
        return
    stride, padding, _, _ = axon.resolve_conv_geometry(
        stride, padding, kh, kw, x.shape[1], x.shape[2])
    sink.append(TracedConv(
        name=name, H=int(x.shape[1]), W=int(x.shape[2]),
        C_in=int(x.shape[3]), C_out=c_out, kh=kh, kw=kw, stride=stride,
        padding=padding, groups=groups, depthwise=depthwise))


# ---------------------------------------------------------------------------
# conv + folded-BN + activation
# ---------------------------------------------------------------------------


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return jax.nn.relu(x)
    if act == "leaky":
        return jax.nn.leaky_relu(x, 0.1)
    if act == "none":
        return x
    raise ValueError(f"unknown activation {act!r}")


def init_conv_bn(key, k: int, c_in: int, c_out: int, *, groups: int = 1,
                 dtype=jnp.float32) -> Params:
    """He-normal conv weight (kh, kw, C_in/groups, C_out) + folded-BN bias."""
    fan_in = k * k * (c_in // groups)
    w = jax.random.normal(key, (k, k, c_in // groups, c_out), jnp.float32)
    w = w * math.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype), "b": jnp.zeros((c_out,), dtype)}


def conv_bn_act(p: Params, x: jax.Array, *, stride=1, padding=0,
                groups: int = 1, act: str = "relu",
                name: str = "") -> jax.Array:
    kh, kw, _, c_out = p["w"].shape
    _tap(name, x, c_out=c_out, kh=kh, kw=kw, stride=stride, padding=padding,
         groups=groups)
    y = axon.conv2d(x, p["w"], stride=stride, padding=padding, groups=groups)
    return _act(y + p["b"], act)


def init_dwconv_bn(key, k: int, c: int, *, dtype=jnp.float32) -> Params:
    w = jax.random.normal(key, (k, k, c), jnp.float32) * math.sqrt(2.0 / (k * k))
    return {"w": w.astype(dtype), "b": jnp.zeros((c,), dtype)}


def dwconv_bn_act(p: Params, x: jax.Array, *, stride=1, padding=0,
                  act: str = "relu", name: str = "") -> jax.Array:
    kh, kw, c = p["w"].shape
    _tap(name, x, c_out=c, kh=kh, kw=kw, stride=stride, padding=padding,
         groups=c, depthwise=True)
    y = axon.depthwise_conv2d(x, p["w"], stride=stride, padding=padding)
    return _act(y + p["b"], act)


# ---------------------------------------------------------------------------
# composite blocks
# ---------------------------------------------------------------------------


def init_bottleneck(key, c_in: int, c_mid: int, c_out: int, *, stride: int,
                    dtype=jnp.float32) -> Params:
    """ResNet-v1 bottleneck: 1x1 reduce -> 3x3 (strided) -> 1x1 expand,
    plus a 1x1 projection shortcut when the shape changes."""
    keys = jax.random.split(key, 4)
    p = {
        "conv1": init_conv_bn(keys[0], 1, c_in, c_mid, dtype=dtype),
        "conv2": init_conv_bn(keys[1], 3, c_mid, c_mid, dtype=dtype),
        "conv3": init_conv_bn(keys[2], 1, c_mid, c_out, dtype=dtype),
    }
    if stride != 1 or c_in != c_out:
        p["down"] = init_conv_bn(keys[3], 1, c_in, c_out, dtype=dtype)
    return p


def bottleneck(p: Params, x: jax.Array, *, stride: int,
               name: str = "") -> jax.Array:
    h = conv_bn_act(p["conv1"], x, padding=0, name=f"{name}.conv1")
    h = conv_bn_act(p["conv2"], h, stride=stride, padding=1,
                    name=f"{name}.conv2")
    h = conv_bn_act(p["conv3"], h, padding=0, act="none", name=f"{name}.conv3")
    if "down" in p:
        x = conv_bn_act(p["down"], x, stride=stride, padding=0, act="none",
                        name=f"{name}.down")
    return jax.nn.relu(h + x)


def init_dw_separable(key, c_in: int, c_out: int, *,
                      dtype=jnp.float32) -> Params:
    """MobileNetV1 depthwise-separable: 3x3 DW conv + 1x1 pointwise."""
    k_dw, k_pw = jax.random.split(key)
    return {
        "dw": init_dwconv_bn(k_dw, 3, c_in, dtype=dtype),
        "pw": init_conv_bn(k_pw, 1, c_in, c_out, dtype=dtype),
    }


def dw_separable(p: Params, x: jax.Array, *, stride: int,
                 name: str = "") -> jax.Array:
    h = dwconv_bn_act(p["dw"], x, stride=stride, padding=1, name=f"{name}.dw")
    return conv_bn_act(p["pw"], h, padding=0, name=f"{name}.pw")


# ---------------------------------------------------------------------------
# parameter-free spatial ops
# ---------------------------------------------------------------------------


def max_pool(x: jax.Array, k: int, *, stride: int | None = None,
             padding=0) -> jax.Array:
    """NHWC max pool; ``padding`` follows conv2d (int / pairs / SAME)."""
    s = k if stride is None else stride
    (sh, sw), pads, _, _ = axon.resolve_conv_geometry(
        s, padding, k, k, x.shape[1], x.shape[2])
    lowest = (-jnp.inf if jnp.issubdtype(x.dtype, jnp.floating)
              else jnp.iinfo(x.dtype).min)
    return jax.lax.reduce_window(
        x, lowest, jax.lax.max, (1, k, k, 1), (1, sh, sw, 1),
        [(0, 0), pads[0], pads[1], (0, 0)])


def global_avg_pool(x: jax.Array) -> jax.Array:
    """(N, H, W, C) -> (N, C), fp32 mean."""
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(x.dtype)


def upsample2x(x: jax.Array) -> jax.Array:
    """Nearest-neighbor 2x spatial upsample (YOLO feature-pyramid step)."""
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


def init_dense(key, d_in: int, d_out: int, *, dtype=jnp.float32) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    return {"w": (w / math.sqrt(d_in)).astype(dtype),
            "b": jnp.zeros((d_out,), dtype)}


def dense(p: Params, x: jax.Array) -> jax.Array:
    return axon.einsum("nd,df->nf", x, p["w"]) + p["b"]
