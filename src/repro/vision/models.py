"""The conv-net model zoo: ResNet50, MobileNetV1, YOLOv3(-tiny).

These are the paper's benchmark workloads (Fig. 11 / §5.2.1) as *runnable*
models in the repo's functional style: ``init(rng, cfg)`` builds a parameter
pytree, ``apply(params, x, cfg)`` runs inference.  Every conv executes
through ``axon.conv2d`` / ``axon.depthwise_conv2d``, so the same forward
pass runs on the Pallas implicit-im2col kernels (``backend="pallas"`` /
``"interpret"``) or plain XLA (``backend="xla"``) and the two are compared
layer-for-layer in the tests.

Classification archs return ``(N, num_classes)`` logits; the YOLO archs
return a dict of detection maps (one ``(N, h, w, anchors * (5 + classes))``
tensor per scale).

``cfg.reduced()`` gives a same-family small variant (tiny input, thin
channels, single-block stages) for CPU smoke tests; shape tracing
(``repro.vision.trace``) always works on the full config because it never
runs compute.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.vision import blocks as B

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}

ARCHS = ("resnet", "mobilenet_v1", "yolov3_tiny", "yolov3")


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    name: str
    arch: str                                 # one of ARCHS
    input_hw: tuple[int, int] = (224, 224)
    in_channels: int = 3
    num_classes: int = 1000
    width_mult: float = 1.0
    # resnet: bottleneck blocks per stage; yolov3: residual reps per stage
    stage_blocks: tuple[int, ...] = (3, 4, 6, 3)
    anchors_per_scale: int = 3                # yolo heads
    param_dtype: str = "float32"

    def __post_init__(self) -> None:
        if self.arch not in ARCHS:
            raise ValueError(f"arch must be one of {ARCHS}, got {self.arch!r}")
        if self.arch == "yolov3" and len(self.stage_blocks) != 5:
            raise ValueError(
                "yolov3 needs one stage_blocks entry per Darknet-53 stage "
                f"(5), got {self.stage_blocks}")

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def head_channels(self) -> int:
        """YOLO detection-map channels: anchors * (x, y, w, h, obj + classes)."""
        return self.anchors_per_scale * (5 + self.num_classes)

    def reduced(self) -> "VisionConfig":
        """Small same-family variant for CPU smoke tests."""
        hw = (64, 64) if self.arch.startswith("yolo") else (32, 32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            input_hw=hw,
            num_classes=8,
            width_mult=self.width_mult * 0.125,
            stage_blocks=tuple(1 for _ in self.stage_blocks),
        )


def _c(cfg: VisionConfig, c: int) -> int:
    """Width-scaled channel count (full configs: identity)."""
    return max(4, int(round(c * cfg.width_mult)))


# ---------------------------------------------------------------------------
# ResNet50 (He et al. 2016): bottleneck stages, the paper's Fig. 11 workload
# ---------------------------------------------------------------------------


def _resnet_init(key, cfg: VisionConfig):
    dt = cfg.pdtype
    keys = jax.random.split(key, 2 + len(cfg.stage_blocks))
    stem_c = _c(cfg, 64)
    p = {"stem": B.init_conv_bn(keys[0], 7, cfg.in_channels, stem_c, dtype=dt),
         "stages": []}
    c_in = stem_c
    for si, n_blocks in enumerate(cfg.stage_blocks):
        c_mid = _c(cfg, 64 * 2 ** si)
        c_out = 4 * c_mid
        stage = []
        bkeys = jax.random.split(keys[1 + si], n_blocks)
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            stage.append(B.init_bottleneck(bkeys[bi], c_in, c_mid, c_out,
                                           stride=stride, dtype=dt))
            c_in = c_out
        p["stages"].append(stage)
    p["head"] = B.init_dense(keys[-1], c_in, cfg.num_classes, dtype=dt)
    return p


def _resnet_apply(p, x, cfg: VisionConfig):
    h = B.conv_bn_act(p["stem"], x, stride=2, padding=3, name="conv1")
    h = B.max_pool(h, 3, stride=2, padding=1)
    for si, n_blocks in enumerate(cfg.stage_blocks):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = B.bottleneck(p["stages"][si][bi], h, stride=stride,
                             name=f"l{si + 1}b{bi + 1}")
    return B.dense(p["head"], B.global_avg_pool(h))


# ---------------------------------------------------------------------------
# MobileNetV1 (Howard et al. 2017): the Fig. 14 depthwise workload
# ---------------------------------------------------------------------------

# (pointwise C_out, DW stride) per separable block; DW runs on the previous
# block's output channels.  The DW layers are exactly core.workloads
# MOBILENET_DW (the 14x14x512 s1 block repeats 5x; the table lists uniques).
_MOBILENET_SPEC = ((64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
                   (512, 2), (512, 1), (512, 1), (512, 1), (512, 1), (512, 1),
                   (1024, 2), (1024, 1))


def _mobilenet_init(key, cfg: VisionConfig):
    dt = cfg.pdtype
    keys = jax.random.split(key, len(_MOBILENET_SPEC) + 2)
    c_in = _c(cfg, 32)
    p = {"stem": B.init_conv_bn(keys[0], 3, cfg.in_channels, c_in, dtype=dt),
         "blocks": []}
    for i, (c_out, _) in enumerate(_MOBILENET_SPEC):
        c_out = _c(cfg, c_out)
        p["blocks"].append(B.init_dw_separable(keys[1 + i], c_in, c_out,
                                               dtype=dt))
        c_in = c_out
    p["head"] = B.init_dense(keys[-1], c_in, cfg.num_classes, dtype=dt)
    return p


def _mobilenet_apply(p, x, cfg: VisionConfig):
    h = B.conv_bn_act(p["stem"], x, stride=2, padding=1, name="conv1")
    for i, (_, stride) in enumerate(_MOBILENET_SPEC):
        h = B.dw_separable(p["blocks"][i], h, stride=stride, name=f"sep{i + 1}")
    return B.dense(p["head"], B.global_avg_pool(h))


# ---------------------------------------------------------------------------
# YOLOv3-tiny (Redmon & Farhadi 2018): 2-scale detection head
# ---------------------------------------------------------------------------

_TINY_BACKBONE = (16, 32, 64, 128, 256, 512)   # each followed by a maxpool


def _yolov3_tiny_init(key, cfg: VisionConfig):
    dt = cfg.pdtype
    keys = jax.random.split(key, 12)
    p = {"backbone": []}
    c_in = cfg.in_channels
    for i, c in enumerate(_TINY_BACKBONE):
        c = _c(cfg, c)
        p["backbone"].append(B.init_conv_bn(keys[i], 3, c_in, c, dtype=dt))
        c_in = c
    c1024, c256, c512, c128 = (_c(cfg, c) for c in (1024, 256, 512, 128))
    p["conv7"] = B.init_conv_bn(keys[6], 3, c_in, c1024, dtype=dt)
    p["neck"] = B.init_conv_bn(keys[7], 1, c1024, c256, dtype=dt)
    p["head1"] = B.init_conv_bn(keys[8], 3, c256, c512, dtype=dt)
    p["det1"] = B.init_conv_bn(keys[9], 1, c512, cfg.head_channels, dtype=dt)
    p["up"] = B.init_conv_bn(keys[10], 1, c256, c128, dtype=dt)
    # concat: upsampled c128 + the 256-wide backbone feature (pre-pool)
    p["head2"] = B.init_conv_bn(keys[11], 3, c128 + _c(cfg, 256), c256,
                                dtype=dt)
    p["det2"] = B.init_conv_bn(jax.random.fold_in(key, 99), 1, c256,
                               cfg.head_channels, dtype=dt)
    return p


def _yolov3_tiny_apply(p, x, cfg: VisionConfig):
    h = x
    route = None
    for i, pb in enumerate(p["backbone"]):
        h = B.conv_bn_act(pb, h, padding=1, act="leaky", name=f"conv{i + 1}")
        if i == 4:
            route = h                       # 256-wide feature, pre-pool
        # the last pool keeps 13x13: stride 1, SAME
        if i < len(p["backbone"]) - 1:
            h = B.max_pool(h, 2, stride=2)
        else:
            h = B.max_pool(h, 2, stride=1, padding="SAME")
    h = B.conv_bn_act(p["conv7"], h, padding=1, act="leaky", name="conv7")
    neck = B.conv_bn_act(p["neck"], h, act="leaky", name="neck")
    h1 = B.conv_bn_act(p["head1"], neck, padding=1, act="leaky", name="head1")
    det1 = B.conv_bn_act(p["det1"], h1, act="none", name="det1")
    u = B.conv_bn_act(p["up"], neck, act="leaky", name="up1")
    u = jnp.concatenate([B.upsample2x(u), route], axis=-1)
    h2 = B.conv_bn_act(p["head2"], u, padding=1, act="leaky", name="head2")
    det2 = B.conv_bn_act(p["det2"], h2, act="none", name="det2")
    return {"det1": det1, "det2": det2}


# ---------------------------------------------------------------------------
# YOLOv3 (Darknet-53 backbone + 3-scale head) -- the Fig. 11 workload
# ---------------------------------------------------------------------------

_DARKNET_STAGES = (64, 128, 256, 512, 1024)    # downsample target per stage


def _yolov3_init(key, cfg: VisionConfig):
    dt = cfg.pdtype
    keys = jax.random.split(key, 8)
    c_in = _c(cfg, 32)
    p = {"stem": B.init_conv_bn(keys[0], 3, cfg.in_channels, c_in, dtype=dt),
         "stages": []}
    for si, c_out in enumerate(_DARKNET_STAGES):
        c_out = _c(cfg, c_out)
        half = max(4, c_out // 2)
        reps = cfg.stage_blocks[si]
        skeys = jax.random.split(keys[1 + si % 5], reps * 2 + 1)
        stage = {"down": B.init_conv_bn(skeys[0], 3, c_in, c_out, dtype=dt),
                 "res": []}
        for r in range(reps):
            stage["res"].append({
                "a": B.init_conv_bn(skeys[1 + 2 * r], 1, c_out, half, dtype=dt),
                "b": B.init_conv_bn(skeys[2 + 2 * r], 3, half, c_out, dtype=dt),
            })
        p["stages"].append(stage)
        c_in = c_out
    # three heads; each is 3x (1x1 narrow, 3x3 wide) pairs + linear det conv
    def head(hkey, c_in, narrow, wide):
        hkeys = jax.random.split(hkey, 8)
        pairs = []
        for r in range(3):
            pairs.append({
                "a": B.init_conv_bn(hkeys[2 * r], 1,
                                    c_in if r == 0 else wide, narrow, dtype=dt),
                "b": B.init_conv_bn(hkeys[2 * r + 1], 3, narrow, wide,
                                    dtype=dt),
            })
        return {"pairs": pairs,
                "det": B.init_conv_bn(hkeys[6], 1, wide, cfg.head_channels,
                                      dtype=dt)}

    c512, c256, c128 = _c(cfg, 512), _c(cfg, 256), _c(cfg, 128)
    p["head1"] = head(keys[6], _c(cfg, 1024), c512, _c(cfg, 1024))
    p["up1"] = B.init_conv_bn(jax.random.fold_in(key, 1), 1, c512, c256,
                              dtype=dt)
    p["head2"] = head(keys[7], c256 + c512, c256, c512)
    p["up2"] = B.init_conv_bn(jax.random.fold_in(key, 2), 1, c256, c128,
                              dtype=dt)
    p["head3"] = head(jax.random.fold_in(key, 3), c128 + c256, c128, c256)
    return p


def _yolo_head(hp, x, *, name):
    """3x (1x1, 3x3) pairs; returns (route, det) -- route taps pair 3's 1x1."""
    h = x
    route = None
    for r, pair in enumerate(hp["pairs"]):
        h = B.conv_bn_act(pair["a"], h, act="leaky", name=f"{name}.{r}.a")
        route = h
        h = B.conv_bn_act(pair["b"], h, padding=1, act="leaky",
                          name=f"{name}.{r}.b")
    det = B.conv_bn_act(hp["det"], h, act="none",
                        name=f"det{name[-1]}")
    return route, det


def _yolov3_apply(p, x, cfg: VisionConfig):
    h = B.conv_bn_act(p["stem"], x, padding=1, act="leaky", name="conv0")
    feats = []                      # per-stage outputs (indexed, not keyed by
    for si, stage in enumerate(p["stages"]):  # channel count: widths collide
        h = B.conv_bn_act(stage["down"], h, stride=2, padding=1, act="leaky",
                          name=f"down{_DARKNET_STAGES[si]}")
        for r, res in enumerate(stage["res"]):
            y = B.conv_bn_act(res["a"], h, act="leaky",
                              name=f"res{_DARKNET_STAGES[si]}.{r}.a")
            y = B.conv_bn_act(res["b"], y, padding=1, act="leaky",
                              name=f"res{_DARKNET_STAGES[si]}.{r}.b")
            h = h + y
        feats.append(h)
    route1, det1 = _yolo_head(p["head1"], h, name="head1")
    u = B.conv_bn_act(p["up1"], route1, act="leaky", name="up1")
    u = jnp.concatenate([B.upsample2x(u), feats[3]], axis=-1)   # 512-w stage
    route2, det2 = _yolo_head(p["head2"], u, name="head2")
    u = B.conv_bn_act(p["up2"], route2, act="leaky", name="up2")
    u = jnp.concatenate([B.upsample2x(u), feats[2]], axis=-1)   # 256-w stage
    _, det3 = _yolo_head(p["head3"], u, name="head3")
    return {"det1": det1, "det2": det2, "det3": det3}


# ---------------------------------------------------------------------------
# public init / apply
# ---------------------------------------------------------------------------

_ARCH_FNS = {
    "resnet": (_resnet_init, _resnet_apply),
    "mobilenet_v1": (_mobilenet_init, _mobilenet_apply),
    "yolov3_tiny": (_yolov3_tiny_init, _yolov3_tiny_apply),
    "yolov3": (_yolov3_init, _yolov3_apply),
}


def init(key, cfg: VisionConfig):
    """Build the parameter pytree (BN pre-folded into conv weight + bias)."""
    return _ARCH_FNS[cfg.arch][0](key, cfg)


def apply(params, x, cfg: VisionConfig):
    """Inference forward pass.  ``x: (N, H, W, C)`` in ``cfg.input_hw``.

    Classification archs return ``(N, num_classes)`` logits; YOLO archs a
    dict of per-scale detection maps."""
    if x.shape[1:] != (*cfg.input_hw, cfg.in_channels):
        raise ValueError(
            f"{cfg.name}: expected input (N, {cfg.input_hw[0]}, "
            f"{cfg.input_hw[1]}, {cfg.in_channels}), got {x.shape}")
    return _ARCH_FNS[cfg.arch][1](params, x, cfg)
