"""Calibration: activation-scale observers driven from the dispatcher.

PTQ needs one number per quantized op: the scale of the activation feeding
it.  Rather than threading hooks through every model, calibration taps the
single choke point all contractions already flow through -- ``axon.einsum``
/ ``axon.conv2d`` call :func:`record` whenever a :class:`QuantizedTensor`
weight arrives.  Inside a :func:`calibration` scope each record feeds an
observer keyed by the *identity* of the weight object, so running the model
eagerly over a calibration batch collects per-call-site statistics with
zero model-code changes; :meth:`Calibration.finalize` then rebuilds the
params pytree with ``act_scale`` filled in.

Eager-only by design: under ``jit`` / ``scan`` tracing the activation is an
abstract tracer with no value to observe, so :func:`record` skips tracers
(the LM's scan-stacked layers therefore stay weight-only -- exactly the
serve engine's int8 mode).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import numpy as np

from repro.quant.qtensor import QuantizedTensor, abs_max_scale


class MinMaxObserver:
    """Track the running absolute maximum over calibration batches."""

    def __init__(self) -> None:
        self.amax = 0.0

    def observe(self, x) -> None:
        self.amax = max(self.amax, float(np.max(np.abs(np.asarray(x)))))

    def scale(self):
        return abs_max_scale(self.amax)


class PercentileObserver:
    """Clip to a high percentile of |x| instead of the outlier maximum.

    Keeps the max of per-batch percentiles -- a batch-streaming surrogate
    for the global percentile that never stores the full value population.
    """

    def __init__(self, pct: float = 99.9) -> None:
        if not 0 < pct <= 100:
            raise ValueError(f"pct must be in (0, 100], got {pct}")
        self.pct = pct
        self.amax = 0.0

    def observe(self, x) -> None:
        val = float(np.percentile(np.abs(np.asarray(x)), self.pct))
        self.amax = max(self.amax, val)

    def scale(self):
        return abs_max_scale(self.amax)


OBSERVERS = {"minmax": MinMaxObserver, "percentile": PercentileObserver}


class Calibration:
    """Collects one observer per QuantizedTensor identity."""

    def __init__(self, observer: str = "percentile") -> None:
        if observer not in OBSERVERS:
            raise ValueError(
                f"observer must be one of {sorted(OBSERVERS)}, "
                f"got {observer!r}")
        self._factory = OBSERVERS[observer]
        self._seen: dict[int, tuple[QuantizedTensor, object]] = {}

    def record(self, qt: QuantizedTensor, x) -> None:
        if isinstance(x, jax.core.Tracer):
            return                      # traced call site: nothing to observe
        entry = self._seen.get(id(qt))
        if entry is None:
            entry = (qt, self._factory())
            self._seen[id(qt)] = entry
        entry[1].observe(x)

    @property
    def n_sites(self) -> int:
        return len(self._seen)

    def finalize(self, params):
        """Rebuild ``params`` with observed ``act_scale`` on each recorded
        QuantizedTensor (unrecorded ones stay weight-only)."""
        def fill(leaf):
            if isinstance(leaf, QuantizedTensor):
                entry = self._seen.get(id(leaf))
                if entry is not None:
                    scale = entry[1].scale().reshape((1,) * leaf.ndim)
                    return dataclasses.replace(leaf, act_scale=scale)
            return leaf

        return jax.tree.map(
            fill, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


_CALIB: contextvars.ContextVar[Calibration | None] = \
    contextvars.ContextVar("quant_calibration", default=None)


@contextlib.contextmanager
def calibration(observer: str = "percentile"):
    """Scope under which dispatch records activations feeding quantized
    weights: ``with calibration() as c: apply(qparams, batch)``."""
    calib = Calibration(observer)
    token = _CALIB.set(calib)
    try:
        yield calib
    finally:
        _CALIB.reset(token)


def record(qt: QuantizedTensor, x) -> None:
    """Dispatcher tap: no-op unless a calibration scope is active."""
    calib = _CALIB.get()
    if calib is not None:
        calib.record(qt, x)
