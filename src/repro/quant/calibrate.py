"""Calibration: activation-scale observers driven from the dispatcher.

PTQ needs one number per quantized op: the scale of the activation feeding
it.  Rather than threading hooks through every model, calibration taps the
single choke point all contractions already flow through -- ``axon.einsum``
/ ``axon.conv2d`` call :func:`record` whenever a :class:`QuantizedTensor`
weight arrives.  Inside a :func:`calibration` scope each record feeds an
observer keyed by the *identity* of the weight object, so running the model
eagerly over a calibration batch collects per-call-site statistics with
zero model-code changes; :meth:`Calibration.finalize` then rebuilds the
params pytree with ``act_scale`` filled in.

Eager-only by design: under ``jit`` / ``scan`` tracing the activation is an
abstract tracer with no value to observe, so :func:`record` skips tracers.
Scan-stacked LM layers get their per-layer statistics through the *alias*
mechanism instead: a scan-unrolled calibration pass (``repro.quant.ptq.
quantize_lm``) slices each layer's weights out of the stacked pytree and
registers the slices via :meth:`Calibration.alias`, so their records land
in per-``(stacked tensor, layer index)`` observers.  ``finalize`` turns
those into a stacked ``(L, 1, ..., 1)`` ``act_scale`` that ``lax.scan``
slices back down to a per-layer scalar at serve time -- the keepdims /
negative-axis layout rule extended to activation scales.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import numpy as np

from repro.quant.qtensor import (QuantizedTensor, abs_max_scale,
                                 slice_leading)


class MinMaxObserver:
    """Track the running absolute maximum over calibration batches."""

    def __init__(self) -> None:
        self.amax = 0.0

    def observe(self, x) -> None:
        self.amax = max(self.amax, float(np.max(np.abs(np.asarray(x)))))

    def scale(self, fmt: str = "int8"):
        return abs_max_scale(self.amax, fmt)


class PercentileObserver:
    """Clip to a high percentile of |x| instead of the outlier maximum.

    Keeps the max of per-batch percentiles -- a batch-streaming surrogate
    for the global percentile that never stores the full value population.
    """

    def __init__(self, pct: float = 99.9) -> None:
        if not 0 < pct <= 100:
            raise ValueError(f"pct must be in (0, 100], got {pct}")
        self.pct = pct
        self.amax = 0.0

    def observe(self, x) -> None:
        val = float(np.percentile(np.abs(np.asarray(x)), self.pct))
        self.amax = max(self.amax, val)

    def scale(self, fmt: str = "int8"):
        return abs_max_scale(self.amax, fmt)


OBSERVERS = {"minmax": MinMaxObserver, "percentile": PercentileObserver}

# site key for records that are not layer-sliced (plain eager call sites)
_WHOLE = -1


class Calibration:
    """Collects observers per QuantizedTensor identity (and layer site).

    Plain call sites key by ``id(weight)``; scan-unrolled drivers register
    per-layer slices with :meth:`alias` so their records accumulate under
    ``(id(stacked weight), layer index)`` instead.
    """

    def __init__(self, observer: str = "percentile") -> None:
        if observer not in OBSERVERS:
            raise ValueError(
                f"observer must be one of {sorted(OBSERVERS)}, "
                f"got {observer!r}")
        self._factory = OBSERVERS[observer]
        # id(parent qt) -> (parent qt, {site: observer}); site is _WHOLE for
        # unsliced records, a layer index for aliased ones
        self._seen: dict[int, tuple[QuantizedTensor, dict[int, object]]] = {}
        # id(slice qt) -> (slice qt keep-alive, id(parent), layer index)
        self._alias: dict[int, tuple[QuantizedTensor, int, int]] = {}
        # (id(parent), layer index) -> memoized slice (see layer_slice)
        self._slices: dict[tuple[int, int], QuantizedTensor] = {}

    def alias(self, sliced: QuantizedTensor, parent: QuantizedTensor,
              index: int) -> None:
        """Route future records of ``sliced`` to ``parent``'s observer for
        layer ``index``.  Keeps both objects alive so the id keys stay
        unambiguous for the lifetime of the scope."""
        self._alias[id(sliced)] = (sliced, id(parent), int(index))
        if id(parent) not in self._seen:
            self._seen[id(parent)] = (parent, {})

    def layer_slice(self, parent: QuantizedTensor,
                    index: int) -> QuantizedTensor:
        """Memoized per-layer slice of a stacked weight, alias-registered.

        Scan-unrolled drivers call this once per (weight, layer) per batch;
        memoizing keeps ONE slice alive per layer for the whole scope
        instead of one per batch -- calibration memory stays O(params), not
        O(params x batches)."""
        key = (id(parent), int(index))
        cached = self._slices.get(key)
        if cached is None:
            cached = self._slices[key] = slice_leading(parent, index)
            self.alias(cached, parent, index)
        return cached

    def record(self, qt: QuantizedTensor, x) -> None:
        if isinstance(x, jax.core.Tracer):
            return                      # traced call site: nothing to observe
        alias = self._alias.get(id(qt))
        if alias is not None:
            _, key, site = alias
        else:
            key, site = id(qt), _WHOLE
            if key not in self._seen:
                self._seen[key] = (qt, {})
        sites = self._seen[key][1]
        obs = sites.get(site)
        if obs is None:
            obs = sites[site] = self._factory()
        obs.observe(x)

    @property
    def n_sites(self) -> int:
        return sum(len(sites) for _, sites in self._seen.values())

    def finalize(self, params):
        """Rebuild ``params`` with observed ``act_scale`` on each recorded
        QuantizedTensor (unrecorded ones stay weight-only).

        Whole-tensor records produce a per-tensor ``(1, ..., 1)`` scale;
        layer-aliased records produce a stacked ``(L, 1, ..., 1)`` scale
        (one slot per leading-axis layer; layers that never recorded fall
        back to the max observed scale, keeping them servable)."""
        import jax.numpy as jnp

        def fill(leaf):
            if not isinstance(leaf, QuantizedTensor):
                return leaf
            entry = self._seen.get(id(leaf))
            if entry is None or not entry[1]:
                return leaf
            sites = entry[1]
            fmt = leaf.fmt
            if set(sites) == {_WHOLE}:
                scale = sites[_WHOLE].scale(fmt).reshape((1,) * leaf.ndim)
                return dataclasses.replace(leaf, act_scale=scale)
            L = leaf.q.shape[0]
            per_layer = {s: float(o.scale(fmt)) for s, o in sites.items()
                         if s != _WHOLE}
            fallback = max(per_layer.values())
            vals = [per_layer.get(l, fallback) for l in range(L)]
            scale = jnp.asarray(vals, jnp.float32).reshape(
                (L,) + (1,) * (leaf.ndim - 1))
            return dataclasses.replace(leaf, act_scale=scale)

        return jax.tree.map(
            fill, params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


_CALIB: contextvars.ContextVar[Calibration | None] = \
    contextvars.ContextVar("quant_calibration", default=None)


@contextlib.contextmanager
def calibration(observer: str = "percentile"):
    """Scope under which dispatch records activations feeding quantized
    weights: ``with calibration() as c: apply(qparams, batch)``."""
    calib = Calibration(observer)
    token = _CALIB.set(calib)
    try:
        yield calib
    finally:
        _CALIB.reset(token)


def current_calibration() -> Calibration | None:
    """The active calibration scope, if any (used by scan-unrolled
    drivers to register layer-slice aliases)."""
    return _CALIB.get()


def record(qt: QuantizedTensor, x) -> None:
    """Dispatcher tap: no-op unless a calibration scope is active."""
    calib = _CALIB.get()
    if calib is not None:
        calib.record(qt, x)
