"""``repro.quant`` -- sub-byte post-training quantization (int8/int4/fp8).

Quantize once (:func:`quantize_model` / :func:`quantize_lm` /
:func:`quantize_lm_weights`), then serve many: the resulting pytree drops
into the existing engines and every ``axon`` operator dispatches the
quantized Pallas kernels matching each weight's storage format under
``ExecutionPolicy(precision="int8")`` (or ``"fp8"``) -- or dequantizes back
to the float reference path under any other policy, which is what the
differential tests pin the kernels against.

Formats: per-channel symmetric **int8** (full int8 x int8 with calibrated
activation scales, weight-only otherwise), nibble-packed **int4**
(weight-only, 0.5 B/elem), and **fp8** e4m3 (1 B/elem both sides, f32
accumulation).  Scan-stacked LM layers calibrate through the scan-unrolled
:func:`quantize_lm` pass, which threads per-layer activation scales through
``lax.scan`` as stacked ``(L, 1, 1)`` arrays.
"""
from repro.quant.calibrate import (
    Calibration,
    MinMaxObserver,
    OBSERVERS,
    PercentileObserver,
    calibration,
    current_calibration,
)
from repro.quant.ptq import (
    LM_WEIGHT_KEYS,
    QuantizedParams,
    lm_calibration_forward,
    quantize_lm,
    quantize_lm_weights,
    quantize_model,
    quantize_vision,
)
from repro.quant.qtensor import (
    FP8_DTYPE,
    FP8_MAX,
    QuantizedTensor,
    dequantize,
    is_quantized,
    pack_int4,
    quantize_activation,
    quantize_weight,
    slice_leading,
    to_fp8,
    unpack_int4,
)

__all__ = [
    "Calibration",
    "FP8_DTYPE",
    "FP8_MAX",
    "LM_WEIGHT_KEYS",
    "MinMaxObserver",
    "OBSERVERS",
    "PercentileObserver",
    "QuantizedParams",
    "QuantizedTensor",
    "calibration",
    "current_calibration",
    "dequantize",
    "is_quantized",
    "lm_calibration_forward",
    "pack_int4",
    "quantize_activation",
    "quantize_lm",
    "quantize_lm_weights",
    "quantize_model",
    "quantize_vision",
    "quantize_weight",
    "slice_leading",
    "to_fp8",
    "unpack_int4",
]
