"""``repro.quant`` -- int8 post-training quantization.

Quantize once (:func:`quantize_model` / :func:`quantize_lm_weights`), then
serve many: the resulting pytree drops into the existing engines and every
``axon`` operator dispatches the int8 Pallas kernels under
``ExecutionPolicy(precision="int8")`` -- or dequantizes back to the float
reference path under any other policy, which is what the differential tests
pin the kernels against.
"""
from repro.quant.calibrate import (
    Calibration,
    MinMaxObserver,
    OBSERVERS,
    PercentileObserver,
    calibration,
)
from repro.quant.ptq import (
    LM_WEIGHT_KEYS,
    QuantizedParams,
    quantize_lm_weights,
    quantize_model,
    quantize_vision,
)
from repro.quant.qtensor import (
    QuantizedTensor,
    dequantize,
    is_quantized,
    quantize_activation,
    quantize_weight,
)

__all__ = [
    "Calibration",
    "LM_WEIGHT_KEYS",
    "MinMaxObserver",
    "OBSERVERS",
    "PercentileObserver",
    "QuantizedParams",
    "QuantizedTensor",
    "calibration",
    "dequantize",
    "is_quantized",
    "quantize_activation",
    "quantize_lm_weights",
    "quantize_model",
    "quantize_vision",
    "quantize_weight",
]
