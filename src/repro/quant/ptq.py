"""Post-training quantization drivers: float params in, QuantizedParams out.

``QuantizedParams`` is not a new container -- it is the SAME pytree
structure as the float params with weight leaves swapped for
:class:`~repro.quant.qtensor.QuantizedTensor` nodes, so every consumer
(``jax.jit``, ``lax.scan`` over stacked layers, the serve/vision engines)
traverses it unchanged.

Two walks cover the repo's model families:

  * :func:`quantize_vision` -- conv (``(kh, kw, C_in, C_out)``) and dense
    (``(d_in, d_out)``) leaves stored under the ``"w"`` key, per-channel
    over the last axis.  Depthwise weights (3-D) stay float: they ride the
    VPU path, not the im2col GeMM.
  * :func:`quantize_lm_weights` -- the einsum-only projection weights of
    the LM zoo (attention/MLP/MoE/lm_head), per-channel over the last axis
    with ``reduce_axes=(-2,)`` so scan-stacked ``(L, d_in, d_out)`` (and
    MoE ``(L, E, d_in, d_out)``) leaves keep independent per-layer scales.
    Embeddings and weights that models reshape/transpose directly (e.g.
    MLA's absorbed ``kv_b``) are deliberately excluded.

:func:`quantize_model` adds calibration on top: quantize weights, run the
model eagerly over calibration batches inside a
:func:`~repro.quant.calibrate.calibration` scope (the axon dispatcher
records the activation feeding every quantized op), and finalize the
observed activation scales into the pytree -- quantize once, serve many.

:func:`quantize_lm` is the LM counterpart, solving the problem eager
calibration cannot: the LM zoo executes its layers under ``lax.scan`` over
stacked params, where activations are tracers with no value to observe.
The driver runs a *scan-unrolled* forward instead -- a Python loop over
layers that slices each layer's params out of the stacked pytree
(:func:`~repro.quant.qtensor.slice_leading` -- the same slice ``lax.scan``
performs) and registers the slices as per-layer observation sites.
``finalize`` stacks the per-layer scales into ``(L, 1, 1)`` ``act_scale``
arrays that scan slices back to per-layer scalars at serve time, upgrading
LM serving from weight-only to calibrated activation int8.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from repro.quant import calibrate as C
from repro.quant.qtensor import (QuantizedTensor, quantize_weight,
                                 slice_leading)

QuantizedParams = Any        # float-params pytree with QuantizedTensor leaves

# LM projection weights that only ever flow through axon.einsum (never
# reshaped/transposed/gathered by model code), so swapping them for
# QuantizedTensor nodes is transparent.
LM_WEIGHT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",              # GQA attention (+ MLA's wo)
    "w_gate", "w_up", "w_down",          # dense SwiGLU and stacked MoE
    "lm_head",                           # untied logits projection
})


def _walk(tree, quantize_leaf: Callable[[str, Any], Any], key: str = ""):
    if isinstance(tree, dict):
        return {k: _walk(v, quantize_leaf, k) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        walked = [_walk(v, quantize_leaf, key) for v in tree]
        return type(tree)(walked) if isinstance(tree, tuple) else walked
    return quantize_leaf(key, tree)


def _is_float_array(leaf) -> bool:
    return (hasattr(leaf, "dtype") and hasattr(leaf, "ndim")
            and not isinstance(leaf, QuantizedTensor)
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def quantize_vision(params) -> QuantizedParams:
    """Quantize a vision model-zoo param pytree (conv + dense weights)."""
    def leaf(key, v):
        if key == "w" and _is_float_array(v) and v.ndim in (2, 4):
            return quantize_weight(v, axis=-1)
        return v

    return _walk(params, leaf)


def quantize_lm_weights(params,
                        keys: frozenset[str] = LM_WEIGHT_KEYS,
                        *, fmt: str = "int8") -> QuantizedParams:
    """Weight-only quantization for the LM zoo (the serve engine's decode
    mode).  ``fmt``: ``"int8"`` (1 B/elem), ``"int4"`` (packed nibbles,
    0.5 B/elem), or ``"fp8"`` (e4m3)."""
    def leaf(key, v):
        if key in keys and _is_float_array(v) and v.ndim >= 2:
            return quantize_weight(v, axis=-1, reduce_axes=(-2,), fmt=fmt)
        return v

    return _walk(params, leaf)


def _slice_layer(stacked, index: int, calib: C.Calibration | None):
    """One layer's params out of a scan-stacked pytree -- exactly the slice
    ``lax.scan`` performs -- registering QuantizedTensor slices as per-layer
    calibration sites (memoized per layer, so repeated batches reuse one
    slice instead of accumulating copies)."""
    def leaf(v):
        if isinstance(v, QuantizedTensor):
            if calib is not None:
                return calib.layer_slice(v, index)
            return slice_leading(v, index)
        return v[index]

    return jax.tree.map(
        leaf, stacked, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def lm_calibration_forward(qparams, batch, cfg):
    """The LM forward with every ``lax.scan`` over layers unrolled.

    Functionally ``transformer.forward`` (same blocks, same order, shared
    attn every N layers, final norm + head) but executed eagerly layer by
    layer so the dispatcher's calibration tap sees concrete activations at
    each quantized call site -- keyed per layer through the slice aliases.
    Calibration-only: the scanned path stays the one that serves.
    """
    from repro.models import layers as L          # deferred: avoids a cycle
    from repro.models import transformer as T

    calib = C.current_calibration()
    x = T._embed_inputs(qparams, batch, cfg)
    positions = jnp.arange(x.shape[1])
    for p_s, stage in zip(qparams["stages"], cfg.stages):
        every = stage.shared_attn_every
        for l in range(stage.n_layers):
            if every and l % every == 0:
                x, _ = T.shared_attn_fwd(p_s["shared"], x, cfg, positions,
                                         None, False)
            layer_p = _slice_layer(p_s["layers"], l, calib)
            x, _, _ = T.block_fwd(layer_p, x, cfg, stage,
                                  positions=positions)
    x = L.rmsnorm(qparams["final_norm"], x)
    return T._head_logits(qparams, x, cfg)


def quantize_lm(params, cfg, calib_batches: Iterable[Any], *,
                fmt: str = "int8",
                observer: str = "percentile") -> QuantizedParams:
    """Scan-safe LM PTQ: per-channel weights + per-layer activation scales.

    Quantizes the projection weights (:func:`quantize_lm_weights`), runs the
    scan-unrolled forward over ``calib_batches`` (dicts with ``"tokens"``
    etc., as consumed by ``transformer.forward``) inside a calibration
    scope, and finalizes stacked ``(L, 1, 1)`` activation scales that
    ``lax.scan`` slices to per-layer scalars at serve time.  The result
    serves through ``ServeEngine`` as calibrated activation int8 (full
    int8 x int8 decode GeMMs) rather than weight-only.

    ``fmt="int4"`` / ``"fp8"`` quantize the weights at those widths; int4
    stays weight-only at dispatch (the act scales are recorded but unused).
    """
    qparams = quantize_lm_weights(params, fmt=fmt)
    with C.calibration(observer) as calib:
        for batch in calib_batches:
            lm_calibration_forward(qparams, batch, cfg)
    if calib.n_sites == 0:
        raise ValueError(
            "calibration observed no quantized call sites -- check that the "
            "config matches the params and the batches are non-empty")
    return calib.finalize(qparams)


def quantize_model(params, apply_fn: Callable[[QuantizedParams, Any], Any],
                   calib_batches: Iterable[Any], *,
                   weight_quantizer: Callable[[Any], QuantizedParams]
                   = quantize_vision,
                   observer: str = "percentile") -> QuantizedParams:
    """Full PTQ: per-channel weights + calibrated activation scales.

    ``apply_fn(qparams, batch)`` must run the model EAGERLY (not jitted):
    calibration observes concrete activation values at each quantized call
    site.  Returns the quantized pytree with ``act_scale`` filled in, ready
    for ``ExecutionPolicy(precision="int8")`` serving.
    """
    qparams = weight_quantizer(params)
    with C.calibration(observer) as calib:
        for batch in calib_batches:
            apply_fn(qparams, batch)
    if calib.n_sites == 0:
        raise ValueError(
            "calibration observed no quantized call sites -- apply_fn must "
            "run the quantized params eagerly through axon operators")
    return calib.finalize(qparams)
