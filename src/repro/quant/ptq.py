"""Post-training quantization drivers: float params in, QuantizedParams out.

``QuantizedParams`` is not a new container -- it is the SAME pytree
structure as the float params with weight leaves swapped for
:class:`~repro.quant.qtensor.QuantizedTensor` nodes, so every consumer
(``jax.jit``, ``lax.scan`` over stacked layers, the serve/vision engines)
traverses it unchanged.

Two walks cover the repo's model families:

  * :func:`quantize_vision` -- conv (``(kh, kw, C_in, C_out)``) and dense
    (``(d_in, d_out)``) leaves stored under the ``"w"`` key, per-channel
    over the last axis.  Depthwise weights (3-D) stay float: they ride the
    VPU path, not the im2col GeMM.
  * :func:`quantize_lm_weights` -- the einsum-only projection weights of
    the LM zoo (attention/MLP/MoE/lm_head), per-channel over the last axis
    with ``reduce_axes=(-2,)`` so scan-stacked ``(L, d_in, d_out)`` (and
    MoE ``(L, E, d_in, d_out)``) leaves keep independent per-layer scales.
    Embeddings and weights that models reshape/transpose directly (e.g.
    MLA's absorbed ``kv_b``) are deliberately excluded.

:func:`quantize_model` adds calibration on top: quantize weights, run the
model eagerly over calibration batches inside a
:func:`~repro.quant.calibrate.calibration` scope (the axon dispatcher
records the activation feeding every quantized op), and finalize the
observed activation scales into the pytree -- quantize once, serve many.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax.numpy as jnp

from repro.quant import calibrate as C
from repro.quant.qtensor import QuantizedTensor, quantize_weight

QuantizedParams = Any        # float-params pytree with QuantizedTensor leaves

# LM projection weights that only ever flow through axon.einsum (never
# reshaped/transposed/gathered by model code), so swapping them for
# QuantizedTensor nodes is transparent.
LM_WEIGHT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",              # GQA attention (+ MLA's wo)
    "w_gate", "w_up", "w_down",          # dense SwiGLU and stacked MoE
    "lm_head",                           # untied logits projection
})


def _walk(tree, quantize_leaf: Callable[[str, Any], Any], key: str = ""):
    if isinstance(tree, dict):
        return {k: _walk(v, quantize_leaf, k) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        walked = [_walk(v, quantize_leaf, key) for v in tree]
        return type(tree)(walked) if isinstance(tree, tuple) else walked
    return quantize_leaf(key, tree)


def _is_float_array(leaf) -> bool:
    return (hasattr(leaf, "dtype") and hasattr(leaf, "ndim")
            and not isinstance(leaf, QuantizedTensor)
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def quantize_vision(params) -> QuantizedParams:
    """Quantize a vision model-zoo param pytree (conv + dense weights)."""
    def leaf(key, v):
        if key == "w" and _is_float_array(v) and v.ndim in (2, 4):
            return quantize_weight(v, axis=-1)
        return v

    return _walk(params, leaf)


def quantize_lm_weights(params,
                        keys: frozenset[str] = LM_WEIGHT_KEYS
                        ) -> QuantizedParams:
    """Weight-only int8 for the LM zoo (the serve engine's decode mode)."""
    def leaf(key, v):
        if key in keys and _is_float_array(v) and v.ndim >= 2:
            return quantize_weight(v, axis=-1, reduce_axes=(-2,))
        return v

    return _walk(params, leaf)


def quantize_model(params, apply_fn: Callable[[QuantizedParams, Any], Any],
                   calib_batches: Iterable[Any], *,
                   weight_quantizer: Callable[[Any], QuantizedParams]
                   = quantize_vision,
                   observer: str = "percentile") -> QuantizedParams:
    """Full PTQ: per-channel weights + calibrated activation scales.

    ``apply_fn(qparams, batch)`` must run the model EAGERLY (not jitted):
    calibration observes concrete activation values at each quantized call
    site.  Returns the quantized pytree with ``act_scale`` filled in, ready
    for ``ExecutionPolicy(precision="int8")`` serving.
    """
    qparams = weight_quantizer(params)
    with C.calibration(observer) as calib:
        for batch in calib_batches:
            apply_fn(qparams, batch)
    if calib.n_sites == 0:
        raise ValueError(
            "calibration observed no quantized call sites -- apply_fn must "
            "run the quantized params eagerly through axon operators")
    return calib.finalize(qparams)
