"""``QuantizedTensor``: the sub-byte carrier the whole quant subsystem rides on.

A quantized weight is a pytree node holding the quantized payload, a float32
scale broadcastable against it (``keepdims`` layout), and an optional
calibrated *activation* scale for the op that consumes it.  The node ducks
as an array (``shape`` / ``ndim`` / ``dtype`` report the logical *float*
tensor), so model code passes it to ``axon.einsum`` / ``conv2d`` unchanged
and the dispatcher decides between the quantized kernels and the
dequantize-to-float reference path.

Three storage formats share the one container (``fmt`` property):

  * ``int8`` : 1 byte per element, symmetric, the PR-4 baseline.
  * ``int4`` : ``bits=4`` -- two nibbles packed per int8 byte along the
               *reduction* axis (``-2``), values in [-7, 7].  Weight-only:
               the kernels unpack in the epilogue, activations stay float.
  * ``fp8``  : ``float8_e4m3fn`` payload, scaled so each channel's abs-max
               lands on e4m3's top of range (448).

Layout rules that make the container survive the repo's structural
transforms without special cases:

  * ``axis`` (the per-channel dimension) is stored *negative*,
  * ``scale`` / ``act_scale`` keep reduced dimensions as size-1
    (``keepdims``), and
  * int4 packing runs along axis ``-2`` -- also negative,

so when ``jax.lax.scan`` slices a stacked ``(L, d_in, d_out)`` weight down
to ``(d_in, d_out)`` per layer, the sliced children still line up: the
channel axis is still ``-1``, the packed axis is still ``-2``, and a sliced
``(1, d_out)`` scale (or per-layer ``(1, 1)`` activation scale from a
stacked ``(L, 1, 1)``) still broadcasts.  Quantization is symmetric
(zero-point 0), so zero padding of quantized operands is exact -- conv
spatial padding needs no zero-point surgery.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
INT4_MAX = 7.0
FP8_MAX = 448.0          # float8_e4m3fn finite max
FP8_DTYPE = jnp.float8_e4m3fn
_EPS = 1e-12

# abs-max -> per-format activation/weight quantization divisor
FMT_MAX = {"int8": INT8_MAX, "int4": INT4_MAX, "fp8": FP8_MAX}


# ---------------------------------------------------------------------------
# int4 nibble packing
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array, axis: int = -2) -> jax.Array:
    """Pack int8 values in [-8, 7] two-per-byte along ``axis``.

    Consecutive pairs ``(q0, q1)`` become ``(q1 << 4) | (q0 & 0xF)``; an odd
    axis length is zero-padded (symmetric quantization makes the pad exact).
    The packed axis shrinks to ``ceil(size / 2)``.
    """
    axis = axis if axis >= 0 else q.ndim + axis
    size = q.shape[axis]
    if size % 2:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
    q = q.astype(jnp.int8)
    lo = jax.lax.slice_in_dim(q, 0, None, 2, axis)
    hi = jax.lax.slice_in_dim(q, 1, None, 2, axis)
    return ((hi << 4) | (lo & 0xF)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, size: int, axis: int = -2) -> jax.Array:
    """Inverse of :func:`pack_int4`: int8 values in [-8, 7], sign-extended.

    ``size`` is the logical (unpacked) axis length -- the trailing pad
    nibble of an odd-size axis is dropped.
    """
    axis = axis if axis >= 0 else packed.ndim + axis
    p = packed.astype(jnp.int8)
    lo = ((p << 4) >> 4).astype(jnp.int8)        # arithmetic: sign-extends
    hi = (p >> 4).astype(jnp.int8)
    both = jnp.stack([lo, hi], axis=axis + 1)    # (..., n/2, 2, ...)
    shape = list(packed.shape)
    shape[axis] = 2 * shape[axis]
    out = both.reshape(shape)
    return jax.lax.slice_in_dim(out, 0, size, 1, axis)


# ---------------------------------------------------------------------------
# the container
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Symmetric quantized tensor: ``dequant = unpack(q).astype(f32) * scale``.

    ``q``        : payload -- int8 (``bits=8``), nibble-packed int8
                   (``bits=4``, packed along axis ``-2``), or
                   ``float8_e4m3fn`` (``bits=8`` with fp8 payload).
    ``scale``    : float32, logical ndim with reduced dims kept as 1.
    ``act_scale``: optional float32 scale for the activation feeding the op
                   that consumes this weight -- per-tensor (size 1), or
                   per-layer ``(L, 1, ..., 1)`` on scan-stacked weights so
                   ``lax.scan`` slices a per-layer scalar; filled in by
                   calibration.  ``None`` = weight-only mode.
    ``axis``     : per-channel (output-feature) axis, negative indexing.
    ``dtype_name``: the logical float dtype dequantization restores.
    ``bits``     : 8 or 4 (4 = nibble-packed int payload).
    ``pack_size``: logical length of the packed axis (``-2``) when
                   ``bits=4``; static so ``shape`` stays concrete under
                   tracing.  None for 8-bit formats.
    """

    q: jax.Array
    scale: jax.Array
    act_scale: jax.Array | None = None
    axis: int = -1
    dtype_name: str = "float32"
    bits: int = 8
    pack_size: int | None = None

    # -- array duck-typing (logical view) -----------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        s = tuple(self.q.shape)
        if self.bits == 4:
            s = s[:-2] + (self.pack_size,) + s[-1:]
        return s

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def fmt(self) -> str:
        """Storage format: ``"int8"``, ``"int4"``, or ``"fp8"``."""
        if self.bits == 4:
            return "int4"
        if self.q.dtype == FP8_DTYPE:
            return "fp8"
        return "int8"

    def layout_errors(self) -> list[str]:
        """Structural violations of the layout rules (empty = conformant).

        The rules (negative channel axis, keepdims scales, int4 packing
        along ``-2``, broadcast-trivial ``act_scale`` trailing dims) are
        exactly what makes the container survive ``lax.scan`` slicing --
        see the module docstring.  The static analyzer
        (``repro.analysis.qt_invariants``) calls this on every
        representative construction."""
        errs: list[str] = []
        if self.axis >= 0:
            errs.append(
                f"channel axis {self.axis} must be stored negative so it "
                "survives leading-axis slicing (lax.scan)")
        elif not -self.q.ndim <= self.axis:
            errs.append(
                f"channel axis {self.axis} out of range for ndim "
                f"{self.q.ndim}")
        logical = self.shape
        if self.scale.ndim != len(logical):
            errs.append(
                f"scale ndim {self.scale.ndim} != logical ndim "
                f"{len(logical)} (keepdims layout required)")
        else:
            for d, (sd, ld) in enumerate(zip(self.scale.shape, logical)):
                if sd not in (1, ld):
                    errs.append(
                        f"scale dim {d} is {sd}, broadcastable against "
                        f"neither 1 nor logical {ld}")
        if self.bits == 4:
            if self.pack_size is None:
                errs.append("bits=4 requires pack_size (logical -2 length)")
            elif self.q.ndim < 2:
                errs.append("bits=4 requires ndim >= 2 (packing along -2)")
            elif self.q.shape[-2] != -(-self.pack_size // 2):
                errs.append(
                    f"packed axis -2 is {self.q.shape[-2]}, expected "
                    f"ceil({self.pack_size} / 2) = "
                    f"{-(-self.pack_size // 2)}")
            if self.axis != -1:
                errs.append(
                    f"int4 requires channel axis -1 (packing owns -2), "
                    f"got {self.axis}")
        elif self.pack_size is not None:
            errs.append(f"pack_size={self.pack_size} is only valid on "
                        "bits=4 tensors")
        if self.act_scale is not None and self.act_scale.ndim > 0:
            trailing = self.act_scale.shape[1:]
            if any(d != 1 for d in trailing):
                errs.append(
                    f"act_scale shape {tuple(self.act_scale.shape)} must "
                    "be per-tensor (size 1) or (L, 1, ..., 1) so scan "
                    "slices a per-layer scalar")
        return errs

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale, self.act_scale), (
            self.axis, self.dtype_name, self.bits, self.pack_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale, act_scale = children
        axis, dtype_name, bits, pack_size = aux
        return cls(q=q, scale=scale, act_scale=act_scale, axis=axis,
                   dtype_name=dtype_name, bits=bits, pack_size=pack_size)


def slice_leading(qt: QuantizedTensor, index: int) -> QuantizedTensor:
    """Slice one layer out of a scan-stacked QuantizedTensor.

    Mirrors exactly what ``lax.scan`` does when the stacked tensor rides the
    xs pytree: every array child loses its leading axis; the negative-axis
    aux data stays valid on the slice.  Used by the scan-unrolled
    calibration pass."""
    return dataclasses.replace(
        qt, q=qt.q[index], scale=qt.scale[index],
        act_scale=None if qt.act_scale is None else qt.act_scale[index])


def quantize_weight(w: jax.Array, *, axis: int = -1,
                    reduce_axes: tuple[int, ...] | None = None,
                    fmt: str = "int8") -> QuantizedTensor:
    """Per-channel symmetric quantization of a weight tensor.

    ``axis`` is the output-feature (per-channel) dimension.  ``reduce_axes``
    are the dimensions the abs-max reduction runs over -- default: every
    axis except ``axis`` (plain dense / conv weights).  Stacked weights
    (scan-stacked layers ``(L, d_in, d_out)``, stacked MoE experts) pass
    ``reduce_axes=(-2,)`` so leading stack dims keep independent scales.

    ``fmt``: ``"int8"`` (1 B/elem), ``"int4"`` (packed 0.5 B/elem,
    weight-only -- requires channel axis ``-1`` and ndim >= 2 so the packed
    reduction axis is ``-2``), or ``"fp8"`` (e4m3, 1 B/elem).
    """
    if fmt not in FMT_MAX:
        raise ValueError(f"fmt must be one of {sorted(FMT_MAX)}, got {fmt!r}")
    axis = axis if axis < 0 else axis - w.ndim
    if reduce_axes is None:
        reduce_axes = tuple(a for a in range(-w.ndim, 0) if a != axis)
    else:
        reduce_axes = tuple(a if a < 0 else a - w.ndim for a in reduce_axes)
        if axis in reduce_axes:
            raise ValueError(
                f"channel axis {axis} cannot also be reduced {reduce_axes}")
    if fmt == "int4" and (w.ndim < 2 or axis != -1):
        raise ValueError(
            "int4 packs along the reduction axis -2: needs ndim >= 2 and "
            f"channel axis -1, got ndim={w.ndim}, axis={axis}")
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes, keepdims=True)
    qmax = FMT_MAX[fmt]
    scale = jnp.maximum(amax, _EPS) / qmax
    name = jnp.dtype(w.dtype).name
    if fmt == "fp8":
        q = jnp.clip(wf / scale, -qmax, qmax).astype(FP8_DTYPE)
        return QuantizedTensor(q=q, scale=scale, axis=axis, dtype_name=name)
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax).astype(jnp.int8)
    if fmt == "int4":
        return QuantizedTensor(q=pack_int4(q, axis=-2), scale=scale,
                               axis=axis, dtype_name=name, bits=4,
                               pack_size=w.shape[-2])
    return QuantizedTensor(q=q, scale=scale, axis=axis, dtype_name=name)


def to_fp8(x: jax.Array) -> jax.Array:
    """The one e4m3 cast: clamp to the finite range, then convert.

    Every fp8 ingestion path (weight-only activations, the calibrated
    activation quantizer, the ``precision="fp8"`` float-GeMM cast) funnels
    through here so the saturation semantics can never diverge."""
    return jnp.clip(x, -FP8_MAX, FP8_MAX).astype(FP8_DTYPE)


def quantize_activation(x: jax.Array, act_scale: jax.Array,
                        fmt: str = "int8") -> jax.Array:
    """On-the-fly symmetric activation quantization (per-tensor scale)."""
    xf = x.astype(jnp.float32) / act_scale.astype(jnp.float32)
    if fmt == "fp8":
        return to_fp8(xf)
    qmax = FMT_MAX[fmt]
    return jnp.clip(jnp.round(xf), -qmax, qmax).astype(jnp.int8)


def dequantize(qt: QuantizedTensor) -> jax.Array:
    """Restore the float tensor: the reference path and the fallback."""
    q = qt.q
    if qt.bits == 4:
        q = unpack_int4(q, qt.pack_size, axis=-2)
    return (q.astype(jnp.float32) * qt.scale).astype(qt.dtype)


def abs_max_scale(amax: float | jax.Array, fmt: str = "int8") -> jax.Array:
    """Activation scale from an observed absolute maximum."""
    return jnp.maximum(jnp.asarray(amax, jnp.float32), _EPS) / FMT_MAX[fmt]


def is_quantized(tree: Any) -> bool:
    """True if any leaf of ``tree`` is a :class:`QuantizedTensor`."""
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return any(isinstance(l, QuantizedTensor) for l in leaves)
