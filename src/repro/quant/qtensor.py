"""``QuantizedTensor``: the int8 carrier the whole quant subsystem rides on.

A quantized weight is a pytree node holding the int8 payload, a float32
scale broadcastable against it (``keepdims`` layout), and an optional
calibrated per-tensor *activation* scale for the op that consumes it.  The
node ducks as an array (``shape`` / ``ndim`` / ``dtype`` report the logical
*float* tensor), so model code passes it to ``axon.einsum`` / ``conv2d``
unchanged and the dispatcher decides between the int8 kernels and the
dequantize-to-float reference path.

Two layout rules make the container survive the repo's structural
transforms without special cases:

  * ``axis`` (the per-channel dimension) is stored *negative*, and
  * ``scale`` / ``act_scale`` keep reduced dimensions as size-1
    (``keepdims``),

so when ``jax.lax.scan`` slices a stacked ``(L, d_in, d_out)`` weight down
to ``(d_in, d_out)`` per layer, the sliced children still line up: the
channel axis is still ``-1`` and the sliced ``(1, d_out)`` scale still
broadcasts.  Quantization is symmetric (zero-point 0), so zero padding of
int8 operands is exact -- conv spatial padding needs no zero-point surgery.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
_EPS = 1e-12


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Symmetric int8 tensor: ``dequant = q.astype(f32) * scale``.

    ``q``        : int8 payload, the logical tensor's shape.
    ``scale``    : float32, same ndim as ``q`` with reduced dims kept as 1.
    ``act_scale``: optional per-tensor float32 scale (size 1) for the
                   activation feeding the op that consumes this weight --
                   filled in by calibration; ``None`` = weight-only mode.
    ``axis``     : per-channel (output-feature) axis, negative indexing.
    ``dtype_name``: the logical float dtype dequantization restores.
    """

    q: jax.Array
    scale: jax.Array
    act_scale: jax.Array | None = None
    axis: int = -1
    dtype_name: str = "float32"

    # -- array duck-typing (logical view) -----------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.q.shape)

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale, self.act_scale), (self.axis,
                                                      self.dtype_name)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale, act_scale = children
        axis, dtype_name = aux
        return cls(q=q, scale=scale, act_scale=act_scale, axis=axis,
                   dtype_name=dtype_name)


def quantize_weight(w: jax.Array, *, axis: int = -1,
                    reduce_axes: tuple[int, ...] | None = None
                    ) -> QuantizedTensor:
    """Per-channel symmetric int8 quantization of a weight tensor.

    ``axis`` is the output-feature (per-channel) dimension.  ``reduce_axes``
    are the dimensions the abs-max reduction runs over -- default: every
    axis except ``axis`` (plain dense / conv weights).  Stacked weights
    (scan-stacked layers ``(L, d_in, d_out)``, stacked MoE experts) pass
    ``reduce_axes=(-2,)`` so leading stack dims keep independent scales.
    """
    axis = axis if axis < 0 else axis - w.ndim
    if reduce_axes is None:
        reduce_axes = tuple(a for a in range(-w.ndim, 0) if a != axis)
    else:
        reduce_axes = tuple(a if a < 0 else a - w.ndim for a in reduce_axes)
        if axis in reduce_axes:
            raise ValueError(
                f"channel axis {axis} cannot also be reduced {reduce_axes}")
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / INT8_MAX
    q = jnp.clip(jnp.round(wf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale, axis=axis,
                           dtype_name=jnp.dtype(w.dtype).name)


def quantize_activation(x: jax.Array, act_scale: jax.Array) -> jax.Array:
    """On-the-fly symmetric int8 activation quantization (per-tensor)."""
    xf = x.astype(jnp.float32) / act_scale.astype(jnp.float32)
    return jnp.clip(jnp.round(xf), -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize(qt: QuantizedTensor) -> jax.Array:
    """Restore the float tensor: the reference path and the fallback."""
    return (qt.q.astype(jnp.float32) * qt.scale).astype(qt.dtype)


def abs_max_scale(amax: float | jax.Array) -> jax.Array:
    """Activation scale from an observed absolute maximum."""
    return jnp.maximum(jnp.asarray(amax, jnp.float32), _EPS) / INT8_MAX


def is_quantized(tree: Any) -> bool:
    """True if any leaf of ``tree`` is a :class:`QuantizedTensor`."""
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return any(isinstance(l, QuantizedTensor) for l in leaves)
