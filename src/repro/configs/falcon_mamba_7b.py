"""Falcon-Mamba 7B [arXiv:2410.05355]: pure Mamba-1, attention-free.

d_inner = 2 * d_model = 8192, state 16, dt_rank = d_model / 16 = 256.
Runs the long_500k decode cell with O(1) state.
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    d_model=4096,
    vocab=65024,
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    conv_k=4,
    stages=(StageCfg(n_layers=64, block="mamba1"),),
)
