"""Mixtral 8x7B [arXiv:2401.04088]: 8 experts top-2, sliding-window attention.

8 experts do not divide the 16-way model axis, so experts shard their FFN dim
(expert_shard='tp').  SWA window 4096 -> the long_500k decode cell runs with a
rolling window cache.
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    d_model=4096,
    vocab=32000,
    n_heads=32,
    n_kv=8,
    d_head=128,
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
    expert_shard="tp",
    rope_theta=1e6,
    stages=(StageCfg(n_layers=32, block="moe", window=4096),),
)
