"""Architecture configs: one module per assigned architecture (+ paper's own).

``get_config(name)`` resolves any of the ten assigned LM ids, e.g.
``get_config("mixtral-8x7b")`` or ``get_config("mixtral-8x7b", reduced=True)``
for the CPU smoke variant.  ``get_vision_config(name)`` resolves the conv-net
model zoo the same way (``repro.vision.models.VisionConfig``).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "deepseek-v3-671b",
    "mixtral-8x7b",
    "yi-9b",
    "deepseek-coder-33b",
    "qwen2.5-14b",
    "llama3-405b",
    "musicgen-medium",
    "zamba2-7b",
    "falcon-mamba-7b",
    "internvl2-1b",
)

VISION_IDS = (
    "resnet50",
    "yolov3-tiny",
    "yolov3",
    "mobilenet-v1",
)


def _load(name: str, *, reduced: bool):
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def get_config(name: str, *, reduced: bool = False):
    if name in VISION_IDS:
        raise ValueError(f"{name!r} is a vision config: use get_vision_config")
    return _load(name, reduced=reduced)


def get_vision_config(name: str, *, reduced: bool = False):
    if name not in VISION_IDS:
        raise ValueError(f"unknown vision config {name!r}; one of {VISION_IDS}")
    return _load(name, reduced=reduced)
