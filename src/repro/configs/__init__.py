"""Architecture configs: one module per assigned architecture (+ paper's own).

``get_config(name)`` resolves any of the ten assigned ids, e.g.
``get_config("mixtral-8x7b")`` or ``get_config("mixtral-8x7b", reduced=True)``
for the CPU smoke variant.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "deepseek-v3-671b",
    "mixtral-8x7b",
    "yi-9b",
    "deepseek-coder-33b",
    "qwen2.5-14b",
    "llama3-405b",
    "musicgen-medium",
    "zamba2-7b",
    "falcon-mamba-7b",
    "internvl2-1b",
)


def get_config(name: str, *, reduced: bool = False):
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg
