"""DeepSeek-Coder 33B [arXiv:2401.14196]: llama-architecture dense GQA."""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    d_model=7168,
    vocab=32256,
    n_heads=56,
    n_kv=8,
    d_head=128,
    d_ff=19200,
    rope_theta=1e5,
    stages=(StageCfg(n_layers=62, block="dense"),),
)
