"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Frontend is a STUB per the brief: input_specs() supplies precomputed frame
embeddings (B, S, d_model); the 4-codebook delay pattern is collapsed to a
single stream of vocab 2048 (backbone shapes unchanged, DESIGN.md §7).
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1536,
    vocab=2048,
    n_heads=24,
    n_kv=24,
    d_head=64,
    d_ff=6144,
    frontend="audio",
    stages=(StageCfg(n_layers=48, block="dense"),),
)
