"""YOLOv3 @ 416x416 (Darknet-53 backbone, 3-scale head; Redmon & Farhadi
2018) -- the paper's Fig. 11 / §5.2.1 detection workload.  ``stage_blocks``
are the Darknet-53 residual repeats."""
from repro.vision.models import VisionConfig

CONFIG = VisionConfig(
    name="yolov3",
    arch="yolov3",
    input_hw=(416, 416),
    num_classes=80,
    stage_blocks=(1, 2, 8, 8, 4),
    anchors_per_scale=3,
)
