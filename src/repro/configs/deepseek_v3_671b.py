"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA + 1 shared/256 routed top-8 MoE + MTP.

61 layers: first 3 dense (d_ff 18432), remaining 58 MoE with expert size 2048
(the assigned table's d_ff=2048 is the expert intermediate size).
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    d_model=7168,
    vocab=129280,
    n_heads=128,
    n_kv=128,
    d_head=128,
    d_ff=18432,
    n_experts=256,
    top_k=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    # §Perf iteration 6d: 'tp' (expert FFN dim over model) measured 20% less
    # collective traffic and 57% less memory than 'ep' on this pjit dispatch
    # -- XLA reshards the capacity buffer for EP instead of an all-to-all.
    # A shard_map all-to-all EP dispatch is the documented next step.
    expert_shard="tp",
    q_lora=1536,
    kv_lora=512,
    nope_head=128,
    rope_head=64,
    v_head=128,
    rope_theta=1e4,
    mtp=True,
    stages=(
        StageCfg(n_layers=3, block="dense", attn="mla"),
        StageCfg(n_layers=58, block="moe", attn="mla"),
    ),
)
