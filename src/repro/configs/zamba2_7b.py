"""Zamba2-7B [arXiv:2411.15242]: Mamba-2 backbone + weight-shared attention.

81 Mamba-2 layers with one weight-shared attention+MLP block applied every
6th layer (the dual-block/LoRA detail is simplified to a single shared block,
DESIGN.md §7).  d_inner = 2 * d_model = 7168, headdim 64 -> 112 SSD heads,
state 64.
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="zamba2-7b",
    d_model=3584,
    vocab=32000,
    n_heads=32,
    n_kv=32,
    d_head=112,
    d_ff=14336,
    ssm_state=64,
    d_inner=7168,
    mamba_headdim=64,
    conv_k=4,
    stages=(StageCfg(n_layers=81, block="hybrid", shared_attn_every=6),),
)
