"""Yi-9B [arXiv:2403.04652]: llama-architecture dense GQA."""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="yi-9b",
    d_model=4096,
    vocab=64000,
    n_heads=32,
    n_kv=4,
    d_head=128,
    d_ff=11008,
    rope_theta=5e6,
    stages=(StageCfg(n_layers=48, block="dense"),),
)
