"""YOLOv3-tiny @ 416x416 (Redmon & Farhadi 2018): the light 2-scale
detector -- the serving-friendly sibling of the paper's YOLOv3 workload."""
from repro.vision.models import VisionConfig

CONFIG = VisionConfig(
    name="yolov3-tiny",
    arch="yolov3_tiny",
    input_hw=(416, 416),
    num_classes=80,
    anchors_per_scale=3,
)
