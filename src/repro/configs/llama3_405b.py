"""Llama-3 405B [arXiv:2407.21783]: dense GQA, 128k vocab.

The biggest assigned config; training cells use bf16 params + gradient
accumulation (see launch/dryrun.py overrides).
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="llama3-405b",
    d_model=16384,
    vocab=128256,
    n_heads=128,
    n_kv=8,
    d_head=128,
    d_ff=53248,
    rope_theta=5e5,
    stages=(StageCfg(n_layers=126, block="dense"),),
)
