"""Qwen2.5-14B [hf:Qwen/Qwen2.5]: dense GQA with QKV bias."""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    d_model=5120,
    vocab=152064,
    n_heads=40,
    n_kv=8,
    d_head=128,
    d_ff=13824,
    qkv_bias=True,
    rope_theta=1e6,
    stages=(StageCfg(n_layers=48, block="dense"),),
)
