"""ResNet50 @ 224x224 (He et al. 2016) -- the paper's Fig. 11 / Table 3
conv workload, runnable through the Axon im2col path."""
from repro.vision.models import VisionConfig

CONFIG = VisionConfig(
    name="resnet50",
    arch="resnet",
    input_hw=(224, 224),
    num_classes=1000,
    stage_blocks=(3, 4, 6, 3),
)
