"""MobileNetV1 @ 224x224 (Howard et al. 2017) -- the paper's Fig. 14
depthwise (memory-bound) workload; its DW layers are the MOBILENET_DW
suite in ``repro.core.workloads``."""
from repro.vision.models import VisionConfig

CONFIG = VisionConfig(
    name="mobilenet-v1",
    arch="mobilenet_v1",
    input_hw=(224, 224),
    num_classes=1000,
)
