"""Model / run configuration schema shared by all architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class StageCfg:
    """A homogeneous run of layers (scanned as one unit)."""

    n_layers: int
    block: str                  # 'dense' | 'moe' | 'mamba1' | 'mamba2' | 'hybrid'
    attn: str = "gqa"           # 'gqa' | 'mla' (attention flavor for attn blocks)
    window: int = 0             # sliding-window size (0 = full attention)
    shared_attn_every: int = 0  # hybrid: one weight-shared attn block per k layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    stages: tuple[StageCfg, ...]
    # attention
    n_heads: int = 0
    n_kv: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # dense FFN
    d_ff: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    expert_shard: str = "ep"      # 'ep' (experts over model) | 'tp'
    moe_chunk: int = 4096         # tokens-per-row routed per chunk
    aux_loss_weight: float = 0.01
    # MLA
    q_lora: int = 0
    kv_lora: int = 0
    nope_head: int = 0
    rope_head: int = 0
    v_head: int = 0
    # SSM
    ssm_state: int = 0
    d_inner: int = 0
    conv_k: int = 4
    mamba_headdim: int = 64
    dt_rank: int = 0
    ssd_chunk: int = 64
    # frontends / heads
    frontend: str = "none"        # 'none' | 'audio' | 'vlm'
    n_patches: int = 0
    mtp: bool = False
    mtp_weight: float = 0.3
    tie_embeddings: bool = False
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    seq_shard: bool = True   # sequence-parallel residual stream (over 'model')
    loss_chunk: int = 512
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    exact_causal: bool = False

    # ---------------------------------------------------------------- utils
    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def vocab_pad(self) -> int:
        """Vocab rounded up to a multiple of 256 so the logits/embedding can
        shard over the 16-way model axis with 128-lane-friendly shards.
        Padded logit columns are masked to -inf in the loss and at decode."""
        return -(-self.vocab // 256) * 256

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for s in self.stages:
            total += s.n_layers * self._block_params(s)
            if s.shared_attn_every:
                total += self._attn_params("gqa") + 3 * d * self.d_ff
        return total

    def _attn_params(self, attn: str) -> int:
        d = self.d_model
        if attn == "mla":
            return (d * self.q_lora
                    + self.q_lora * self.n_heads * (self.nope_head + self.rope_head)
                    + d * (self.kv_lora + self.rope_head)
                    + self.kv_lora * self.n_heads * (self.nope_head + self.v_head)
                    + self.n_heads * self.v_head * d)
        return d * self.n_heads * self.d_head * 2 + d * self.n_kv * self.d_head * 2

    def _block_params(self, s: StageCfg) -> int:
        d = self.d_model
        if s.block == "dense":
            return self._attn_params(s.attn) + 3 * d * self.d_ff
        if s.block == "moe":
            moe = self.n_experts * 3 * d * self.d_ff_expert
            moe += self.n_shared_experts * 3 * d * self.d_ff_expert
            moe += d * self.n_experts
            return self._attn_params(s.attn) + moe
        if s.block == "mamba1":
            di, n = self.d_inner, self.ssm_state
            return (d * 2 * di + self.conv_k * di + di * (self.dt_rank + 2 * n)
                    + self.dt_rank * di + di * n + 2 * di + di * d)
        if s.block in ("mamba2", "hybrid"):
            di, n = self.d_inner, self.ssm_state
            nh = di // self.mamba_headdim
            return d * (2 * di + 2 * n + nh) + di * d
        raise ValueError(s.block)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        moe_layers = sum(s.n_layers for s in self.stages if s.block == "moe")
        all_experts = moe_layers * self.n_experts * 3 * d * self.d_ff_expert
        active = moe_layers * self.top_k * 3 * d * self.d_ff_expert
        return total - all_experts + active

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        scale_stage = lambda s: dataclasses.replace(
            s, n_layers=min(s.n_layers, 2),
            shared_attn_every=min(s.shared_attn_every, 2) if s.shared_attn_every else 0,
            window=min(s.window, 8) if s.window else 0)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=64,
            vocab=128,
            n_heads=4 if self.n_heads else 0,
            n_kv=min(self.n_kv, 2) if self.n_kv else 0,
            d_head=16 if self.d_head else 0,
            d_ff=128 if self.d_ff else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_ff_expert=64 if self.d_ff_expert else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora=32 if self.q_lora else 0,
            kv_lora=16 if self.kv_lora else 0,
            nope_head=16 if self.nope_head else 0,
            rope_head=8 if self.rope_head else 0,
            v_head=16 if self.v_head else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=128 if self.d_inner else 0,
            dt_rank=8 if self.dt_rank else 0,
            mamba_headdim=32 if self.d_inner else 64,
            ssd_chunk=8,
            n_patches=min(self.n_patches, 4) if self.n_patches else 0,
            loss_chunk=16,
            attn_block_q=8,
            attn_block_kv=8,
            stages=tuple(scale_stage(s) for s in self.stages),
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'
    microbatches: int = 1        # gradient-accumulation steps (train only)


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
