"""InternVL2-1B [arXiv:2404.16821]: Qwen2-0.5B LM backbone + InternViT stub.

The ViT frontend is a STUB per the brief: input_specs() supplies precomputed
patch embeddings (B, 256, d_model) concatenated before the text tokens.
"""
from repro.configs.base import ModelConfig, StageCfg

CONFIG = ModelConfig(
    name="internvl2-1b",
    d_model=896,
    vocab=151655,
    n_heads=14,
    n_kv=2,
    d_head=64,
    d_ff=4864,
    qkv_bias=True,
    rope_theta=1e6,
    frontend="vlm",
    n_patches=256,
    tie_embeddings=True,
    stages=(StageCfg(n_layers=24, block="dense"),),
)
