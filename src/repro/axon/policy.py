"""Execution policy: the single knob surface for the unified operator API.

Every ``axon.einsum`` / ``axon.matmul`` / ``axon.conv2d`` call resolves its
backend, blocking, and accumulation dtype from the *current* policy instead
of threading ``interpret=`` / ``block=`` / ``order=`` kwargs through every
layer.  The policy is read at trace time, so a jitted model staged under
``with axon.policy(backend="interpret")`` bakes the Pallas-interpreter path
into that compilation and nothing else.

Backends:

  auto      : Pallas kernels on TPU, XLA elsewhere (the production default).
  pallas    : always dispatch to the Axon Pallas kernels (interpreted off-TPU
              so the same policy runs in CI).
  interpret : force ``interpret=True`` pallas_calls (kernel bodies execute in
              Python -- the debugging/verification path).
  xla       : plain jnp/lax lowering, bit-identical to calling jnp directly.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.dataflows import Dataflow

BACKENDS = ("auto", "pallas", "xla", "interpret")
PRECISIONS = ("float", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """Scoped execution configuration for all Axon operators.

    ``block`` / ``order`` = None means "ask the mapper" (``auto`` mapping);
    setting them pins the Pallas blocking / loop order for every dispatch in
    scope.  ``accum_dtype`` is the dtype kernels accumulate partial products
    in (float32 only for now); result dtypes follow jnp.einsum semantics,
    i.e. the per-call ``preferred_element_type``.

    ``precision`` governs reduced-width dispatch.  ``"int8"`` and ``"fp8"``
    both route ``repro.quant.QuantizedTensor`` operands onto the quantized
    kernels *matching the tensor's own storage format* (int8 with int32
    accumulation when a calibrated activation scale is present, weight-only
    int8/int4, or e4m3 fp8 -- see ``QuantizedTensor.fmt``); ``"float"`` --
    the default -- dequantizes them back to the float reference path.
    ``"fp8"`` additionally casts eligible *float x float* GeMMs to e4m3
    operands (f32 accumulation) -- serving an unquantized model at 1-byte
    operand traffic.  Under ``"int8"`` float operands are unaffected, so one
    policy flip compares int8 against the float baseline on identical
    quantized params.

    ``attn_int8`` routes the cached-decode attention (QK^T and PV) through
    the int8 flash kernel with per-head scales (float softmax); it only
    takes effect on the kernel backends -- ``xla`` stays the float
    reference.  Single-device serving only for now: the kernel path skips
    the float path's cache-layout sharding constraints, so on a
    multi-device mesh it would gather the KV cache (see ROADMAP).
    """

    backend: str = "auto"
    precision: str = "float"
    attn_int8: bool = False
    block: tuple[int, int, int] | None = None   # fixed (bm, bk, bn)
    order: Dataflow | None = None               # fixed loop order
    # kernel partial-product accumulation dtype; float32 is the only value
    # the Pallas kernels implement (others raise at dispatch).  The XLA
    # backend is unaffected (use preferred_element_type per call there).
    accum_dtype: Any = jnp.float32
    zero_gate: bool = False    # route 2-D GeMMs through the zero-gating kernel
    # None = infer (interpret off-TPU so 'pallas' runs everywhere); an
    # explicit bool forces it -- False surfaces real pallas_call compile
    # errors on hosts that cannot lower Mosaic.
    force_interpret: bool | None = None

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, "
                f"got {self.precision!r}")

    def resolved_backend(self) -> str:
        """Collapse ``auto`` to the concrete backend for this process."""
        if self.backend == "auto":
            return "pallas" if jax.default_backend() == "tpu" else "xla"
        return self.backend

    def interpret(self) -> bool:
        """Whether Pallas kernels in scope run under ``interpret=True``."""
        if self.force_interpret is not None:
            return self.force_interpret
        if self.backend == "interpret":
            return True
        return jax.default_backend() == "cpu"


_DEFAULT = ExecutionPolicy()
# None marks "no scope active": current_policy() falls through to _DEFAULT,
# so set_default_policy takes effect in every thread/context at once.
_CURRENT: contextvars.ContextVar[ExecutionPolicy | None] = \
    contextvars.ContextVar("axon_policy", default=None)


def current_policy() -> ExecutionPolicy:
    cur = _CURRENT.get()
    return _DEFAULT if cur is None else cur


def set_default_policy(p: ExecutionPolicy) -> ExecutionPolicy:
    """Replace the process-wide default (what applies outside any
    ``policy`` scope, in every thread); returns the previous default."""
    global _DEFAULT
    old = _DEFAULT
    _DEFAULT = p
    return old


@contextlib.contextmanager
def policy(p: ExecutionPolicy | None = None, /, **overrides):
    """Scope a policy: ``with axon.policy(backend="interpret"): ...``.

    Accepts either a full ``ExecutionPolicy`` or field overrides applied on
    top of the current one.  Nests and restores on exit (including on error).
    """
    base = current_policy()
    new = dataclasses.replace(base, **overrides) if p is None else (
        dataclasses.replace(p, **overrides) if overrides else p)
    token = _CURRENT.set(new)
    try:
        yield new
    finally:
        _CURRENT.reset(token)
