"""Kernel registry: one table from operator kind to implementation.

The dispatcher (``repro.axon.dispatch``) never imports kernels directly -- it
looks them up here, so swapping a kernel (a new Mosaic GeMM, a GPU Triton
backend, a quantized path) is a one-line registration instead of a sweep over
every call site.
"""
from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}


def register(kind: str) -> Callable[[Callable], Callable]:
    """Decorator: ``@register("gemm")`` binds an implementation to a kind."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[kind] = fn
        return fn

    return deco


def get(kind: str) -> Callable:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"no kernel registered for {kind!r}; have {sorted(_REGISTRY)}"
        ) from None


def kinds() -> list[str]:
    return sorted(_REGISTRY)
