"""Kernel registry: one table from operator kind to implementation.

The dispatcher (``repro.axon.dispatch``) never imports kernels directly -- it
looks them up here, so swapping a kernel (a new Mosaic GeMM, a GPU Triton
backend, a quantized path) is a one-line registration instead of a sweep over
every call site.

Each registration also carries a :class:`KernelMeta` record -- the declared
contract the static analyzer (``repro.analysis``) checks against what the
kernel actually traces to: the accumulation dtype(s) the implementation is
allowed to use, whether it defines a custom VJP (or an explicit ``no_vjp``
marker with a stated reason), and which backend family it lowers through.
Runtime dispatch ignores the metadata entirely; it exists so contracts are
*declared* in exactly one place and verified mechanically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# accumulation-dtype contracts a kind may declare; "native" = XLA chooses
ACCUM_CONTRACTS = ("float32", "int32", "int32|float32", "native")
# VJP markers: "custom" = jax.custom_vjp defined; "no_vjp" = deliberately
# forward-only (reason required); "native" = XLA autodiff applies as-is
VJP_MARKERS = ("custom", "no_vjp", "native")
BACKEND_FAMILIES = ("pallas", "xla")


@dataclasses.dataclass(frozen=True)
class KernelMeta:
    """Declared contract for one registered kernel kind.

    ``accum``      : accumulation dtype(s) the kernel may use --
                     ``"float32"``, ``"int32"``, ``"int32|float32"`` (the
                     int8 path accumulates int32 when a calibrated
                     activation scale routes int8 x int8, float32 in
                     weight-only mode), or ``"native"`` (XLA backend,
                     accumulation left to the compiler).
    ``vjp``        : ``"custom"`` (jax.custom_vjp defined), ``"no_vjp"``
                     (forward-only by design -- ``vjp_reason`` required),
                     or ``"native"`` (plain XLA autodiff).
    ``vjp_reason`` : why a ``no_vjp`` kind is forward-only.
    ``backend``    : ``"pallas"`` or ``"xla"`` lowering family.
    """

    kind: str
    accum: str = "float32"
    vjp: str | None = None
    vjp_reason: str | None = None
    backend: str = "pallas"

    def __post_init__(self) -> None:
        if self.accum not in ACCUM_CONTRACTS:
            raise ValueError(
                f"{self.kind}: accum must be one of {ACCUM_CONTRACTS}, "
                f"got {self.accum!r}")
        if self.vjp is not None and self.vjp not in VJP_MARKERS:
            raise ValueError(
                f"{self.kind}: vjp must be one of {VJP_MARKERS} or None, "
                f"got {self.vjp!r}")
        if self.vjp == "no_vjp" and not self.vjp_reason:
            raise ValueError(
                f"{self.kind}: no_vjp marker requires a vjp_reason")
        if self.backend not in BACKEND_FAMILIES:
            raise ValueError(
                f"{self.kind}: backend must be one of {BACKEND_FAMILIES}, "
                f"got {self.backend!r}")

    @property
    def accum_dtypes(self) -> tuple[str, ...]:
        """The concrete dtype names this contract permits (empty for
        ``native`` -- no constraint)."""
        if self.accum == "native":
            return ()
        return tuple(self.accum.split("|"))


_REGISTRY: dict[str, Callable] = {}
_META: dict[str, KernelMeta] = {}


def register(kind: str, *, accum: str = "float32", vjp: str | None = None,
             vjp_reason: str | None = None,
             backend: str = "pallas") -> Callable[[Callable], Callable]:
    """Decorator: ``@register("gemm", accum="float32", vjp="custom")``
    binds an implementation (and its declared contract) to a kind."""
    m = KernelMeta(kind=kind, accum=accum, vjp=vjp, vjp_reason=vjp_reason,
                   backend=backend)

    def deco(fn: Callable) -> Callable:
        _REGISTRY[kind] = fn
        _META[kind] = m
        return fn

    return deco


def get(kind: str) -> Callable:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"no kernel registered for {kind!r}; have {sorted(_REGISTRY)}"
        ) from None


def meta(kind: str) -> KernelMeta:
    """Declared contract for ``kind`` (KeyError for unknown kinds)."""
    try:
        return _META[kind]
    except KeyError:
        raise KeyError(
            f"no metadata registered for {kind!r}; have {sorted(_META)}"
        ) from None


def metas() -> dict[str, KernelMeta]:
    """All declared contracts, keyed by kind (a copy)."""
    return dict(_META)


def kinds() -> list[str]:
    return sorted(_REGISTRY)
