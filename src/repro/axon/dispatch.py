"""The unified operator front door: ``axon.einsum`` / ``matmul`` / ``conv2d``.

Every contraction in the repo flows through here.  ``einsum`` parses the
spec, classifies it (batch / M / N / contraction label groups), and -- when
the current :class:`~repro.axon.policy.ExecutionPolicy` asks for the Pallas
backend -- lowers matmul-shaped contractions onto the Axon kernels:

  * 2-D GeMMs (including any contraction whose batch labels appear only on
    the LHS, which fold into M) -> the mapper-selected ``axon_gemm``;
  * small-M contractions (M <= 8: matvecs, decode-step projections) -> the
    memory-bound ``gemv`` kernel;
  * shared-batch contractions (e.g. MoE's per-expert GeMMs) -> ``vmap`` over
    the 2-D kernel;
  * anything else (3+ operands, repeated labels, traced sums) -> XLA.

Quantized operands (``repro.quant.QuantizedTensor`` weights) take a fourth
route: under ``ExecutionPolicy(precision="int8")`` (or ``"fp8"``) they
dispatch the quantized Pallas kernels matching their storage format --
``quant_gemm`` / ``quant_conv2d`` for int8 (weight-only GEMV for
decode-shaped steps), ``int4_gemm`` / ``int4_gemv`` for nibble-packed int4
weights, ``fp8_gemm`` for e4m3 -- and under any other policy they
dequantize onto the float paths above, which is exactly the reference the
differential tests compare against.  :func:`quant_route` is the eligibility
predicate, exposed so the conformance tests can pin every fallback reason.
``precision="fp8"`` additionally casts eligible float GeMMs to e4m3
operands (f32 accumulation) with no quantization step at all.

Mapper decisions are LRU-cached per (shape, dtype) in ``repro.core.mapper``,
so the candidate sweep runs once per unique GeMM shape per process.  Kernel
dispatches carry a ``jax.custom_vjp`` whose backward is two more Axon GeMMs
(dA = g @ B^T, dB = A^T @ g), so the training path stays on-kernel end to
end.  Under the ``xla`` backend every call is a plain ``jnp.einsum`` --
bit-identical to calling jnp directly.
"""
from __future__ import annotations

import dataclasses
import functools
import string

import jax
import jax.numpy as jnp

from repro.axon import registry
from repro.axon.policy import ExecutionPolicy, current_policy
from repro.core.dataflows import Dataflow, GemmShape
from repro.core.energy_model import dram_energy_joules
from repro.core.mapper import (mapper_cache_info, modeled_traffic,
                               select_tpu_blocking)
from repro.obs import annotate as _ann
from repro.obs import optrace as _obs
from repro.obs import profiler as _profiler
from repro.kernels.axon_gemm import axon_gemm
from repro.kernels.dwconv import dwconv
from repro.kernels.gemv import gemv as gemv_kernel
from repro.kernels.im2col_conv import im2col_conv
from repro.kernels.quant_gemm import (fp8_gemm, int4_gemm, int4_gemv,
                                      quant_gemm, quant_im2col_conv, wq_gemv)
from repro.kernels.zero_gate_gemm import zero_gate_gemm
from repro.kernels import ref
from repro.quant import calibrate as _qcal
from repro.quant.qtensor import (QuantizedTensor, dequantize,
                                 quantize_activation, to_fp8)


# ---------------------------------------------------------------------------
# einsum spec -> contraction plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContractionPlan:
    """A two-operand einsum lowered to ``(B, M, K) @ (B, K, N)``."""

    kind: str                        # "gemm" | "gemv"
    lhs_perm: tuple[int, ...]        # lhs axes -> (batch..., m..., k...)
    rhs_perm: tuple[int, ...]        # rhs axes -> (batch..., k..., n...)
    B: int
    M: int
    K: int
    N: int
    out_group_shape: tuple[int, ...]  # (batch dims..., m dims..., n dims...)
    out_perm: tuple[int, ...]         # grouped order -> einsum output order


@functools.lru_cache(maxsize=4096)
def plan_contraction(spec: str, lhs_shape: tuple[int, ...],
                     rhs_shape: tuple[int, ...]) -> ContractionPlan | None:
    """Classify a two-operand einsum; None = not kernel-mappable (use XLA)."""
    if "->" not in spec or "." in spec:
        return None
    inputs, out = spec.split("->")
    parts = [p.strip() for p in inputs.split(",")]
    if len(parts) != 2:
        return None
    la, lb, lo = parts[0], parts[1], out.strip()
    if (len(set(la)) != len(la) or len(set(lb)) != len(lb)
            or len(set(lo)) != len(lo)):
        return None                               # repeated labels (traces)
    if len(la) != len(lhs_shape) or len(lb) != len(rhs_shape):
        return None
    if 0 in lhs_shape or 0 in rhs_shape:
        return None      # empty operands: XLA returns the empty/zeros result
    sa, sb, so = set(la), set(lb), set(lo)
    if not so <= (sa | sb):
        return None
    if (sa - sb - so) or (sb - sa - so):
        return None                               # single-operand sum-out
    contract = [c for c in la if c in sb and c not in so]
    if not contract:
        return None                               # outer product
    size: dict[str, int] = dict(zip(la, lhs_shape))
    for lbl, d in zip(lb, rhs_shape):
        if size.get(lbl, d) != d:
            return None
        size[lbl] = d
    batch = [c for c in lo if c in sa and c in sb]
    m_lbls = [c for c in lo if c in sa and c not in sb]
    n_lbls = [c for c in lo if c in sb and c not in sa]

    lhs_perm = tuple(la.index(c) for c in batch + m_lbls + contract)
    rhs_perm = tuple(lb.index(c) for c in batch + contract + n_lbls)
    prod = lambda lbls: functools.reduce(
        lambda x, y: x * y, (size[c] for c in lbls), 1)
    B, M, K, N = prod(batch), prod(m_lbls), prod(contract), prod(n_lbls)
    grouped = batch + m_lbls + n_lbls
    out_perm = tuple(grouped.index(c) for c in lo)
    # vector-output (N == 1) and rank-1 (K == 1) contractions are not
    # matmul-shaped enough to feed the MXU kernels -- XLA fuses the
    # equivalent dot/broadcast far better (the SSM decode einsums hit this).
    if N == 1 or K == 1:
        return None
    # small-M contractions (decode-step projections, matvecs) ride the
    # streaming GEMV kernel: M rows sit on the sublane dim of one (M, bk)
    # block instead of spawning a bm=1-degenerate GeMM grid.
    kind = "gemv" if (B == 1 and M <= 8) else "gemm"
    return ContractionPlan(
        kind=kind, lhs_perm=lhs_perm, rhs_perm=rhs_perm, B=B, M=M, K=K, N=N,
        out_group_shape=tuple(size[c] for c in grouped), out_perm=out_perm)


@functools.lru_cache(maxsize=4096)
def _rhs_sole_n_axis(spec: str, lhs_ndim: int, rhs_ndim: int) -> int | None:
    """The rhs axis carrying the contraction's ONLY n-group label, or None.

    The quantized kernels fold a per-channel weight scale into the epilogue
    as a per-output-column vector, which is exact iff the scale varies along
    exactly this axis (column scaling commutes with the K-sum)."""
    if "->" not in spec or "." in spec:
        return None
    inputs, out = spec.split("->")
    parts = [p.strip() for p in inputs.split(",")]
    if len(parts) != 2:
        return None
    la, lb, lo = parts[0], parts[1], out.strip()
    if len(la) != lhs_ndim or len(lb) != rhs_ndim:
        return None
    sa = set(la)
    n_lbls = [c for c in lo if c in set(lb) and c not in sa]
    if len(n_lbls) != 1:
        return None
    return lb.index(n_lbls[0])


# ---------------------------------------------------------------------------
# kernel callables (config-static wrappers with custom VJPs)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _gemm_callable(block: tuple[int, int, int], order: Dataflow,
                   interpret: bool, out_dtype: str):
    """2-D GeMM with an Axon-kernel backward (dA = g B^T, dB = A^T g).

    Backward operands stay in their native dtypes -- the kernel accumulates
    partial products in fp32 internally, so upcasting copies of g/A/B would
    double HBM traffic for no precision gain."""
    bm, bk, bn = block
    out_dt = jnp.dtype(out_dtype)

    @jax.custom_vjp
    def mm(a, b):
        return axon_gemm(a, b, block=block, order=order, out_dtype=out_dt,
                         interpret=interpret)

    def fwd(a, b):
        return mm(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        da = axon_gemm(g, b.T, block=(bm, bn, bk),
                       order=order, out_dtype=a.dtype, interpret=interpret)
        db = axon_gemm(a.T, g, block=(bk, bm, bn),
                       order=order, out_dtype=b.dtype, interpret=interpret)
        return da, db

    mm.defvjp(fwd, bwd)
    # jit at the callable level: eager callers (benchmarks, the ops shims,
    # ad-hoc use) compile once per config instead of re-tracing per call
    return jax.jit(mm)


@functools.lru_cache(maxsize=None)
def _gemv_callable(block_k: int, block_n: int, interpret: bool,
                   out_dtype: str):
    """(1, K) x (K, N) via the streaming GEMV kernel; jnp backward."""
    out_dt = jnp.dtype(out_dtype)

    @jax.custom_vjp
    def mv(x, w):
        return gemv_kernel(x, w, block_k=block_k, block_n=block_n,
                           out_dtype=out_dt, interpret=interpret)

    def fwd(x, w):
        return mv(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        gf = g.astype(jnp.float32)
        dx = (gf @ w.astype(jnp.float32).T).astype(x.dtype)
        dw = (x.astype(jnp.float32).T @ gf).astype(w.dtype)
        return dx, dw

    mv.defvjp(fwd, bwd)
    return jax.jit(mv)


@functools.lru_cache(maxsize=None)
def _zero_gate_callable(block: tuple[int, int, int], interpret: bool,
                        out_dtype: str):
    out_dt = jnp.dtype(out_dtype)

    @jax.custom_vjp
    def zg(a, b):
        return zero_gate_gemm(a, b, block=block, out_dtype=out_dt,
                              interpret=interpret)

    def fwd(a, b):
        return zg(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        gf = g.astype(jnp.float32)
        da = (gf @ b.astype(jnp.float32).T).astype(a.dtype)
        db = (a.astype(jnp.float32).T @ gf).astype(b.dtype)
        return da, db

    zg.defvjp(fwd, bwd)
    return jax.jit(zg)


# ---------------------------------------------------------------------------
# registry entries
# ---------------------------------------------------------------------------


def _mapped_blocking(pol: ExecutionPolicy, M: int, K: int, N: int,
                     itemsize: int) -> tuple[tuple[int, int, int], Dataflow]:
    block, order = pol.block, pol.order
    if block is not None:
        # pinned block: no sweep -- the mapper's order would have been
        # scored against its own block choice, not this one
        return block, (order if order is not None else Dataflow.OS)
    sel = select_tpu_blocking(GemmShape(M, K, N), bytes_per_elem=itemsize)
    return ((sel.bm, sel.bk, sel.bn),
            order if order is not None else sel.loop_order)


@registry.register("gemm", accum="float32", vjp="custom")
def _gemm_impl(at, bt, pol: ExecutionPolicy, out_dtype):
    B, M, K = at.shape
    N = bt.shape[2]
    _check_accum_dtype(pol)
    block, order = _mapped_blocking(pol, M, K, N, jnp.dtype(at.dtype).itemsize)
    mm = _gemm_callable(block, order, pol.interpret(),
                        jnp.dtype(out_dtype).name)
    if B == 1:
        return mm(at[0], bt[0])[None]
    return jax.vmap(mm)(at, bt)


def _check_accum_dtype(pol: ExecutionPolicy) -> None:
    if jnp.dtype(pol.accum_dtype) != jnp.float32:
        raise NotImplementedError(
            "the Axon kernels accumulate in float32; "
            f"policy accum_dtype={pol.accum_dtype} is not implemented")


@registry.register("gemv", accum="float32", vjp="custom")
def _gemv_impl(at, bt, pol: ExecutionPolicy, out_dtype):
    # at: (1, M, K) with M <= 8 -- the M rows are the kernel's small batch
    _, _, K = at.shape
    N = bt.shape[2]
    _check_accum_dtype(pol)
    if pol.block is not None:
        bk, bn = pol.block[1], pol.block[2]
    else:
        bk, bn = min(512, K), min(1024, N)
    mv = _gemv_callable(bk, bn, pol.interpret(), jnp.dtype(out_dtype).name)
    return mv(at[0], bt[0])[None]


@registry.register("zero_gate", accum="float32", vjp="custom")
def _zero_gate_impl(at, bt, pol: ExecutionPolicy, out_dtype):
    _, M, K = at.shape
    N = bt.shape[2]
    _check_accum_dtype(pol)
    block, _ = _mapped_blocking(pol, M, K, N, jnp.dtype(at.dtype).itemsize)
    zg = _zero_gate_callable(block, pol.interpret(),
                             jnp.dtype(out_dtype).name)
    return zg(at[0], bt[0])[None]


@registry.register("xla_einsum", accum="native", vjp="native", backend="xla")
def _xla_einsum(spec, *operands, precision=None, preferred_element_type=None):
    return jnp.einsum(spec, *operands, precision=precision,
                      preferred_element_type=preferred_element_type)


# ---------------------------------------------------------------------------
# quantized kernels (inference-only: no custom VJP -- PTQ params are frozen)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _quant_gemm_callable(block: tuple[int, int, int], interpret: bool,
                         out_dtype: str):
    return jax.jit(functools.partial(
        quant_gemm, block=block, out_dtype=jnp.dtype(out_dtype),
        interpret=interpret))


@functools.lru_cache(maxsize=None)
def _wq_gemv_callable(block_k: int, block_n: int, interpret: bool,
                      out_dtype: str):
    return jax.jit(functools.partial(
        wq_gemv, block_k=block_k, block_n=block_n,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret))


@functools.lru_cache(maxsize=None)
def _int4_gemm_callable(block: tuple[int, int, int], k_size: int,
                        interpret: bool, out_dtype: str):
    return jax.jit(functools.partial(
        int4_gemm, k_size=k_size, block=block,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret))


@functools.lru_cache(maxsize=None)
def _int4_gemv_callable(block_k: int, block_n: int, k_size: int,
                        interpret: bool, out_dtype: str):
    return jax.jit(functools.partial(
        int4_gemv, k_size=k_size, block_k=block_k, block_n=block_n,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret))


@functools.lru_cache(maxsize=None)
def _fp8_gemm_callable(block: tuple[int, int, int], interpret: bool,
                       out_dtype: str):
    return jax.jit(functools.partial(
        fp8_gemm, block=block, out_dtype=jnp.dtype(out_dtype),
        interpret=interpret))


@registry.register("quant_gemm", accum="int32|float32", vjp="no_vjp",
                   vjp_reason="inference-only: PTQ weights are frozen")
def _quant_gemm_impl(at, bt, scale, pol: ExecutionPolicy, out_dtype):
    """(M, K) x (K, N) int8 weight GeMM with fused dequant epilogue.

    ``at`` int8 = full int8 (int32 accumulation); ``at`` float = weight-only.
    Small-M float activations (decode steps) ride the streaming GEMV."""
    M, K = at.shape
    N = bt.shape[1]
    _check_accum_dtype(pol)
    if at.dtype != jnp.int8 and M <= 8:
        if pol.block is not None:
            bk, bn = pol.block[1], pol.block[2]
        else:
            bk, bn = min(512, K), min(1024, N)
        mv = _wq_gemv_callable(bk, bn, pol.interpret(),
                               jnp.dtype(out_dtype).name)
        return mv(at, bt, scale)
    # the dominant streamed operand is the 1-byte weight (weight-only) or
    # both int8 operands: let the mapper block for 1-byte traffic
    block, _ = _mapped_blocking(pol, M, K, N, 1)
    mm = _quant_gemm_callable(block, pol.interpret(),
                              jnp.dtype(out_dtype).name)
    return mm(at, bt, scale)


@registry.register("int4_gemm", accum="float32", vjp="no_vjp",
                   vjp_reason="inference-only: PTQ weights are frozen")
def _int4_gemm_impl(at, bt, scale, k_size, pol: ExecutionPolicy, out_dtype):
    """(M, K) float x nibble-packed (K/2, N) int4 weight, weight-only.

    Decode-shaped small-M activations ride the streaming int4 GEMV; the
    mapper blocks for 1-byte weight traffic (conservative for 0.5 B)."""
    M = at.shape[0]
    N = bt.shape[1]
    _check_accum_dtype(pol)
    if M <= 8:
        if pol.block is not None:
            bk, bn = pol.block[1], pol.block[2]
        else:
            bk, bn = min(512, k_size), min(1024, N)
        mv = _int4_gemv_callable(bk, bn, k_size, pol.interpret(),
                                 jnp.dtype(out_dtype).name)
        return mv(at, bt, scale)
    block, _ = _mapped_blocking(pol, M, k_size, N, 1)
    mm = _int4_gemm_callable(block, k_size, pol.interpret(),
                             jnp.dtype(out_dtype).name)
    return mm(at, bt, scale)


@registry.register("fp8_gemm", accum="float32", vjp="no_vjp",
                   vjp_reason="inference-only: PTQ weights are frozen")
def _fp8_gemm_impl(at, bt, scale, pol: ExecutionPolicy, out_dtype):
    """(M, K) x (K, N) e4m3 GeMM, f32 accumulation, scale-cast epilogue."""
    M, K = at.shape
    N = bt.shape[1]
    _check_accum_dtype(pol)
    block, _ = _mapped_blocking(pol, M, K, N, 1)
    mm = _fp8_gemm_callable(block, pol.interpret(),
                            jnp.dtype(out_dtype).name)
    return mm(at, bt, scale)


@functools.lru_cache(maxsize=None)
def _quant_conv_callable(*, stride, padding, out_dtype, interpret,
                         **block_kwargs):
    return jax.jit(functools.partial(
        quant_im2col_conv, stride=stride, padding=padding,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret, **block_kwargs))


@registry.register("quant_conv2d", accum="int32", vjp="no_vjp",
                   vjp_reason="inference-only: PTQ weights are frozen")
def _quant_conv2d_impl(xq, wq, scale, pol: ExecutionPolicy, stride, padding,
                       out_dtype, block_rows=8, block_cout=128,
                       block_cin=512):
    _check_accum_dtype(pol)
    conv = _quant_conv_callable(
        stride=stride, padding=padding, out_dtype=jnp.dtype(out_dtype),
        block_rows=block_rows, block_cout=block_cout, block_cin=block_cin,
        interpret=pol.interpret())
    return conv(xq, wq, scale)


def _use_quant(pol: ExecutionPolicy, quantized: bool | None) -> bool:
    return (pol.precision in ("int8", "fp8")) if quantized is None \
        else bool(quantized)


def _channel_scale(qt: QuantizedTensor, naxis: int) -> jax.Array | None:
    """Flatten ``qt.scale`` to a per-output-column vector, or None if the
    scale varies along any axis other than ``naxis`` (kernel-inexpressible).
    """
    varying = [i for i, d in enumerate(qt.scale.shape) if d != 1]
    if varying == [naxis]:
        return qt.scale.reshape(-1)
    if not varying:                             # per-tensor scale
        return jnp.broadcast_to(qt.scale.reshape(()), (qt.shape[naxis],))
    return None


def _per_tensor_act_scale(qt: QuantizedTensor) -> jax.Array | None:
    if qt.act_scale is None or qt.act_scale.size != 1:
        return None
    return qt.act_scale.reshape(())


def quant_route(spec: str, a, qt: QuantizedTensor, pol: ExecutionPolicy,
                quantized: bool | None = None) -> tuple[str, str]:
    """The quantized-kernel eligibility predicate: ``(route, reason)``.

    ``route`` is the registry kind the dispatch will use -- ``"quant_gemm"``
    (int8 / weight-only int8), ``"int4_gemm"``, ``"fp8_gemm"`` -- or
    ``"dequant"`` with the reason the weight falls back to the bit-exact
    dequantized float path.  Pure function of static call-site properties
    (spec, shapes, scale layout, policy), so the conformance tests pin every
    branch without reading kernel outputs."""
    if not _use_quant(pol, quantized):
        return "dequant", "policy precision is float"
    if pol.resolved_backend() == "xla":
        return "dequant", "xla backend"
    if not (hasattr(a, "shape") and hasattr(a, "dtype")
            and jnp.issubdtype(a.dtype, jnp.floating)):
        return "dequant", "non-float activation"
    plan = plan_contraction(spec, tuple(a.shape), tuple(qt.shape))
    if plan is None:
        return "dequant", "spec is not a matmul-shaped contraction"
    if plan.B != 1:
        return "dequant", "shared-batch contraction (B > 1)"
    naxis = _rhs_sole_n_axis(spec, a.ndim, qt.ndim)
    if naxis is None:
        return "dequant", "no sole n-group label on the rhs"
    if _channel_scale(qt, naxis) is None:
        return "dequant", "scale varies off the sole n-group axis"
    fmt = qt.fmt
    if fmt == "int4":
        # the packed payload cannot be transposed/reshaped like a logical
        # array: only the identity (K, N) rhs layout has a kernel
        if qt.ndim != 2 or plan.rhs_perm != (0, 1):
            return "dequant", "int4 payload needs the identity (K, N) layout"
        return "int4_gemm", "packed int4 weight-only kernel"
    if fmt == "fp8":
        return "fp8_gemm", "e4m3 kernel (f32 accumulation)"
    return "quant_gemm", "int8 kernel"


def _quant_einsum(spec: str, a, b, pol: ExecutionPolicy,
                  preferred_element_type, quantized: bool | None):
    """Einsum with a QuantizedTensor operand.

    Kernel path (weight on the rhs, matmul-shaped, unbatched, channel scale
    on the sole n-group label): the kernel matching the weight's storage
    format -- full int8 when a calibrated activation scale is present,
    weight-only int8/int4 otherwise, e4m3 for fp8 weights.  Every other
    configuration (see :func:`quant_route`) dequantizes back to the float
    reference dispatch.
    """
    if isinstance(a, QuantizedTensor) and isinstance(b, QuantizedTensor):
        a = dequantize(a)               # no quantized kernel takes two weights
    if isinstance(a, QuantizedTensor):
        # weight-on-the-lhs has no kernel layout: reference path
        return einsum(spec, dequantize(a), b, policy=pol,
                      preferred_element_type=preferred_element_type)
    qt = b
    _qcal.record(qt, a)                    # no-op outside calibration scopes
    route, route_reason = quant_route(spec, a, qt, pol, quantized)
    if _obs.enabled():
        _obs_record_einsum(
            spec, a.shape, qt.shape, a.dtype, pol,
            plan_contraction(spec, tuple(a.shape), tuple(qt.shape)),
            "dequant" if route == "dequant" else route,
            route=route, reason=route_reason)
    if route == "dequant":
        return _kernel_call("dequant", pol, lambda: einsum(
            spec, a, dequantize(qt), policy=pol,
            preferred_element_type=preferred_element_type))
    plan = plan_contraction(spec, tuple(a.shape), tuple(qt.shape))
    naxis = _rhs_sole_n_axis(spec, a.ndim, qt.ndim)
    colscale = _channel_scale(qt, naxis)
    if preferred_element_type is not None:
        out_dtype = jnp.dtype(preferred_element_type)
    else:
        out_dtype = jnp.result_type(a.dtype, qt.dtype)
    at = jax.lax.transpose(a, plan.lhs_perm).reshape(plan.M, plan.K)
    s_act = _per_tensor_act_scale(qt)
    if route == "int4_gemm":
        # weight-only by design: int4 activations would need calibrated
        # clipping far tighter than serving accuracy tolerates
        out = _kernel_call("int4_gemm", pol, lambda: registry.get(
            "int4_gemm")(at, qt.q, colscale, plan.K, pol, out_dtype))
    elif route == "fp8_gemm":
        bt = jax.lax.transpose(qt.q, plan.rhs_perm).reshape(plan.K, plan.N)
        if s_act is not None:
            at = quantize_activation(at, s_act, fmt="fp8")
            colscale = colscale * s_act
        else:
            # uncalibrated: e4m3 is a float format -- a saturating direct
            # cast is the scale-1.0 quantization
            at = to_fp8(at)
        out = _kernel_call("fp8_gemm", pol, lambda: registry.get(
            "fp8_gemm")(at, bt, colscale, pol, out_dtype))
    else:
        bt = jax.lax.transpose(qt.q, plan.rhs_perm).reshape(plan.K, plan.N)
        if s_act is not None:
            at = quantize_activation(at, s_act)
            colscale = colscale * s_act
        out = _kernel_call("quant_gemm", pol, lambda: registry.get(
            "quant_gemm")(at, bt, colscale, pol, out_dtype))
    out = out.reshape(plan.out_group_shape)
    return jax.lax.transpose(out, plan.out_perm)


@functools.lru_cache(maxsize=None)
def _conv_callable(fn, ref_fn, *, stride, padding, out_dtype, **block_kwargs):
    """Kernel-path conv with a custom VJP.

    Forward runs the Pallas kernel; backward runs the exact VJP of the XLA
    reference (the same mathematical function), so ``jax.grad`` through the
    ``pallas``/``interpret`` backends matches the ``xla`` backend without the
    kernels needing their own transpose rules."""
    kernel = functools.partial(fn, stride=stride, padding=padding,
                               out_dtype=out_dtype, **block_kwargs)

    @jax.custom_vjp
    def conv(x, w):
        return kernel(x, w)

    def fwd(x, w):
        return conv(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        _, vjp = jax.vjp(
            lambda xx, ww: ref_fn(xx, ww, stride=stride, padding=padding,
                                  out_dtype=out_dtype), x, w)
        return vjp(g)

    conv.defvjp(fwd, bwd)
    return jax.jit(conv)


@registry.register("conv2d", accum="float32", vjp="custom")
def _conv2d_impl(x, w, pol: ExecutionPolicy, stride, padding, groups,
                 out_dtype, block_rows=8, block_cout=128, block_cin=512):
    _check_accum_dtype(pol)
    conv = _conv_callable(
        im2col_conv, ref.conv2d_ref, stride=stride, padding=padding,
        block_rows=block_rows, block_cout=block_cout, block_cin=block_cin,
        out_dtype=None if out_dtype is None else jnp.dtype(out_dtype),
        interpret=pol.interpret())
    if groups == 1:
        return conv(x, w)
    # Grouped conv: vmap the single-group kernel over the group axis (per-
    # group GeMMs).  lax semantics: input channels split into `groups`
    # consecutive blocks; output-channel block g consumes input block g.
    N, H, W, _ = x.shape
    kh, kw, cig, cout = w.shape
    cog = cout // groups
    xg = jnp.moveaxis(x.reshape(N, H, W, groups, cig), 3, 0)      # (G,N,H,W,cig)
    wg = jnp.moveaxis(w.reshape(kh, kw, cig, groups, cog), 3, 0)  # (G,kh,kw,cig,cog)
    outg = jax.vmap(conv)(xg, wg)                                 # (G,N,Ho,Wo,cog)
    return jnp.moveaxis(outg, 0, 3).reshape(
        N, outg.shape[2], outg.shape[3], cout)


@registry.register("xla_conv2d", accum="native", vjp="native", backend="xla")
def _xla_conv2d(x, w, *, stride, padding, groups, out_dtype):
    return ref.conv2d_ref(x, w, stride=stride, padding=padding, groups=groups,
                          out_dtype=out_dtype)


@registry.register("dwconv", accum="float32", vjp="custom")
def _dwconv_impl(x, w, pol: ExecutionPolicy, stride, padding, out_dtype,
                 block_rows=8, block_c=128):
    _check_accum_dtype(pol)
    conv = _conv_callable(
        dwconv, ref.dwconv_ref, stride=stride, padding=padding,
        block_rows=block_rows, block_c=block_c,
        out_dtype=None if out_dtype is None else jnp.dtype(out_dtype),
        interpret=pol.interpret())
    return conv(x, w)


@registry.register("xla_dwconv", accum="native", vjp="native", backend="xla")
def _xla_dwconv(x, w, *, stride, padding, out_dtype):
    return ref.dwconv_ref(x, w, stride=stride, padding=padding,
                          out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# telemetry (repro.obs) -- every helper below is reached only behind an
# ``_obs.enabled()`` check at the call site, so with telemetry off the
# dispatch hot path pays one boolean read and allocates nothing
# ---------------------------------------------------------------------------


def _kernel_call(kind: str, pol: ExecutionPolicy, fn):
    """Invoke one kernel dispatch under its device-timeline scope.

    Every dispatch site runs inside ``annotate.scope("axon:<kind>")`` so
    the staged ops carry the kernel kind into profiler device traces
    (under jit this costs one name-stack push at trace time; numerics are
    untouched, keeping obs-off runs bit-identical).  When
    ``optrace.configure(measure_dispatch=True)`` is set and the call is
    eager, the dispatch is additionally timed through
    ``block_until_ready`` into a ``dispatch:<kind>`` wall scope -- the
    measured side that ``repro.obs.attribution`` joins against the ring's
    modeled FLOPs/bytes."""
    if _obs.measuring() and jax.core.trace_state_clean():
        with _profiler.wall("dispatch:" + kind, kind=kind,
                            backend=pol.resolved_backend()) as w:
            with _ann.scope("axon:" + kind):
                out = fn()
            w.ready(out)
        return out
    with _ann.scope("axon:" + kind):
        return fn()


def _obs_kind(plan: ContractionPlan, pol: ExecutionPolicy) -> str:
    """The registry kind :func:`_dispatch`/:func:`_fp8_dispatch` will use."""
    if pol.precision == "fp8" and plan.B == 1:
        return "fp8_gemm"
    if pol.zero_gate and plan.B == 1:
        return "zero_gate"
    return plan.kind


def _obs_record_einsum(spec: str, lhs_shape, rhs_shape, dtype, pol,
                       plan: ContractionPlan | None, kind: str, *,
                       op: str = "einsum", route: str | None = None,
                       reason: str | None = None) -> None:
    """Record one GeMM-path dispatch with its mapper blocking and modeled
    FLOPs / HBM bytes / DRAM energy from the ``repro.core`` models."""
    itemsize = jnp.dtype(dtype).itemsize
    block = order = hit = None
    flops = nbytes = 0.0
    if plan is not None:
        flops = 2.0 * plan.B * plan.M * plan.K * plan.N
        shape = GemmShape(plan.M, plan.K, plan.N)
        if kind in ("gemm", "zero_gate") and pol.block is None:
            # the kernel impl will consult the same LRU entry; probing the
            # miss count before our own lookup tells hit from miss
            before = mapper_cache_info().misses
            sel = select_tpu_blocking(shape, bytes_per_elem=itemsize)
            hit = mapper_cache_info().misses == before
            block = (sel.bm, sel.bk, sel.bn)
            order = sel.loop_order.name
            nbytes = float(plan.B * modeled_traffic(
                shape, sel.bm, sel.bk, sel.bn, sel.loop_order, itemsize))
        else:
            # gemv / quant / fp8 paths: operands + result, streamed once
            nbytes = float(plan.B * (plan.M * plan.K + plan.K * plan.N
                                     + plan.M * plan.N) * itemsize)
    _obs.record_dispatch(
        op, kind, spec=spec, lhs=tuple(lhs_shape), rhs=tuple(rhs_shape),
        dtype=jnp.dtype(dtype).name, backend=pol.resolved_backend(),
        block=block, order=order, mapper_hit=hit, route=route,
        reason=reason, flops=flops, bytes=nbytes,
        energy_j=dram_energy_joules(nbytes))


def _xla_einsum_cost(spec: str, operands) -> tuple[float, float]:
    """Naive modeled (flops, bytes) for an arbitrary einsum fallback:
    one MAC per point of the full index space, operands + result streamed
    once.  Keeps the attribution join total over every dispatched kind;
    (0, 0) when the spec can't be sized (ellipsis, shapeless operands)."""
    try:
        ins, out = spec.replace(" ", "").split("->")
        if "." in spec:
            return 0.0, 0.0
        dims: dict[str, int] = {}
        for term, o in zip(ins.split(","), operands):
            for ax, d in zip(term, o.shape):
                dims[ax] = int(d)
        flops = 2.0
        for d in dims.values():
            flops *= d
        out_elems = 1
        for ax in out:
            out_elems *= dims[ax]
        itemsize = max(jnp.dtype(o.dtype).itemsize for o in operands)
        nbytes = float((sum(int(o.size) for o in operands) + out_elems)
                       * itemsize)
        return flops, nbytes
    except Exception:
        return 0.0, 0.0


def _obs_record_xla_einsum(spec: str, operands, precision, pol) -> None:
    """Record the einsum XLA fallback with the reason it fell back."""
    if pol.resolved_backend() == "xla":
        reason = "xla backend selected by policy"
    elif len(operands) != 2:
        reason = f"{len(operands)} operands (kernels take 2)"
    elif precision is not None:
        reason = "explicit precision hint"
    else:
        a, b = operands
        if not (hasattr(a, "dtype") and hasattr(b, "dtype")
                and jnp.issubdtype(a.dtype, jnp.floating)
                and jnp.issubdtype(b.dtype, jnp.floating)):
            reason = "non-float operands"
        else:
            reason = "spec is not a matmul-shaped contraction"
    shapes = [tuple(o.shape) for o in operands if hasattr(o, "shape")]
    dt = next((jnp.dtype(o.dtype).name for o in operands
               if hasattr(o, "dtype")), None)
    flops, nbytes = _xla_einsum_cost(
        spec, [o for o in operands if hasattr(o, "shape")])
    _obs.record_dispatch(
        "einsum", "xla", spec=spec,
        lhs=shapes[0] if shapes else None,
        rhs=shapes[1] if len(shapes) > 1 else None, dtype=dt,
        backend=pol.resolved_backend(), reason=reason,
        flops=flops, bytes=nbytes, energy_j=dram_energy_joules(nbytes))


def _obs_record_conv(op: str, kind: str, x, w_shape, pol, H_out: int,
                     W_out: int, *, route: str | None = None,
                     reason: str | None = None) -> None:
    """Record a conv dispatch with modeled im2col GeMM FLOPs/bytes."""
    N = x.shape[0]
    kh, kw = w_shape[0], w_shape[1]
    if len(w_shape) == 4:
        cig, cout = w_shape[2], w_shape[3]
    else:                                   # depthwise (kh, kw, C)
        cig, cout = 1, w_shape[2]
    ho, wo = max(H_out, 0), max(W_out, 0)
    flops = 2.0 * N * ho * wo * kh * kw * cig * cout
    itemsize = jnp.dtype(x.dtype).itemsize
    w_elems = kh * kw * cig * cout if len(w_shape) == 4 else kh * kw * cout
    nbytes = float((x.size + w_elems + N * ho * wo * cout) * itemsize)
    _obs.record_dispatch(
        op, kind, lhs=tuple(x.shape), rhs=tuple(w_shape),
        dtype=jnp.dtype(x.dtype).name, backend=pol.resolved_backend(),
        route=route, reason=reason, flops=flops, bytes=nbytes,
        energy_j=dram_energy_joules(nbytes))


# ---------------------------------------------------------------------------
# public operators
# ---------------------------------------------------------------------------


def einsum(spec: str, *operands, precision=None, preferred_element_type=None,
           policy: ExecutionPolicy | None = None,
           quantized: bool | None = None) -> jax.Array:
    """Policy-dispatched einsum.

    Under the ``xla`` backend this is exactly ``jnp.einsum`` (bit-identical).
    Under ``pallas`` / ``interpret``, matmul-shaped two-operand contractions
    are lowered onto the Axon kernels (fp32 accumulation); the rest fall back
    to XLA.  ``repro.quant.QuantizedTensor`` operands dispatch the quantized
    kernels matching their storage format (int8 / packed int4 / e4m3) when
    the policy's ``precision`` is ``"int8"`` or ``"fp8"`` (or ``quantized=
    True`` overrides it per call) and dequantize to this float path
    otherwise; ``precision="fp8"`` also casts eligible float contractions to
    e4m3 operands.
    """
    pol = policy if policy is not None else current_policy()
    if any(isinstance(o, QuantizedTensor) for o in operands):
        if len(operands) == 2 and precision is None:
            return _quant_einsum(spec, operands[0], operands[1], pol,
                                 preferred_element_type, quantized)
        # ineligible for the int8 kernels (3+ operands, precision hints):
        # dequantize onto the float reference path
        operands = tuple(dequantize(o) if isinstance(o, QuantizedTensor)
                         else o for o in operands)
    if pol.resolved_backend() != "xla" and len(operands) == 2 \
            and precision is None:
        a, b = operands
        # kernels accumulate in fp32: only exact for floating operands
        # (integer einsums stay on the exact XLA path)
        if (hasattr(a, "shape") and hasattr(b, "shape")
                and hasattr(a, "dtype") and hasattr(b, "dtype")
                and jnp.issubdtype(a.dtype, jnp.floating)
                and jnp.issubdtype(b.dtype, jnp.floating)):
            plan = plan_contraction(spec, tuple(a.shape), tuple(b.shape))
            if plan is not None:
                if _obs.enabled():
                    _obs_record_einsum(spec, a.shape, b.shape,
                                       jnp.result_type(a.dtype, b.dtype),
                                       pol, plan, _obs_kind(plan, pol))
                if pol.precision == "fp8" and plan.B == 1:
                    return _fp8_dispatch(plan, a, b, pol,
                                         preferred_element_type)
                return _dispatch(plan, a, b, pol, preferred_element_type)
    if _obs.enabled():
        _obs_record_xla_einsum(spec, operands, precision, pol)
    return _kernel_call("xla", pol, lambda: registry.get("xla_einsum")(
        spec, *operands, precision=precision,
        preferred_element_type=preferred_element_type))


def _dispatch(plan: ContractionPlan, a, b, pol: ExecutionPolicy,
              preferred_element_type) -> jax.Array:
    # Match jnp.einsum dtype semantics: preferred_element_type is both the
    # accumulation and the result dtype; default result type promotes.
    if preferred_element_type is not None:
        out_dtype = jnp.dtype(preferred_element_type)
    else:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    at = jax.lax.transpose(a, plan.lhs_perm).reshape(plan.B, plan.M, plan.K)
    bt = jax.lax.transpose(b, plan.rhs_perm).reshape(plan.B, plan.K, plan.N)
    kind = plan.kind
    # zero-gating covers every unbatched kernel dispatch; shared-batch
    # contractions (B > 1) fall back to the dense kernel -- the mask operand
    # would need a batched pallas grid that the kernel doesn't implement yet.
    if pol.zero_gate and plan.B == 1:
        kind = "zero_gate"
    out = _kernel_call(kind, pol, lambda: registry.get(kind)(
        at, bt, pol, out_dtype))                          # (B, M, N)
    out = out.reshape(plan.out_group_shape)
    return jax.lax.transpose(out, plan.out_perm)


def _fp8_dispatch(plan: ContractionPlan, a, b, pol: ExecutionPolicy,
                  preferred_element_type) -> jax.Array:
    """``precision="fp8"`` on float operands: cast BOTH sides to e4m3 and
    run the fp8 kernel (f32 accumulation) -- 1-byte operand traffic for an
    unquantized model.  Shared-batch contractions (B > 1) stay on the float
    kernels; this path takes precedence over zero-gating in scope."""
    if preferred_element_type is not None:
        out_dtype = jnp.dtype(preferred_element_type)
    else:
        out_dtype = jnp.result_type(a.dtype, b.dtype)
    at = to_fp8(jax.lax.transpose(a, plan.lhs_perm).reshape(plan.M, plan.K))
    bt = to_fp8(jax.lax.transpose(b, plan.rhs_perm).reshape(plan.K, plan.N))
    ones = jnp.ones((plan.N,), jnp.float32)
    out = _kernel_call("fp8_gemm", pol, lambda: registry.get(
        "fp8_gemm")(at, bt, ones, pol, out_dtype))
    out = out.reshape(plan.out_group_shape)
    return jax.lax.transpose(out, plan.out_perm)


# labels usable for leading batch dims without colliding with m/k/n
_LEAD_LABELS = "".join(c for c in string.ascii_lowercase if c not in "mkn")


def matmul(a, b, *, policy: ExecutionPolicy | None = None,
           preferred_element_type=None,
           quantized: bool | None = None) -> jax.Array:
    """``a @ b`` through the Axon dispatch (leading lhs dims fold into M)."""
    if a.ndim == 1 and b.ndim == 2:
        return einsum("k,kn->n", a, b, policy=policy, quantized=quantized,
                      preferred_element_type=preferred_element_type)
    if a.ndim >= 2 and b.ndim == 2 and a.ndim - 2 <= len(_LEAD_LABELS):
        lead = _LEAD_LABELS[:a.ndim - 2]
        spec = f"{lead}mk,kn->{lead}mn"
        return einsum(spec, a, b, policy=policy, quantized=quantized,
                      preferred_element_type=preferred_element_type)
    if a.ndim == b.ndim and a.ndim >= 3 and a.shape[:-2] == b.shape[:-2] \
            and a.ndim - 2 <= len(_LEAD_LABELS):
        lead = _LEAD_LABELS[:a.ndim - 2]
        spec = f"{lead}mk,{lead}kn->{lead}mn"
        return einsum(spec, a, b, policy=policy, quantized=quantized,
                      preferred_element_type=preferred_element_type)
    if isinstance(a, QuantizedTensor):
        a = dequantize(a)
    if isinstance(b, QuantizedTensor):
        b = dequantize(b)
    return jnp.matmul(a, b, preferred_element_type=preferred_element_type)


def resolve_conv_geometry(stride, padding, kh: int, kw: int, H: int, W: int):
    """Normalize stride/padding and compute the output spatial dims.

    ``stride``: int or ``(sh, sw)``.  ``padding``: int, ``(ph, pw)``,
    explicit ``((pt, pb), (pl, pr))`` pairs, or ``"SAME"`` / ``"VALID"``
    (resolved against the input dims, matching lax's asymmetric SAME split).
    Returns ``((sh, sw), ((pt, pb), (pl, pr)), H_out, W_out)``; output dims
    can be <= 0 (zero-area output / kernel larger than the padded input) --
    callers route those to the XLA reference path.
    """
    sh, sw = ref.normalize_stride(stride)
    if sh < 1 or sw < 1:
        raise ValueError(f"conv stride must be >= 1, got ({sh}, {sw})")
    if isinstance(padding, str):
        kind = padding.upper()
        if kind == "VALID":
            pads = ((0, 0), (0, 0))
        elif kind == "SAME":
            def _same(size, k, s):
                total = max((-(-size // s) - 1) * s + k - size, 0)
                return (total // 2, total - total // 2)
            pads = (_same(H, kh, sh), _same(W, kw, sw))
        else:
            raise ValueError(
                f"padding must be 'SAME', 'VALID', or explicit amounts, "
                f"got {padding!r}")
    else:
        pads = ref.normalize_padding(padding)
    (pt, pb), (pleft, pr) = pads
    if min(pt, pb, pleft, pr) < 0:
        raise ValueError(f"conv padding must be >= 0, got {pads}")
    H_out, W_out = ref.conv_out_hw(H, W, kh, kw, (sh, sw), pads)
    return (sh, sw), pads, H_out, W_out


def conv2d(x, w, *, stride=1, padding=0, groups: int = 1, out_dtype=None,
           block_rows: int = 8, block_cout: int = 128, block_cin: int = 512,
           policy: ExecutionPolicy | None = None,
           quantized: bool | None = None) -> jax.Array:
    """NHWC x HWIO conv through the on-chip-im2col kernel (or XLA).

    ``stride`` is an int or ``(sh, sw)``; ``padding`` an int, ``(ph, pw)``,
    explicit ``((pt, pb), (pl, pr))`` pairs, or ``"SAME"`` / ``"VALID"``.
    ``groups > 1`` is a grouped conv (``w: (kh, kw, C_in // groups, C_out)``,
    lax ``feature_group_count`` semantics), lowered as vmapped per-group
    GeMMs on the kernel backends.  Shapes the Pallas kernel cannot lower
    (zero-area outputs, kernel larger than the padded input, empty operands)
    fall back to the XLA reference path.  The ``block_*`` tiling kwargs only
    affect the kernel backends (XLA picks its own tiling).

    A ``repro.quant.QuantizedTensor`` filter dispatches the int8
    implicit-im2col kernel when the policy precision is ``"int8"`` (or
    ``quantized=True``), the weight carries a calibrated per-tensor
    activation scale, and the geometry is kernel-eligible (dense, groups=1);
    otherwise it dequantizes onto this float path."""
    pol = policy if policy is not None else current_policy()
    if isinstance(w, QuantizedTensor):
        _qcal.record(w, x)
        kh, kw = w.shape[0], w.shape[1]
        st, pads, H_out, W_out = resolve_conv_geometry(
            stride, padding, kh, kw, x.shape[1], x.shape[2])
        colscale = _channel_scale(w, 3) if w.ndim == 4 else None
        s_act = _per_tensor_act_scale(w)
        # the quantized conv kernel speaks int8 only; int4/fp8 filters
        # dequantize onto the float path (conv stays an int8 workload)
        if (_use_quant(pol, quantized) and pol.resolved_backend() != "xla"
                and w.fmt == "int8"
                and groups == 1 and colscale is not None
                and s_act is not None and H_out >= 1 and W_out >= 1
                and 0 not in x.shape and 0 not in w.shape
                and jnp.issubdtype(x.dtype, jnp.floating)):
            if _obs.enabled():
                _obs_record_conv("conv2d", "quant_conv2d", x, w.shape, pol,
                                 H_out, W_out, route="quant_conv2d",
                                 reason="int8 im2col kernel")
            xq = quantize_activation(x, s_act)
            out_dt = x.dtype if out_dtype is None else jnp.dtype(out_dtype)
            return _kernel_call("quant_conv2d", pol, lambda: registry.get(
                "quant_conv2d")(
                    xq, w.q, colscale * s_act, pol, st, pads, out_dt,
                    block_rows=block_rows, block_cout=block_cout,
                    block_cin=block_cin))
        w = dequantize(w)
    kh, kw, cig, cout = w.shape
    if groups < 1:
        raise ValueError(f"groups must be >= 1, got {groups}")
    if x.shape[3] != cig * groups or cout % groups:
        raise ValueError(
            f"conv2d: input channels {x.shape[3]} and filter {w.shape} are "
            f"inconsistent with groups={groups} (need C_in == "
            f"w.shape[2] * groups and C_out % groups == 0)")
    stride, padding, H_out, W_out = resolve_conv_geometry(
        stride, padding, kh, kw, x.shape[1], x.shape[2])
    if pol.resolved_backend() == "xla":
        if _obs.enabled():
            _obs_record_conv("conv2d", "xla", x, w.shape, pol, H_out, W_out,
                             reason="xla backend selected by policy")
        return _kernel_call("xla", pol, lambda: registry.get("xla_conv2d")(
            x, w, stride=stride, padding=padding, groups=groups,
            out_dtype=out_dtype))
    if H_out < 1 or W_out < 1 or 0 in x.shape or 0 in w.shape:
        # Pallas-ineligible: zero-area output (kernel larger than the padded
        # input, stride overshoot) or empty operands.  XLA produces the
        # correctly-shaped (possibly empty) result.
        if _obs.enabled():
            _obs_record_conv("conv2d", "xla", x, w.shape, pol, H_out, W_out,
                             reason="pallas-ineligible geometry")
        return _kernel_call("xla", pol, lambda: registry.get("xla_conv2d")(
            x, w, stride=stride, padding=padding, groups=groups,
            out_dtype=out_dtype))
    if _obs.enabled():
        _obs_record_conv("conv2d", "conv2d", x, w.shape, pol, H_out, W_out)
    return _kernel_call("conv2d", pol, lambda: registry.get("conv2d")(
        x, w, pol, stride, padding, groups, out_dtype,
        block_rows=block_rows, block_cout=block_cout, block_cin=block_cin))


def depthwise_conv2d(x, w, *, stride=1, padding=0,
                     out_dtype=None, block_rows: int = 8, block_c: int = 128,
                     policy: ExecutionPolicy | None = None) -> jax.Array:
    """NHWC x (kh, kw, C) depthwise conv (VPU kernel path, no im2col).

    Accepts the same generalized ``stride`` / ``padding`` as :func:`conv2d`;
    Pallas-ineligible shapes fall back to the XLA reference path.  Depthwise
    filters are never int8-quantized (VPU path, no im2col GeMM), so a
    ``QuantizedTensor`` here always dequantizes."""
    pol = policy if policy is not None else current_policy()
    if isinstance(w, QuantizedTensor):
        _qcal.record(w, x)
        w = dequantize(w)
    kh, kw = w.shape[0], w.shape[1]
    stride, padding, H_out, W_out = resolve_conv_geometry(
        stride, padding, kh, kw, x.shape[1], x.shape[2])
    if pol.resolved_backend() == "xla" or H_out < 1 or W_out < 1 \
            or 0 in x.shape or 0 in w.shape:
        if _obs.enabled():
            _obs_record_conv(
                "depthwise", "xla", x, w.shape, pol, H_out, W_out,
                reason="xla backend selected by policy"
                if pol.resolved_backend() == "xla"
                else "pallas-ineligible geometry")
        return _kernel_call("xla", pol, lambda: registry.get("xla_dwconv")(
            x, w, stride=stride, padding=padding, out_dtype=out_dtype))
    if _obs.enabled():
        _obs_record_conv("depthwise", "dwconv", x, w.shape, pol, H_out,
                         W_out)
    return _kernel_call("dwconv", pol, lambda: registry.get("dwconv")(
        x, w, pol, stride, padding, out_dtype,
        block_rows=block_rows, block_c=block_c))


def explain(spec: str, *operands) -> dict:
    """Describe how ``einsum(spec, *operands)`` would dispatch (for tests,
    benchmarks, and humans).  Operands may be arrays or shape tuples."""
    shapes = tuple(tuple(getattr(o, "shape", o)) for o in operands)
    pol = current_policy()
    info = {"backend": pol.resolved_backend(), "kind": "xla",
            "reason": None}
    if pol.resolved_backend() == "xla":
        info["reason"] = "xla backend selected by policy"
        return info
    if len(shapes) != 2:
        info["reason"] = f"{len(shapes)} operands (kernels take 2)"
        return info
    plan = plan_contraction(spec, *shapes)
    if plan is None:
        info["reason"] = "spec is not a matmul-shaped contraction"
        return info
    kind = plan.kind
    if pol.zero_gate and plan.B == 1:
        kind = "zero_gate"
    info.update(kind=kind, B=plan.B, M=plan.M, K=plan.K, N=plan.N,
                vmapped=plan.B > 1)
    return info
