"""``repro.axon`` -- the unified, policy-scoped operator API.

One production entry point for every contraction in the repo::

    from repro import axon

    y = axon.einsum("bsd,df->bsf", x, w)          # mapper-selected kernel
    with axon.policy(backend="interpret"):        # scoped override
        y = axon.matmul(a, b)

Kernel, mapper, and backend improvements land behind this facade; call
sites never thread ``interpret=`` / ``block=`` / ``order=`` kwargs again.
"""
from repro.axon.dispatch import (
    conv2d,
    depthwise_conv2d,
    einsum,
    explain,
    matmul,
    plan_contraction,
    quant_route,
    resolve_conv_geometry,
)
from repro.axon.policy import (
    BACKENDS,
    ExecutionPolicy,
    current_policy,
    policy,
    set_default_policy,
)
from repro.core.mapper import mapper_cache_clear, mapper_cache_info

__all__ = [
    "BACKENDS",
    "ExecutionPolicy",
    "conv2d",
    "current_policy",
    "depthwise_conv2d",
    "einsum",
    "explain",
    "mapper_cache_clear",
    "mapper_cache_info",
    "matmul",
    "plan_contraction",
    "policy",
    "quant_route",
    "resolve_conv_geometry",
    "set_default_policy",
]
