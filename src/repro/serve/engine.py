"""Batched serving engine.

``make_serve_step`` builds the jitted one-token step (decode + sampling)
used both by the engine and by the dry-run's ``serve_step`` lowering.  The
engine runs wave-style batching: up to ``batch_slots`` requests decode in
lock-step; prompts are fed through the same cached step (teacher-forcing),
completed slots stop sampling via an active mask.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import axon
from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig, *, temperature: float = 0.0,
                    policy: axon.ExecutionPolicy | None = None):
    """(params, caches, tokens (B,1), rng) -> (next_tokens (B,1), caches).

    ``policy`` pins the axon execution policy for the whole step at trace
    time (e.g. ``ExecutionPolicy(backend="pallas")`` to serve through the
    Axon kernels); None captures the policy current at construction.
    """
    pol = policy if policy is not None else axon.current_policy()

    def serve_step(params, caches, batch, rng):
        with axon.policy(pol):
            logits, caches = T.decode_step(params, caches, batch, cfg)
            logits = logits[:, -1]
            if temperature > 0:
                nxt = jax.random.categorical(rng, logits / temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt[:, None].astype(jnp.int32), caches

    return serve_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = 1


class ServeEngine:
    """Wave-batched generation over fixed slots."""

    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 policy: axon.ExecutionPolicy | None = None):
        self.params = params
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(seed)
        self._step = jax.jit(make_serve_step(cfg, temperature=temperature,
                                             policy=policy))

    def generate(self, requests: list[Request]) -> list[list[int]]:
        outputs: list[list[int]] = []
        for i in range(0, len(requests), self.batch_slots):
            outputs.extend(self._wave(requests[i: i + self.batch_slots]))
        return outputs

    def _wave(self, reqs: list[Request]) -> list[list[int]]:
        B = len(reqs)
        caches = T.init_caches(self.cfg, batch=B, max_len=self.max_len,
                               dtype=jnp.float32)
        prompt_len = max(len(r.prompt) for r in reqs)
        # left-pad prompts with EOS so all slots stay aligned
        prompts = np.full((B, prompt_len), reqs[0].eos_id, np.int32)
        for b, r in enumerate(reqs):
            prompts[b, prompt_len - len(r.prompt):] = r.prompt

        tok = None
        for t in range(prompt_len):
            step_tok = jnp.asarray(prompts[:, t: t + 1])
            self.rng, sub = jax.random.split(self.rng)
            tok, caches = self._step(self.params, caches,
                                     {"tokens": step_tok}, sub)

        max_new = max(r.max_new_tokens for r in reqs)
        done = np.zeros((B,), bool)
        outs: list[list[int]] = [[] for _ in range(B)]
        for _ in range(max_new):
            self.rng, sub = jax.random.split(self.rng)
            tok, caches = self._step(self.params, caches,
                                     {"tokens": tok}, sub)
            t_np = np.asarray(tok)[:, 0]
            for b, r in enumerate(reqs):
                if not done[b] and len(outs[b]) < r.max_new_tokens:
                    outs[b].append(int(t_np[b]))
                    if t_np[b] == r.eos_id:
                        done[b] = True
            if done.all():
                break
        return outs
