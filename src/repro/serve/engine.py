"""Serving engines: continuous batching (default) and wave batching.

``ServeEngine`` is a continuous-batching engine with slot-level scheduling:
an admission queue feeds ``batch_slots`` independent slots, each running a
prefill -> decode -> done state machine.  A slot that finishes is backfilled
from the pending queue on the next step, so short requests never wait for
long ones (no head-of-line blocking).  All compute flows through ONE
fixed-shape jitted step (``make_chunk_step``) traced at exactly two token
widths -- ``prefill_chunk`` while any slot is prefilling, and 1 for pure
decode -- so recompilation never happens mid-serve.  Prompts are teacher-
forced a whole chunk per step (batched GeMMs through ``axon.einsum``), and
per-slot validity masks guarantee inactive or padded lanes never write the
KV caches of live ones.

``WaveServeEngine`` is the previous wave-batched engine, kept as the
benchmark baseline: it stalls every slot until the longest request of its
wave finishes, and its left-padded prompt feed leaks pad tokens into
shorter prompts' caches (see ``tests/test_serve_engine.py`` for the
regression the continuous engine fixes).

``make_serve_step`` builds the jitted one-token step (decode + sampling)
used by the wave engine and the dry-run's ``serve_step`` lowering.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import axon, quant
from repro.configs.base import ModelConfig
from repro.core.mapper import mapper_cache_stats
from repro.models import transformer as T
from repro.parallel import sharding as shd
from repro.parallel.specs import make_param_spec_fn
from repro.obs import annotate as _ann
from repro.obs import attribution as _attr
from repro.obs import metrics as _obs_metrics, optrace as _obs
from repro.obs import streaming as _streaming
from repro.serve import kvcache as KV

QUEUE_POLICIES = ("fifo", "sjf")

# every state a ServeEngine slot can be in (the _Slot state machine);
# the retrace analyzer enumerates token widths over multisets of these
SLOT_STATES = ("free", "prefill", "decode")


def step_width(states, prefill_chunk: int) -> int:
    """Token width the continuous engine feeds for one step, as a pure
    function of the slot states.

    This is THE place the step signature is decided: the jitted chunk step
    is traced at ``(B, prefill_chunk)`` while any slot is prefilling and
    ``(B, 1)`` for pure decode, and nothing else -- the static analyzer
    (``repro.analysis.retrace``) enumerates every slot-state multiset
    against :func:`declared_step_widths` to prove no scheduler state can
    sneak a third trace in mid-serve."""
    return prefill_chunk if any(s == "prefill" for s in states) else 1


def declared_step_widths(prefill_chunk: int) -> tuple[int, ...]:
    """The complete set of token widths the chunk step is traced at."""
    if prefill_chunk == 1:
        return (1,)
    return (prefill_chunk, 1)


def prefill_width(prompt_len: int, prefill_chunk: int) -> int:
    """Token width of every decoupled-prefill step, as a pure function of
    the prompt length.

    Always ``prefill_chunk``: partial tail chunks are padded through the
    valid mask, never fed at their own size, so the dedicated batch-1
    prefill jit is traced at exactly ONE signature regardless of prompt
    length.  The static analyzer (``repro.analysis.retrace``) enumerates
    prompt lengths against :func:`declared_prefill_widths` to prove it."""
    del prompt_len
    return prefill_chunk


def declared_prefill_widths(prefill_chunk: int) -> tuple[int, ...]:
    """The complete set of token widths the decoupled prefill step is
    traced at."""
    return (prefill_chunk,)


def make_serve_step(cfg: ModelConfig, *, temperature: float = 0.0,
                    policy: axon.ExecutionPolicy | None = None):
    """(params, caches, tokens (B,1), rng) -> (next_tokens (B,1), caches).

    ``policy`` pins the axon execution policy for the whole step at trace
    time (e.g. ``ExecutionPolicy(backend="pallas")`` to serve through the
    Axon kernels); None captures the policy current at construction.
    """
    pol = policy if policy is not None else axon.current_policy()

    def serve_step(params, caches, batch, rng):
        with axon.policy(pol):
            logits, caches = T.decode_step(params, caches, batch, cfg)
            logits = logits[:, -1]
            if temperature > 0:
                nxt = jax.random.categorical(rng, logits / temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt[:, None].astype(jnp.int32), caches

    return serve_step


def make_chunk_step(cfg: ModelConfig, *, temperature: float = 0.0,
                    policy: axon.ExecutionPolicy | None = None,
                    paged: KV.PagedCacheConfig | None = None):
    """The continuous engine's unified step.

    (params, caches, tokens (B, C), valid (B, C), rng) ->
    (next_tokens (B,), caches).  Each slot teacher-forces its valid tokens
    (a prompt chunk, or the single fed-back token while decoding) and the
    returned token is sampled from the logits at the slot's LAST valid
    position -- for a slot finishing its prompt that is its first generated
    token; for a decoding slot it is the next one.  Slots with no valid
    tokens are untouched (their sampled token is garbage the engine ignores).

    With ``paged`` the caches pytree holds pool tensors plus the device
    page table (still ONE fixed-shape step: the table is an argument, so
    admissions rewrite it without retracing).
    """
    pol = policy if policy is not None else axon.current_policy()

    def chunk_step(params, caches, tokens, valid, rng):
        with axon.policy(pol):
            logits, caches = T.prefill_step(params, caches,
                                            {"tokens": tokens}, valid, cfg,
                                            paged=paged)
            last = jnp.maximum(valid.sum(-1) - 1, 0)
            sel = jnp.take_along_axis(
                logits, last[:, None, None], axis=1)[:, 0]      # (B, vocab)
            if temperature > 0:
                nxt = jax.random.categorical(rng, sel / temperature, axis=-1)
            else:
                nxt = jnp.argmax(sel, axis=-1)
            return nxt.astype(jnp.int32), caches

    return chunk_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = 1


@dataclasses.dataclass
class _Slot:
    """Per-slot scheduler state (host side)."""

    state: str = "free"                  # free | prefill | decode
    req_idx: int = -1
    req: Request | None = None
    prompt: np.ndarray | None = None
    fed: int = 0                         # prompt tokens already consumed
    out: list[int] = dataclasses.field(default_factory=list)
    last_tok: int = 0
    t_admit: float = 0.0
    t_first: float = -1.0


class ServeEngine:
    """Continuous-batching generation over ``batch_slots`` slots.

    Scheduler knobs:
      batch_slots   : number of concurrent request lanes
      prefill_chunk : prompt tokens teacher-forced per step (clamped to the
                      smallest sliding window so a chunk never overruns a
                      rolling SWA cache)
      queue_policy  : 'fifo' (arrival order) or 'sjf' (shortest prompt first)
      quantized     : True / "int8" = weight-only int8, "int4" = packed
                      int4 (0.5 B/elem weights), "fp8" = e4m3 -- projection
                      weights are per-channel quantized at construction.
                      Pre-quantized params (including the calibrated
                      activation-int8 pytrees from ``quant.quantize_lm``,
                      whose per-layer scales thread through ``lax.scan``)
                      are accepted as-is.  Either way the step policy serves
                      at the matching reduced precision, so decode steps
                      stream sub-byte weights through the quantized kernels.
      attn_int8     : route the decode attention (QK^T / PV against the KV
                      cache) through the int8 flash kernel with per-head
                      scales -- kernel backends only (xla stays float).

    Cache knobs:
      cache_dtype   : KV-cache storage dtype.  None defaults to the model's
                      activation dtype (``cfg.cdtype``), or bfloat16 when
                      serving under a reduced-precision policy (quantized
                      weights / attn_int8) -- the cache no longer silently
                      doubles to f32 bytes for quantized serving.
      paged         : store the cache in a shared fixed-size page pool with
                      a slot->page table instead of a dense per-slot
                      ``max_len`` buffer (``repro.serve.kvcache``).
      page_size     : tokens per page (paged only).
      pool_pages    : physical pages in the pool; None sizes it dense-
                      equivalent (``batch_slots * ceil(max_len/page_size)``)
                      -- undersize it to oversubscribe slots against real
                      usage.
      cache_fmt     : None = float payload at ``cache_dtype``; "int8"/"fp8"
                      = quantize-on-write pages (per-token-per-head scales,
                      ~4x below a dense f32 cache) with dequant-on-read.
      prefix_cache  : hash completed prompts and share their full pages
                      with later requests (copy-on-write by construction),
                      skipping prefill for the shared tokens.  Auto-
                      disabled for architectures whose sequence state is
                      not fully paged (SWA / SSM / hybrid / embedding
                      frontends).

    Mesh knobs:
      mesh          : a ``jax.sharding.Mesh`` with axes from {'pod', 'data',
                      'model'} (``launch.mesh.make_debug_mesh`` /
                      ``make_production_mesh``).  Parameters are placed via
                      the TP/FSDP rules in ``parallel.specs``, the KV-cache
                      pytree (dense AND paged pools) is pinned with
                      ``NamedSharding`` from ``parallel.sharding.
                      make_cache_spec_fn``, and every jitted step is traced
                      under the mesh so the model-level ``constrain`` calls
                      take effect (tensor-parallel attention/MLP, expert-
                      parallel MoE).  All specs are divisibility-guarded:
                      outputs are bit-identical to a single-device engine.
      decouple_prefill : split serving into prefill -> insert -> generate.
                      Prompts run through a dedicated batch-1 prefill jit
                      (one fixed ``prefill_chunk``-wide signature) and the
                      produced cache is handed to a decode slot via a jitted
                      ``insert`` (``models.transformer.insert_slot``), so the
                      main chunk step stays decode-only at width 1 -- the
                      layout that lets prefill and decode later live on
                      separate meshes.  Dense caches only.

    ``generate`` returns outputs in request order; ``last_stats`` holds
    per-request latency/token counts for the most recent call, with queue
    wait (``queue_s``), time-to-first-token measured from admission
    (``ttft_s``), and decode vs prefill throughput reported separately.
    """

    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 8,
                 max_len: int = 512, prefill_chunk: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 policy: axon.ExecutionPolicy | None = None,
                 queue_policy: str = "fifo",
                 quantized: bool | str = False, attn_int8: bool = False,
                 cache_dtype=None, paged: bool = False, page_size: int = 16,
                 pool_pages: int | None = None, cache_fmt: str | None = None,
                 prefix_cache: bool = True, mesh=None,
                 decouple_prefill: bool = False):
        if queue_policy not in QUEUE_POLICIES:
            raise ValueError(
                f"queue_policy must be one of {QUEUE_POLICIES}, "
                f"got {queue_policy!r}")
        if cache_fmt is not None and not paged:
            raise ValueError("cache_fmt (quantized cache pages) requires "
                             "paged=True; dense caches take cache_dtype")
        if decouple_prefill and paged:
            raise ValueError(
                "decouple_prefill requires dense caches: paged pools have "
                "no slot axis for insert_slot to copy into (the paged "
                "handoff is a page-table rewrite, not yet wired up)")
        if quantized and not quant.is_quantized(params):
            fmt = "int8" if quantized is True else str(quantized)
            params = quant.quantize_lm_weights(params, fmt=fmt)
        # quantized (or pre-quantized params with no explicit policy) serves
        # at reduced precision; an explicitly supplied policy is otherwise
        # respected verbatim (precision="float" = dequantized reference).
        # The precision follows the weights' own storage format -- fp8
        # payloads serve under "fp8" whether they arrived pre-quantized or
        # via quantized="fp8"; everything else (int8, packed int4) under
        # "int8".
        if quant.is_quantized(params) and (quantized or policy is None):
            pol = policy if policy is not None else axon.current_policy()
            if pol.precision == "float":
                fmts = {l.fmt for l in jax.tree.leaves(
                    params,
                    is_leaf=lambda x: isinstance(x, quant.QuantizedTensor))
                    if isinstance(l, quant.QuantizedTensor)}
                prec = "fp8" if fmts == {"fp8"} else "int8"
                policy = dataclasses.replace(pol, precision=prec)
        if attn_int8:
            pol = policy if policy is not None else axon.current_policy()
            policy = dataclasses.replace(pol, attn_int8=True)
        self.params = params
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_len = max_len
        windows = [min(s.window, max_len) for s in cfg.stages if s.window]
        self.prefill_chunk = max(1, min([prefill_chunk, *windows]))
        self.queue_policy = queue_policy
        self.rng = jax.random.PRNGKey(seed)
        # cache storage dtype: the activation dtype by default; reduced-
        # precision serving (quantized weights / int8 attention) drops to
        # bf16 -- the attention path already re-quantizes or accumulates in
        # fp32, so f32 cache bytes bought nothing
        pol_now = policy if policy is not None else axon.current_policy()
        reduced = pol_now.precision != "float" or pol_now.attn_int8
        if cache_dtype is None:
            cache_dtype = jnp.bfloat16 if reduced else cfg.cdtype
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.paged: KV.PagedCacheConfig | None = None
        self.pool: KV.PagePool | None = None
        if paged:
            pps = -(-max_len // int(page_size))
            n_pool = int(pool_pages) if pool_pages is not None \
                else batch_slots * pps
            self.paged = KV.PagedCacheConfig(
                page_size=int(page_size), pages_per_slot=pps,
                pool_pages=n_pool,
                fmt=None if cache_fmt in (None, "float") else cache_fmt,
                dtype_name=self.cache_dtype.name)
            self.pool = KV.PagePool(n_pool, int(page_size))
            self.prefix_cache = bool(prefix_cache) \
                and KV.supports_prefix_reuse(cfg)
            # host mirror of the device page table; rewritten at admission
            self._pt_host = np.zeros((batch_slots, pps), np.int32)
            # pools + prefix contents persist ACROSS generate() calls --
            # that is the whole point of the prefix index
            self._caches = T.init_caches(cfg, batch=batch_slots,
                                         max_len=max_len,
                                         dtype=self.cache_dtype,
                                         paged=self.paged)
        else:
            self.prefix_cache = False
        # mesh-parallel serving: place the parameters by the TP/FSDP rules,
        # pin every cache leaf (pools, counters, page table) with a
        # NamedSharding, and declare them as the step's out_shardings so
        # donation keeps the sharded pytree in place across steps.  The
        # jitted callables are wrapped to trace under the mesh, which is
        # what arms the model-level `constrain` calls.
        self.mesh = mesh
        self.decouple_prefill = bool(decouple_prefill)
        self._cache_shardings = None
        self._pt_sharding = None
        step_out = reset_out = None
        if mesh is not None:
            self.params = jax.device_put(
                self.params,
                shd.param_sharding(self.params, mesh,
                                   make_param_spec_fn(cfg)))
            self._cache_spec_fn = shd.make_cache_spec_fn(mesh, cfg)
            struct = jax.eval_shape(
                lambda: T.init_caches(cfg, batch=batch_slots,
                                      max_len=max_len,
                                      dtype=self.cache_dtype,
                                      paged=self.paged))
            self._cache_shardings = shd.tree_shardings(
                struct, mesh, self._cache_spec_fn)
            self._pt_sharding = NamedSharding(mesh, PartitionSpec())
            if self.paged is not None:
                self._caches = jax.device_put(self._caches,
                                              self._cache_shardings)
            step_out = (NamedSharding(mesh, PartitionSpec()),
                        self._cache_shardings)
            reset_out = self._cache_shardings
        # donate the caches operand: the scatter updates and slot resets run
        # in place instead of copying the whole KV pytree every step
        self._step = self._under_mesh(jax.jit(
            make_chunk_step(cfg, temperature=temperature,
                            policy=policy, paged=self.paged),
            donate_argnums=(1,), out_shardings=step_out))
        self._reset = self._under_mesh(jax.jit(
            T.reset_slots, donate_argnums=(0,), out_shardings=reset_out))
        # prefill/insert/generate split: a dedicated prefill lane whose
        # filled cache is handed to a decode slot by a jitted insert
        # (dynamic slot index -- one trace serves every slot).  The lane
        # runs at the decode engine's own batch width with a single live
        # row: dense caches are cheap relative to paged pools, and keeping
        # the prefill step's shapes/shardings IDENTICAL to the inline
        # chunk step is what makes mesh-sharded decoupled serving
        # bit-identical to single-device (a batch-1 lane partitions
        # differently and drifts in the last ulp)
        self._prefill_caches = None
        if self.decouple_prefill:
            self._prefill_caches = T.init_caches(
                cfg, batch=batch_slots, max_len=max_len,
                dtype=self.cache_dtype)
            prefill_out = insert_out = reset_p_out = None
            if mesh is not None:
                self._prefill_caches = jax.device_put(
                    self._prefill_caches, self._cache_shardings)
                prefill_out = step_out
                insert_out = self._cache_shardings
                reset_p_out = self._cache_shardings
            self._prefill = self._under_mesh(jax.jit(
                make_chunk_step(cfg, temperature=temperature, policy=policy),
                donate_argnums=(1,), out_shardings=prefill_out))
            self._insert = self._under_mesh(jax.jit(
                T.insert_slot, donate_argnums=(0,),
                out_shardings=insert_out))
            self._reset_prefill = self._under_mesh(jax.jit(
                T.reset_slots, donate_argnums=(0,),
                out_shardings=reset_p_out))
        self.last_stats: dict[str, Any] | None = None
        # per-trace modeled cost of one chunk step, keyed by token width:
        # jitted steps never hit the op ring (one dispatch per compilation),
        # so the modeled FLOPs/bytes are captured from the traced-cost
        # ledger the first time each width is traced with telemetry on
        self._traced_step_cost: dict[int, dict[str, float]] = {}

    def _under_mesh(self, fn):
        """Wrap a jitted callable so every call (and thus every trace) runs
        inside the engine's mesh context -- that is what makes the model's
        ``parallel.sharding.constrain`` calls resolve against the mesh.
        Identity when the engine is single-device."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def wrapped(*args, **kwargs):
            with mesh:
                return fn(*args, **kwargs)

        return wrapped

    def declared_step_widths(self) -> tuple[int, ...]:
        """Token widths this engine's chunk step will ever be traced at.
        A decoupled-prefill engine runs the main step decode-only (width
        1); prompt chunks go through the dedicated prefill jit instead."""
        if self.decouple_prefill:
            return (1,)
        return declared_step_widths(self.prefill_chunk)

    def declared_prefill_widths(self) -> tuple[int, ...]:
        """Token widths of the dedicated prefill step (empty when prefill
        runs inline through the chunk step)."""
        if not self.decouple_prefill:
            return ()
        return declared_prefill_widths(self.prefill_chunk)

    # ------------------------------------------------------------- schedule

    def _validate(self, requests):
        """Fail fast -- before any compute -- on unservable requests."""
        for idx, req in enumerate(requests):
            if not req.prompt:
                raise ValueError(f"request {idx}: empty prompt")
            if len(req.prompt) + req.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {idx}: prompt ({len(req.prompt)}) + "
                    f"max_new_tokens ({req.max_new_tokens}) exceeds "
                    f"max_len={self.max_len}")

    def _admit(self, slots, pending, requests, caches, now, finish=None):
        """Backfill free slots from the pending queue (resets their cache).

        Paged engines additionally consult the page pool: admission takes
        pages (sharing any registered prompt prefix), rewrites the slot's
        row of the host page-table mirror, and starts the slot's position
        counters at the shared token count so prefill skips straight past
        the tokens the shared pages already hold.

        Decoupled-prefill engines instead run the whole prompt through the
        dedicated batch-1 prefill lane here, insert the produced cache into
        the slot, and hand the first sampled token to ``finish`` (the
        generate loop's post-sample transition) -- the slot enters the
        decode loop already holding its first token."""
        reset = np.zeros((self.batch_slots,), bool)
        lens = np.zeros((self.batch_slots,), np.int32)
        for b in range(self.batch_slots):
            if slots[b].state != "free" or not pending:
                continue
            idx = pending.popleft()
            req = requests[idx]
            if self.decouple_prefill:
                slots[b] = _Slot(state="prefill", req_idx=idx, req=req,
                                 prompt=np.asarray(req.prompt, np.int32),
                                 fed=len(req.prompt), t_admit=now)
                first, pcaches = self._prefill_request(req.prompt)
                caches = self._insert(caches, pcaches, np.int32(b))
                slots[b].state = "decode"
                finish(b, slots[b], first)
                continue
            shared = 0
            if self.pool is not None:
                need = len(req.prompt) + req.max_new_tokens
                try:
                    pages, shared = self.pool.admit(
                        b, tuple(req.prompt), need, prefix=self.prefix_cache)
                except RuntimeError:
                    # pool pressure: requeue and retry when a slot frees --
                    # unless nothing is running, in which case the request
                    # can never fit and the exhaustion is fatal
                    if all(s.state == "free" for s in slots):
                        raise
                    pending.appendleft(idx)
                    break
                self._pt_host[b, :] = 0
                self._pt_host[b, : len(pages)] = pages
            slots[b] = _Slot(state="prefill", req_idx=idx, req=req,
                             prompt=np.asarray(req.prompt, np.int32),
                             fed=shared, t_admit=now)
            lens[b] = shared
            reset[b] = True
        if reset.any():
            if self.pool is not None:
                caches[KV.PAGE_TABLE_KEY] = KV.device_page_table(
                    self._pt_host, self._pt_sharding)
                caches = self._reset(caches, jnp.asarray(reset),
                                     jnp.asarray(lens))
            else:
                caches = self._reset(caches, jnp.asarray(reset))
        return caches

    def _prefill_request(self, prompt) -> tuple[int, Any]:
        """Run one whole prompt through the dedicated prefill lane (row 0
        of the prefill cache; the other rows stay masked out).

        Every step feeds token width ``prefill_width(len(prompt),
        prefill_chunk)`` -- the single declared prefill signature; partial
        tail chunks are padded through the valid mask, so no prompt length
        can retrace the prefill jit.  Returns the first sampled token and
        the filled cache, ready for the ``insert_slot`` handoff."""
        B = self.batch_slots
        C = prefill_width(len(prompt), self.prefill_chunk)
        caches = self._reset_prefill(self._prefill_caches,
                                     jnp.ones((B,), bool))
        tok = None
        for i in range(0, len(prompt), C):
            n = min(C, len(prompt) - i)
            tokens = np.zeros((B, C), np.int32)
            tokens[0, :n] = prompt[i: i + n]
            valid = np.zeros((B, C), bool)
            valid[0, :n] = True
            self.rng, sub = jax.random.split(self.rng)
            tok, caches = self._prefill(self.params, caches,
                                        jnp.asarray(tokens),
                                        jnp.asarray(valid), sub)
        self._prefill_caches = caches
        self._prefill_fed += len(prompt)
        return int(np.asarray(tok)[0]), caches

    def generate(self, requests: list[Request]) -> list[list[int]]:
        self._validate(requests)
        B = self.batch_slots
        t0 = time.perf_counter()
        obs_on = _obs.enabled()     # snapshot: one boolean read per call
        order = list(range(len(requests)))
        if self.queue_policy == "sjf":
            order.sort(key=lambda i: len(requests[i].prompt))
        pending = collections.deque(order)
        slots = [_Slot() for _ in range(B)]
        outputs: list[list[int] | None] = [None] * len(requests)
        per_req: list[dict | None] = [None] * len(requests)
        self._prefill_fed = 0          # decoupled-prefill token counter
        if self.pool is not None:
            caches = self._caches      # pool + prefix pages persist per call
            hits0, hit_tok0 = self.pool.hits, self.pool.hit_tokens
        else:
            caches = T.init_caches(self.cfg, batch=B, max_len=self.max_len,
                                   dtype=self.cache_dtype)
            if self._cache_shardings is not None:
                caches = jax.device_put(caches, self._cache_shardings)

        def finish(b: int, s: _Slot, tok: int) -> None:
            """Post-sample slot transition, shared between the decode loop
            and decoupled-prefill admission: record the token, flip the
            slot to decode, and retire + free it when the request is done
            (eos, max_new reached, or max_new == 0)."""
            now = time.perf_counter() - t0
            if s.t_first < 0:
                s.t_first = now
            mnew = s.req.max_new_tokens
            if mnew > 0:
                s.out.append(tok)
                s.last_tok = tok
            s.state = "decode"
            if mnew == 0 or tok == s.req.eos_id or len(s.out) >= mnew:
                if self.pool is not None:
                    # freed pages return to the pool; with prefix caching
                    # the full prompt pages freeze into the index first so
                    # later requests can share them
                    self.pool.release(
                        b, prompt=tuple(s.req.prompt)
                        if self.prefix_cache else None)
                    self._pt_host[b, :] = 0
                outputs[s.req_idx] = s.out
                per_req[s.req_idx] = {
                    "prompt_len": len(s.prompt),
                    "new_tokens": len(s.out),
                    # queue wait vs compute, reported separately: all
                    # requests arrive at t=0, so t_admit IS the queue
                    # wait and ttft is measured from admission
                    "queue_s": s.t_admit,
                    "ttft_s": s.t_first - s.t_admit,
                    "decode_s": now - s.t_first,
                    "admit_s": s.t_admit,
                    "first_token_s": s.t_first,
                    "done_s": now,
                    "latency_s": now,           # all requests arrive at t=0
                }
                if obs_on:
                    _obs.serve_request_spans(
                        s.req_idx, t_origin=t0, queue_s=s.t_admit,
                        first_s=s.t_first, done_s=now,
                        prompt_len=len(s.prompt),
                        new_tokens=len(s.out), slot=b)
                slots[b] = _Slot()              # freed: backfilled next step

        steps = 0
        n_prefill = 0
        modeled = {"flops": 0.0, "bytes": 0.0, "energy_j": 0.0}
        covered_steps = 0
        # publish pool/mapper gauges on the streaming cadence for the
        # duration of this call (no-op without an active exporter)
        streaming_on = obs_on and _streaming.add_collector(
            self._stream_collector)

        while pending or any(s.state != "free" for s in slots):
            caches = self._admit(slots, pending, requests, caches,
                                 time.perf_counter() - t0, finish)
            C = step_width([s.state for s in slots], self.prefill_chunk)
            tokens = np.zeros((B, C), np.int32)
            valid = np.zeros((B, C), bool)
            fed = [0] * B
            for b, s in enumerate(slots):
                if s.state == "prefill":
                    n = min(C, len(s.prompt) - s.fed)
                    tokens[b, :n] = s.prompt[s.fed: s.fed + n]
                    valid[b, :n] = True
                    fed[b] = n
                elif s.state == "decode":
                    tokens[b, 0] = s.last_tok
                    valid[b, 0] = True
            self.rng, sub = jax.random.split(self.rng)
            t_step = time.perf_counter() if obs_on else 0.0
            ledger0 = (_obs.traced_totals()
                       if obs_on and C not in self._traced_step_cost else None)
            with _ann.host_scope("serve_step", enabled=obs_on):
                nxt, caches = self._step(self.params, caches,
                                         jnp.asarray(tokens),
                                         jnp.asarray(valid), sub)
                nxt = np.asarray(nxt)   # host transfer: device sync point
            if ledger0 is not None:
                after = _obs.traced_totals()
                if after["count"] > ledger0["count"]:
                    # this step traced: the ledger delta IS the modeled
                    # per-execution cost of a width-C chunk step
                    self._traced_step_cost[C] = {
                        k: after[k] - ledger0[k]
                        for k in ("flops", "bytes", "energy_j")}
            cost = self._traced_step_cost.get(C) if obs_on else None
            if cost is not None:
                for k in modeled:
                    modeled[k] += cost[k]
                covered_steps += 1
            if obs_on:
                _obs.add_span(
                    "serve_step", t_step, time.perf_counter() - t_step,
                    cat="serve", args={
                        "step": steps, "width": C,
                        "prefill_slots": sum(
                            1 for s in slots if s.state == "prefill"),
                        "decode_slots": sum(
                            1 for s in slots if s.state == "decode")})
            steps += 1
            n_prefill += sum(fed)
            for b, s in enumerate(slots):
                if s.state == "prefill":
                    s.fed += fed[b]
                    if s.fed < len(s.prompt):
                        continue            # prompt not finished: no sample
                elif s.state != "decode":
                    continue
                finish(b, s, int(nxt[b]))

        wall = time.perf_counter() - t0
        n_prefill += self._prefill_fed     # decoupled-prefill lane tokens
        n_tok = sum(len(o) for o in outputs if o is not None)
        self.last_stats = {
            "requests": per_req,
            "steps": steps,
            "wall_s": wall,
            "generated_tokens": n_tok,
            "tokens_per_s": n_tok / wall if wall > 0 else 0.0,
            # prompt tokens teacher-forced this call, reported apart from
            # generation throughput so mixed workloads stop under-reporting
            "prefill_tokens": n_prefill,
            "prefill_tokens_per_s": n_prefill / wall if wall > 0 else 0.0,
            "cache_bytes": KV.pytree_bytes(caches),
            "cache_bytes_per_slot": KV.pytree_bytes(caches) // B,
            # mapper cache health: a fixed-shape serve loop should be all
            # hits after warmup -- misses mid-run mean shape churn
            "mapper_cache": mapper_cache_stats(),
        }
        if self.decouple_prefill:
            self.last_stats["decoupled_prefill_tokens"] = self._prefill_fed
        if self.mesh is not None:
            self.last_stats["mesh"] = {
                "devices": int(self.mesh.size),
                "axes": {k: int(v) for k, v in self.mesh.shape.items()},
            }
        if self.pool is not None:
            self._caches = caches
            self.last_stats["pool"] = self.pool.stats()
            self.last_stats["prefix_hits"] = self.pool.hits - hits0
            self.last_stats["prefix_hit_tokens"] = \
                self.pool.hit_tokens - hit_tok0
        if obs_on:
            # achieved-intensity attribution: modeled step cost from the
            # traced ledger vs this call's measured wall time
            self.last_stats["attribution"] = _attr.engine_row(
                wall_s=wall, modeled=modeled, steps=steps,
                covered_steps=covered_steps)
            self._publish_metrics(per_req)
        if streaming_on:
            _streaming.remove_collector(self._stream_collector)
        return outputs

    def _publish_metrics(self, per_req: list[dict | None]) -> None:
        """Push this call's stats into the repro.obs registry (telemetry
        enabled only -- ``generate`` never touches metric objects
        otherwise)."""
        st = self.last_stats
        _obs_metrics.counter(
            "serve_requests_total", "requests completed").inc(
                sum(1 for r in per_req if r is not None))
        _obs_metrics.counter(
            "serve_tokens_total", "tokens generated").inc(
                st["generated_tokens"])
        _obs_metrics.counter(
            "serve_prefill_tokens_total", "prompt tokens prefilled").inc(
                st["prefill_tokens"])
        _obs_metrics.counter(
            "serve_steps_total", "engine steps executed").inc(st["steps"])
        _obs_metrics.gauge(
            "serve_tokens_per_s", "last call's generation throughput").set(
                st["tokens_per_s"])
        lat = _obs_metrics.histogram(
            "serve_request_latency_seconds", "request completion latency")
        ttft = _obs_metrics.histogram(
            "serve_ttft_seconds", "time to first token (from admission)")
        for r in per_req:
            if r is not None:
                lat.observe(r["latency_s"])
                ttft.observe(r["ttft_s"])
        self._publish_resource_gauges()

    def _publish_resource_gauges(self) -> None:
        """Mapper/page-pool gauges -- published at end of ``generate`` and,
        when a streaming exporter is running, on every snapshot cadence."""
        mc = mapper_cache_stats()
        _obs_metrics.gauge(
            "mapper_cache_hit_rate", "blocking-decision cache hit rate").set(
                mc["hit_rate"])
        _obs_metrics.gauge(
            "mapper_cache_entries", "blocking-decision cache entries").set(
                mc["entries"])
        if self.pool is not None:
            ps = self.pool.stats()
            _obs_metrics.gauge(
                "pagepool_occupancy", "fraction of KV pages in use").set(
                    ps["occupancy"])
            _obs_metrics.gauge(
                "pagepool_free_pages", "KV pages currently free").set(
                    ps["free_pages"])
            _obs_metrics.gauge(
                "pagepool_prefix_hit_rate",
                "prefix-index share of requested prompt tokens").set(
                    ps["prefix_hit_rate"])
            _obs_metrics.gauge(
                "pagepool_evictions", "prefix pages evicted (lifetime)").set(
                    ps["evictions"])

    def _stream_collector(self) -> None:
        """Streaming-exporter callback: refresh resource gauges mid-serve
        so long runs stream live occupancy, not just the final state."""
        if _obs.enabled():
            self._publish_resource_gauges()


class WaveServeEngine:
    """Wave-batched generation over fixed slots (the pre-continuous baseline).

    Known limitations, kept for benchmarking: every slot stalls until the
    longest request in its wave finishes, prompts are left-padded with
    ``reqs[0].eos_id`` (pad tokens enter shorter prompts' KV caches, and
    per-request eos ids are ignored for padding).  ``ServeEngine`` fixes
    both via slot-level masking.
    """

    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 policy: axon.ExecutionPolicy | None = None,
                 cache_dtype=None):
        self.params = params
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.cache_dtype = jnp.dtype(cfg.cdtype if cache_dtype is None
                                     else cache_dtype)
        self.rng = jax.random.PRNGKey(seed)
        self._step = jax.jit(make_serve_step(cfg, temperature=temperature,
                                             policy=policy))

    def generate(self, requests: list[Request]) -> list[list[int]]:
        outputs: list[list[int]] = []
        for i in range(0, len(requests), self.batch_slots):
            outputs.extend(self._wave(requests[i: i + self.batch_slots]))
        return outputs

    def _wave(self, reqs: list[Request]) -> list[list[int]]:
        B = len(reqs)
        caches = T.init_caches(self.cfg, batch=B, max_len=self.max_len,
                               dtype=self.cache_dtype)
        prompt_len = max(len(r.prompt) for r in reqs)
        # left-pad prompts with EOS so all slots stay aligned
        prompts = np.full((B, prompt_len), reqs[0].eos_id, np.int32)
        for b, r in enumerate(reqs):
            prompts[b, prompt_len - len(r.prompt):] = r.prompt

        tok = None
        for t in range(prompt_len):
            step_tok = jnp.asarray(prompts[:, t: t + 1])
            self.rng, sub = jax.random.split(self.rng)
            tok, caches = self._step(self.params, caches,
                                     {"tokens": step_tok}, sub)

        max_new = max(r.max_new_tokens for r in reqs)
        done = np.zeros((B,), bool)
        outs: list[list[int]] = [[] for _ in range(B)]

        def record(t_np):
            for b, r in enumerate(reqs):
                if not done[b] and len(outs[b]) < r.max_new_tokens:
                    outs[b].append(int(t_np[b]))
                    if t_np[b] == r.eos_id:
                        done[b] = True

        # the step after the last prompt token already sampled the first
        # generated token (the original wave engine discarded it)
        record(np.asarray(tok)[:, 0])
        for _ in range(max_new - 1):
            if done.all():
                break
            self.rng, sub = jax.random.split(self.rng)
            tok, caches = self._step(self.params, caches,
                                     {"tokens": tok}, sub)
            record(np.asarray(tok)[:, 0])
        return outs
