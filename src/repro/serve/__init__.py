"""Serving: cached decode step + batched engine."""
