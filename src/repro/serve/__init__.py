"""Serving: cached decode step + batched engine + paged KV-cache pool."""
from repro.serve.kvcache import (PagedCacheConfig, PagePool, pytree_bytes,
                                 summarize_pytree, supports_prefix_reuse)

__all__ = ["PagePool", "PagedCacheConfig", "pytree_bytes",
           "summarize_pytree", "supports_prefix_reuse"]
