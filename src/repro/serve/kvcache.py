"""Paged, quantized KV-cache subsystem.

The dense serving caches give every slot its own ``max_len`` buffer in the
cache dtype -- at session scale the single biggest memory-traffic sink, and
exactly the operand-reuse story the paper makes for im2col: stop re-paying
DRAM for state you already hold.  This module replaces slot-dense storage
with a **fixed-size page pool** plus a slot->page table, and optionally
stores the payload **quantized** (int8 or fp8 e4m3, per-token-per-head
scales -- the negative-axis/keepdims layout discipline of
``repro.quant.QuantizedTensor`` carried over to streaming cache writes):

  * the physical allocation is ``pool_pages x page_size`` tokens per cache
    tensor, shared by every slot; a slot consumes only the pages its request
    actually needs (``ceil((prompt + max_new) / page_size)``), so thousands
    of mostly-short sessions stop paying for ``max_len`` each;
  * payloads are int8/fp8 at 1 B/elem plus an f32 scale per token-head
    (``1/d_head`` extra bytes), ~3-4x below a dense f32 cache at equal
    capacity -- dequant-on-read keeps every float attention path (and the
    int8 flash kernel's per-head requantization) working unchanged;
  * **prefix reuse**: completed prompts register their full pages under a
    rolling hash; admission shares matching pages copy-on-write-by-
    construction (shared pages are frozen -- writes only ever land on pages
    the slot allocated fresh, because sharing is page-aligned and writes are
    append-only), so a repeated system prompt costs zero prefill steps.

Two halves:

  * **device side** -- pure functions used inside the jitted step:
    :func:`gather_pages` / :func:`scatter_pages` move token rows between the
    ``(P, page_size, ...)`` pools and ``(B, S, ...)`` views through the page
    table; :func:`read_seq` / :func:`write_seq` add the quantize-on-write /
    dequant-on-read layer.  The page table is a *step argument* (it rides
    the caches pytree), never a captured constant -- a captured table would
    retrace the step on every admission (``repro.analysis.retrace`` RTR006
    pins this).
  * **host side** -- :class:`PagePool`, the scheduler-owned allocator:
    free-list + refcounts + the prefix index (LRU, evicted under pool
    pressure).  It never touches device memory; the engine mirrors its
    decisions into the device page table.  ``invariant_errors`` is the
    machine-checkable contract (no page aliased by two writable slots,
    freed pages never referenced, refcounts consistent) that
    ``repro.analysis.pagetable`` model-checks in CI.

Deviation noted: under ``attn_int8`` the decode kernel still derives its own
per-head scales from the dequantized page stream instead of consuming the
per-token page scales directly -- folding per-token K/V scales into the
int8 QK^T/PV products is the documented kernel follow-up.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qtensor import FMT_MAX, FP8_DTYPE, to_fp8

_EPS = 1e-12

# payload formats the pools support ("fp8" still carries an f32 scale so a
# channel's abs-max lands on e4m3's top of range, like quantize_weight)
CACHE_FMTS = ("int8", "fp8")


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static (trace-time) description of a paged cache.

    ``page_size``      : tokens per page.
    ``pages_per_slot`` : logical pages a slot's table addresses
                         (``ceil(max_len / page_size)``).
    ``pool_pages``     : physical pages per pool tensor.
    ``fmt``            : ``None`` (float payload at ``dtype``), ``"int8"``,
                         or ``"fp8"`` -- quantize-on-write payload format.
    ``dtype_name``     : logical float dtype reads restore (and the storage
                         dtype when ``fmt`` is None).
    """

    page_size: int
    pages_per_slot: int
    pool_pages: int
    fmt: str | None = None
    dtype_name: str = "float32"

    def __post_init__(self) -> None:
        if self.page_size < 1 or self.pages_per_slot < 1 or self.pool_pages < 1:
            raise ValueError(f"degenerate paged cache config {self}")
        if self.fmt is not None and self.fmt not in CACHE_FMTS:
            raise ValueError(
                f"cache fmt must be None or one of {CACHE_FMTS}, "
                f"got {self.fmt!r}")

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def store_dtype(self):
        """Physical pool dtype: int8 / fp8 payload, or the float dtype."""
        if self.fmt == "int8":
            return jnp.int8
        if self.fmt == "fp8":
            return FP8_DTYPE
        return self.dtype

    @property
    def max_tokens(self) -> int:
        """Logical token capacity a slot's table addresses."""
        return self.pages_per_slot * self.page_size

    def seq_pages(self, window: int = 0) -> int:
        """Logical pages backing one cache buffer: the full table for dense
        attention, the rolling-window span for SWA."""
        if window:
            return min(self.pages_per_slot,
                       -(-min(window, self.max_tokens) // self.page_size))
        return self.pages_per_slot


def supports_prefix_reuse(cfg) -> bool:
    """Prefix pages can stand in for prefill only when the ENTIRE per-slot
    sequence state lives in paged buffers: full-attention dense/moe stages
    (rolling SWA windows and recurrent SSM/conv state are not addressable
    by position) and a token frontend (the prefix hash keys on token ids)."""
    return (cfg.frontend == "none"
            and all(s.block in ("dense", "moe") and not s.window
                    and not s.shared_attn_every for s in cfg.stages))


# ---------------------------------------------------------------------------
# device side: quantize / gather / scatter
# ---------------------------------------------------------------------------


def quantize_tokens(x: jax.Array, fmt: str) -> tuple[jax.Array, jax.Array]:
    """Per-token symmetric quantization over the trailing feature axis.

    ``x`` (..., d) float -> (payload (..., d) int8|fp8, scale (...) f32).
    One scale per token row (per head when a head axis precedes ``d``), the
    streaming analog of ``quantize_weight``'s per-channel scales.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, _EPS) / FMT_MAX[fmt]
    q = xf / scale[..., None]
    if fmt == "fp8":
        return to_fp8(q), scale
    qmax = FMT_MAX[fmt]
    return (jnp.clip(jnp.round(q), -qmax, qmax).astype(jnp.int8), scale)


def dequantize_tokens(payload: jax.Array, scale: jax.Array,
                      dtype) -> jax.Array:
    """Inverse of :func:`quantize_tokens` (restores ``dtype``)."""
    return (payload.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P, page_size, ...) pool + (B, n) page table -> (B, n * page_size, ...)
    contiguous per-slot view (the dequant-on-read fallback's first half)."""
    P, ps = pool.shape[0], pool.shape[1]
    gathered = jnp.take(pool, page_table, axis=0)     # (B, n, ps, ...)
    B, n = page_table.shape
    return gathered.reshape((B, n * ps) + pool.shape[2:])


def scatter_pages(pool: jax.Array, page_table: jax.Array, values: jax.Array,
                  idx: jax.Array, valid: jax.Array) -> jax.Array:
    """Write token rows ``values`` (B, T, ...) at logical token index
    ``idx`` (B, T) through the page table; invalid lanes are dropped (their
    writes target an out-of-bounds physical page)."""
    P, ps = pool.shape[0], pool.shape[1]
    page = jnp.minimum(idx // ps, page_table.shape[1] - 1)
    off = idx % ps
    phys = jnp.take_along_axis(page_table, page, axis=1, mode="clip")
    phys = jnp.where(valid, phys, P)                  # OOB -> dropped
    return pool.at[phys, off].set(values.astype(pool.dtype), mode="drop")


def read_seq(cache: dict, name: str, page_table: jax.Array, n_pages: int,
             *, dtype) -> jax.Array:
    """Gather cache tensor ``name`` into a contiguous (B, n_pages * ps, ...)
    float view, dequantizing quantized payloads on the way out."""
    pt = page_table[:, :n_pages]
    vals = gather_pages(cache[name + "_pages"], pt)
    scales = cache.get(name + "_scales")
    if scales is None:
        return vals.astype(dtype)
    return dequantize_tokens(vals, gather_pages(scales, pt), dtype)


def write_seq(cache: dict, name: str, page_table: jax.Array,
              values: jax.Array, idx: jax.Array, valid: jax.Array,
              fmt: str | None) -> dict:
    """Scatter this step's token rows into the pools (quantize-on-write when
    the cache carries scale pools); returns the updated leaves only."""
    pool = cache[name + "_pages"]
    scales = cache.get(name + "_scales")
    if scales is None:
        return {name + "_pages": scatter_pages(pool, page_table, values,
                                               idx, valid)}
    payload, scale = quantize_tokens(values, fmt)
    return {
        name + "_pages": scatter_pages(pool, page_table, payload, idx, valid),
        name + "_scales": scatter_pages(scales, page_table, scale, idx, valid),
    }


def init_paged_seq_cache(feats: dict[str, tuple[int, ...]], batch: int,
                         pcfg: PagedCacheConfig,
                         float_names: frozenset[str] = frozenset()) -> dict:
    """Build one layer's paged cache: a ``(pool_pages, page_size) + feat``
    payload pool per tensor (plus an f32 scale pool when quantized) and the
    per-slot ``len`` counter.  ``feats`` maps tensor name -> per-token
    feature shape, e.g. ``{"k": (n_kv, d_head), "v": (n_kv, d_head)}``.

    Tensors named in ``float_names`` stay float even under a quantized
    ``fmt`` (no scale pool; reads/writes pass through).  MLA uses this for
    the compressed latent ``c``: that tensor IS the architecture's cache
    compression already, and int8 error in it re-expands through the
    up-projection into every head's K and V -- flipping greedy near-ties
    for a handful of saved bytes -- so only the rope key quantizes."""
    out: dict = {}
    for name, feat in feats.items():
        quantize = pcfg.fmt is not None and name not in float_names
        out[name + "_pages"] = jnp.zeros(
            (pcfg.pool_pages, pcfg.page_size) + tuple(feat),
            pcfg.store_dtype if quantize else pcfg.dtype)
        if quantize:
            out[name + "_scales"] = jnp.zeros(
                (pcfg.pool_pages, pcfg.page_size) + tuple(feat[:-1]),
                jnp.float32)
    out["len"] = jnp.zeros((batch,), jnp.int32)
    return out


# leaf names reset_slots must leave untouched: pool tensors have no slot
# axis (stale rows are unreachable once the slot counters reset), and the
# page table is owned by the host-side scheduler mirror
PAGED_LEAF_SUFFIXES = ("_pages", "_scales")
PAGE_TABLE_KEY = "page_table"


def device_page_table(pt_host, sharding=None) -> jax.Array:
    """Host page-table mirror -> device array for the caches pytree.

    ``sharding`` (a NamedSharding, normally fully replicated) pins the
    table's placement on a mesh-parallel engine; without it a bare
    ``jnp.asarray`` would land the table on the default device only and
    every admission would re-negotiate its layout against the sharded
    cache pytree inside the step."""
    if sharding is not None:
        return jax.device_put(jnp.asarray(pt_host, jnp.int32), sharding)
    return jnp.asarray(pt_host, jnp.int32)


# ---------------------------------------------------------------------------
# byte accounting (the maxtext summarize_pytree_data shape)
# ---------------------------------------------------------------------------


def pytree_bytes(tree) -> int:
    """Total bytes of every array leaf (device-resident cache footprint)."""
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def summarize_pytree(tree, top: int = 8) -> dict:
    """{"total_bytes", "total_gb", "leaves": [(path, shape, dtype, bytes)]}
    sorted largest first -- the per-tensor cache accounting rows."""
    rows = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        rows.append((jax.tree_util.keystr(path), tuple(leaf.shape),
                     jnp.dtype(leaf.dtype).name, nbytes))
    rows.sort(key=lambda r: -r[-1])
    total = sum(r[-1] for r in rows)
    return {"total_bytes": total, "total_gb": total / 1024 ** 3,
            "leaves": rows[:top]}


# ---------------------------------------------------------------------------
# host side: the page allocator + prefix index
# ---------------------------------------------------------------------------


class PagePool:
    """Host-side page allocator with refcounts and a prefix index.

    The pool never touches device memory: it decides which physical page
    backs which (slot, logical page) and the engine mirrors that into the
    device page table.  Pages are refcounted because the prefix index and
    multiple slots may share one page; a page returns to the free list only
    at refcount zero.

    Sharing discipline (what makes copy-on-write trivial): only *full*
    prompt pages are ever registered or shared, and cache writes are
    append-only at positions >= the shared token count -- so a shared page
    is frozen by construction and a writable page always has exactly one
    owner.  ``invariant_errors`` checks exactly that, plus refcount/free-
    list consistency; ``repro.analysis.pagetable`` drives it over scripted
    admission/release/eviction scenarios as a CI gate.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(
                f"PagePool needs n_pages/page_size >= 1, got "
                f"{n_pages}/{page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.refcount = np.zeros(n_pages, np.int32)
        self._free: collections.deque[int] = collections.deque(range(n_pages))
        self._slot_pages: dict[int, list[int]] = {}
        self._slot_shared: dict[int, int] = {}      # leading shared pages
        # prefix key -> frozen page ids, insertion order = LRU order
        self._prefix: collections.OrderedDict[bytes, tuple[int, ...]] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0

    # -------------------------------------------------------------- prefix
    @staticmethod
    def _key(tokens) -> bytes:
        return hashlib.sha1(np.asarray(tokens, np.int64).tobytes()).digest()

    def match_prefix(self, prompt) -> tuple[tuple[int, ...], int]:
        """Longest registered page-aligned prefix of ``prompt``, capped at
        ``len(prompt) - 1`` tokens: the last prompt token is always re-fed
        so the finishing prefill step has logits to sample from."""
        ps = self.page_size
        for k in range((len(prompt) - 1) // ps, 0, -1):
            ent = self._prefix.get(self._key(prompt[: k * ps]))
            if ent is not None:
                self._prefix.move_to_end(self._key(prompt[: k * ps]))
                return ent, k * ps
        return (), 0

    def register_prefix(self, prompt, pages) -> int:
        """Freeze the full prompt pages of a finished request under every
        page-aligned prefix key (so future lookups find the longest match
        directly).  Returns the number of new index entries."""
        ps = self.page_size
        added = 0
        for k in range(1, len(prompt) // ps + 1):
            key = self._key(prompt[: k * ps])
            if key in self._prefix:
                self._prefix.move_to_end(key)
                continue
            ent = tuple(pages[:k])
            for p in ent:
                self._ref(p)
            self._prefix[key] = ent
            added += 1
        return added

    def _evict_one(self) -> bool:
        if not self._prefix:
            return False
        _, ent = self._prefix.popitem(last=False)      # least recently used
        for p in ent:
            self._deref(p)
        self.evictions += 1
        return True

    # --------------------------------------------------------------- pages
    def _ref(self, p: int) -> None:
        self.refcount[p] += 1

    def _deref(self, p: int) -> None:
        self.refcount[p] -= 1
        if self.refcount[p] < 0:
            raise RuntimeError(f"refcount underflow on page {p}")
        if self.refcount[p] == 0:
            self._free.append(p)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` free pages, evicting LRU prefix entries under
        pressure; raises RuntimeError when the pool is truly exhausted."""
        while len(self._free) < n and self._evict_one():
            pass
        if len(self._free) < n:
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)} "
                f"of {self.n_pages}")
        return [self._free.popleft() for _ in range(n)]

    def admit(self, slot: int, prompt, need_tokens: int, *,
              prefix: bool = True) -> tuple[list[int], int]:
        """Assign pages for a request needing ``need_tokens`` positions.

        Returns ``(page_ids, shared_tokens)``: the slot's logical->physical
        page list (shared prefix pages first, then fresh pages) and how many
        leading prompt tokens the shared pages already hold."""
        if slot in self._slot_pages:
            raise ValueError(f"slot {slot} already holds pages")
        shared, stok = self.match_prefix(prompt) if prefix else ((), 0)
        for p in shared:
            self._ref(p)
        n_total = -(-need_tokens // self.page_size)
        try:
            fresh = self.alloc(n_total - len(shared))
        except RuntimeError:
            for p in shared:
                self._deref(p)
            raise
        for p in fresh:
            self._ref(p)
        self._slot_pages[slot] = list(shared) + fresh
        self._slot_shared[slot] = len(shared)
        if stok:
            self.hits += 1
            self.hit_tokens += stok
        else:
            self.misses += 1
        return self._slot_pages[slot], stok

    def release(self, slot: int, prompt=None) -> None:
        """Return a finished slot's pages; with ``prompt`` given, its full
        prompt pages are first frozen into the prefix index."""
        pages = self._slot_pages.pop(slot)
        self._slot_shared.pop(slot)
        if prompt is not None:
            self.register_prefix(prompt, pages)
        for p in pages:
            self._deref(p)

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._slot_pages.get(slot, ()))

    # --------------------------------------------------------------- state
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "pages": self.n_pages,
            "page_size": self.page_size,
            "free_pages": self.free_pages,
            "occupancy": 1.0 - self.free_pages / self.n_pages,
            "prefix_entries": len(self._prefix),
            "prefix_hits": self.hits,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_hit_rate": self.hits / lookups if lookups else 0.0,
            "evictions": self.evictions,
        }

    def invariant_errors(self) -> list[tuple[str, str]]:
        """Machine-checkable allocator contract; empty = consistent.

        Codes: PGT001 a page aliased into a writable region, PGT002 a freed
        page still referenced, PGT003 refcount inconsistent with the
        reference graph, PGT004 free-list corruption (duplicate or leaked
        page)."""
        errs: list[tuple[str, str]] = []
        expected = np.zeros(self.n_pages, np.int64)
        owners: dict[int, list[int]] = collections.defaultdict(list)
        writable: dict[int, int] = {}
        for s, pages in self._slot_pages.items():
            sh = self._slot_shared.get(s, 0)
            for i, p in enumerate(pages):
                expected[p] += 1
                owners[p].append(s)
                if i >= sh:
                    if p in writable:
                        errs.append((
                            "PGT001",
                            f"page {p} is in the writable region of slots "
                            f"{writable[p]} and {s}"))
                    writable[p] = s
        frozen = set()
        for ent in self._prefix.values():
            for p in ent:
                expected[p] += 1
                frozen.add(p)
        for p, s in writable.items():
            if p in frozen:
                errs.append((
                    "PGT001",
                    f"page {p} is writable by slot {s} but frozen in the "
                    "prefix index"))
            if len(owners[p]) > 1:
                errs.append((
                    "PGT001",
                    f"page {p} is writable by slot {s} but referenced by "
                    f"slots {sorted(owners[p])}"))
        for p in np.nonzero(expected != self.refcount)[0]:
            errs.append((
                "PGT003",
                f"page {int(p)} refcount {int(self.refcount[p])} != "
                f"{int(expected[p])} references held"))
        free = list(self._free)
        free_set = set(free)
        if len(free) != len(free_set):
            errs.append(("PGT004", "free list holds duplicate pages"))
        for p in free:
            if expected[p] or self.refcount[p] > 0:
                errs.append((
                    "PGT002", f"free page {p} is still referenced"))
        for p in range(self.n_pages):
            if self.refcount[p] == 0 and p not in free_set:
                errs.append((
                    "PGT004",
                    f"page {p} has refcount 0 but is not on the free list "
                    "(leaked)"))
        return errs
