"""The jitted training step: loss -> grads (with optional gradient
accumulation over microbatches) -> AdamW update.

The step is a pure function of (state, batch); microbatching reshapes the
leading batch dim to (n_micro, micro) and accumulates grads with a scan so
activation memory scales with the microbatch, not the global batch.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import axon
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import compress_with_feedback, init_error
from repro.parallel.sharding import constrain


def init_train_state(key, cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                     *, grad_compression: bool = False) -> dict:
    params = T.init_params(key, cfg)
    state = {
        "params": params,
        "opt": adamw.init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    if grad_compression:
        state["ef_error"] = init_error(params)
    return state


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig, *,
                    microbatches: int = 1, grad_compression: bool = False,
                    accum_dtype=jnp.float32,
                    policy: axon.ExecutionPolicy | None = None):
    """``policy`` pins the axon execution policy for the whole step at trace
    time (forward and backward contractions both dispatch under it); None
    captures the policy current at construction."""
    pol = policy if policy is not None else axon.current_policy()

    def loss_of(params, mb):
        with axon.policy(pol):
            return T.loss_fn(params, mb, cfg)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        batch = jax.tree.map(
            lambda x: constrain(x, "batch"), batch)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def to_micro(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbatch = jax.tree.map(to_micro, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: (a + b.astype(accum_dtype)), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss), ms = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32)), mbatch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m[-1], ms)

        new_state = dict(state)
        if grad_compression:
            grads, new_state["ef_error"] = compress_with_feedback(
                grads, state["ef_error"])

        params, opt, opt_metrics = adamw.adamw_update(
            params, grads, state["opt"], opt_cfg)
        new_state.update(params=params, opt=opt, step=state["step"] + 1)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return new_state, metrics

    return train_step
