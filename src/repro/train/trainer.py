"""Fault-tolerant training loop.

Production posture (1000+-node design, exercised at CPU scale here):
  * auto-resume from the latest verified checkpoint (params + optimizer +
    data-iterator state), elastic across mesh-shape changes
  * periodic async checkpoints; emergency checkpoint on SIGTERM/SIGINT
  * crash retry: a step that raises is retried from the last checkpoint up
    to ``max_retries`` times (covers transient device/host failures)
  * straggler watchdog: per-step wall-time is tracked; steps slower than
    ``straggler_factor`` x the running median are logged with a hook for
    external remediation (the single-process analogue of replacing a slow
    host)
"""
from __future__ import annotations

import logging
import signal
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.trainer")


class Trainer:
    def __init__(
        self,
        *,
        train_step: Callable[[dict, dict], tuple[dict, dict]],
        state: dict,
        dataset,
        ckpt_dir: str,
        ckpt_every: int = 100,
        keep_n: int = 3,
        max_retries: int = 3,
        straggler_factor: float = 3.0,
        straggler_hook: Callable[[int, float, float], None] | None = None,
        batch_shardings: Any = None,
    ):
        self.train_step = train_step
        self.state = state
        self.dataset = dataset
        self.ckpt = CheckpointManager(ckpt_dir, keep_n=keep_n)
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_hook = straggler_hook or self._default_straggler_hook
        self.batch_shardings = batch_shardings
        self.step_times: list[float] = []
        self.metrics_history: list[dict] = []
        self._stop = False

    # ------------------------------------------------------------ resume
    def maybe_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        self.state, extra = self.ckpt.restore(self.state)
        if "data_state" in extra:
            self.dataset.restore(extra["data_state"])
        log.info("resumed from checkpoint step %d", latest)
        return True

    # ------------------------------------------------------------ signals
    def _install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("signal %s: emergency checkpoint then stop", signum)
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _default_straggler_hook(self, step: int, dt: float, median: float):
        log.warning("straggler: step %d took %.3fs (median %.3fs)",
                    step, dt, median)

    # ------------------------------------------------------------ loop
    def _save(self, step: int):
        self.ckpt.save(step, self.state,
                       extra={"data_state": self.dataset.state()})

    def run(self, num_steps: int) -> list[dict]:
        self._install_signal_handlers()
        start = int(self.state["step"])
        retries = 0
        step = start
        while step < num_steps and not self._stop:
            batch = self.dataset.next()
            if self.batch_shardings is not None:
                batch = {k: jax.device_put(v, self.batch_shardings[k])
                         for k, v in batch.items()}
            t0 = time.monotonic()
            try:
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:  # noqa: BLE001 -- transient-failure retry path
                retries += 1
                log.exception("step %d failed (retry %d/%d)",
                              step, retries, self.max_retries)
                if retries > self.max_retries:
                    raise
                if self.ckpt.latest_step() is not None:
                    self.maybe_resume()
                    step = int(self.state["step"])
                continue
            retries = 0
            dt = time.monotonic() - t0
            self.step_times.append(dt)
            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-50:])
                if dt > self.straggler_factor * med:
                    self.straggler_hook(step, dt, med)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            metrics["step_time_s"] = dt
            self.metrics_history.append(metrics)
            step += 1
            if step % self.ckpt_every == 0:
                self._save(step)
        self._save(step)
        self.ckpt.wait()
        return self.metrics_history
