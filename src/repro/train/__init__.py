"""Training: jitted train step + fault-tolerant Trainer loop."""
