"""Page-table invariant checker: model-check the serving page allocator.

The paged KV cache (``repro.serve.kvcache``) splits responsibility: device
pools hold the bytes, a host-side :class:`PagePool` decides which physical
page backs which (slot, logical page).  A bug in that allocator corrupts
cache contents *silently* -- a page aliased into two writable regions makes
one request's decode read another's keys, which no shape check and no
single-request test can see.  This pass drives the real allocator through
scripted admission / release / prefix-reuse / eviction / exhaustion
scenarios and audits ``PagePool.invariant_errors`` after every transition:

  PGT001  a page aliased into a writable region (two writable slots, or
          writable while frozen in the prefix index)
  PGT002  a freed page still referenced
  PGT003  refcounts inconsistent with the reference graph
  PGT004  free-list corruption (duplicate or leaked page)
  PGT005  a scripted scenario deviated from the allocator's contract
          (prefix sharing, eviction under pressure, exhaustion recovery)

Everything runs host-side on a few dozen pages -- no device memory, no
tracing -- so the pass adds milliseconds to the analysis gate.
"""
from __future__ import annotations

import random

from repro.analysis.findings import Finding, error

PASS = "pagetable"
PAGE_SIZE = 4


def _pool(n_pages: int):
    from repro.serve.kvcache import PagePool
    return PagePool(n_pages, PAGE_SIZE)


def _audit(pool, ctx: str, out: list[Finding]) -> None:
    for code, msg in pool.invariant_errors():
        out.append(error(code, PASS, "PagePool", f"after {ctx}: {msg}"))


def _deviation(out: list[Finding], ctx: str, msg: str) -> None:
    out.append(error("PGT005", PASS, "PagePool", f"{ctx}: {msg}"))


def _prompt(seed: int, n: int) -> tuple[int, ...]:
    return tuple(seed * 100 + i for i in range(n))


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


def _scenario_churn() -> list[Finding]:
    """Admit/release cycles with no prefix sharing: pages must round-trip
    back to the free list with zero refcounts."""
    out: list[Finding] = []
    pool = _pool(16)
    for round_ in range(3):
        for slot in range(4):
            pool.admit(slot, _prompt(slot, 5), 5 + slot, prefix=False)
            _audit(pool, f"churn admit r{round_} s{slot}", out)
        for slot in range(4):
            pool.release(slot)
            _audit(pool, f"churn release r{round_} s{slot}", out)
    if pool.free_pages != pool.n_pages:
        _deviation(out, "churn",
                   f"{pool.n_pages - pool.free_pages} pages never returned "
                   "to the free list after all slots released")
    return out


def _scenario_prefix_reuse() -> list[Finding]:
    """A released prompt's full pages must be shared (frozen) on the next
    admission of the same prompt, with writes isolated to fresh pages."""
    out: list[Finding] = []
    pool = _pool(16)
    prompt = _prompt(7, 2 * PAGE_SIZE)            # exactly two full pages
    pages0, shared0 = pool.admit(0, prompt, len(prompt) + 4)
    if shared0 != 0:
        _deviation(out, "prefix", "cold admission reported shared tokens")
    _audit(pool, "prefix cold admit", out)
    pool.release(0, prompt=prompt)
    _audit(pool, "prefix register+release", out)

    pages1, shared1 = pool.admit(1, prompt, len(prompt) + 4)
    _audit(pool, "prefix warm admit", out)
    # the last prompt token is always re-fed, so at most one full page of
    # the two registers as shareable here
    if shared1 != PAGE_SIZE:
        _deviation(out, "prefix",
                   f"warm admission shared {shared1} tokens, expected "
                   f"{PAGE_SIZE} (longest full-page prefix short of the "
                   "last prompt token)")
    elif pages1[0] != pages0[0]:
        _deviation(out, "prefix",
                   "warm admission did not reuse the registered page")
    # a concurrent admission of the same prompt shares the same frozen page
    pages2, shared2 = pool.admit(2, prompt, len(prompt) + 4)
    _audit(pool, "prefix concurrent admit", out)
    if shared2 and pages2[0] != pages1[0]:
        _deviation(out, "prefix",
                   "two live slots sharing one prefix got different pages")
    pool.release(1, prompt=prompt)
    pool.release(2, prompt=prompt)
    _audit(pool, "prefix all released", out)
    return out


def _scenario_eviction() -> list[Finding]:
    """Under pool pressure the LRU prefix entries must be evicted -- and
    only entries, never a live slot's pages."""
    out: list[Finding] = []
    pool = _pool(8)
    # fill the index: 3 distinct 1-page prompts, registered then released
    for i in range(3):
        prompt = _prompt(i, PAGE_SIZE)
        pool.admit(0, prompt, len(prompt) + 1)
        pool.release(0, prompt=prompt)
        _audit(pool, f"eviction seed {i}", out)
    # demand more pages than remain free: evictions must make room
    free_before = pool.free_pages
    pages, _ = pool.admit(1, _prompt(9, 4), free_before * PAGE_SIZE
                          + PAGE_SIZE)
    _audit(pool, "eviction pressure admit", out)
    if pool.evictions == 0:
        _deviation(out, "eviction",
                   "admission beyond the free-page count succeeded without "
                   "evicting any prefix entry")
    pool.release(1)
    _audit(pool, "eviction release", out)
    return out


def _scenario_exhaustion() -> list[Finding]:
    """True exhaustion (live slots own everything) must raise -- and leave
    the allocator exactly as it was."""
    out: list[Finding] = []
    pool = _pool(4)
    pool.admit(0, _prompt(1, 4), 4 * PAGE_SIZE)   # slot 0 takes every page
    _audit(pool, "exhaustion full admit", out)
    rc_before = pool.refcount.copy()
    try:
        pool.admit(1, _prompt(2, 4), PAGE_SIZE)
    except RuntimeError:
        pass
    else:
        _deviation(out, "exhaustion",
                   "admission succeeded with every page owned by a live "
                   "slot")
    _audit(pool, "exhaustion failed admit", out)
    if (pool.refcount != rc_before).any():
        _deviation(out, "exhaustion",
                   "a failed admission changed page refcounts")
    pool.release(0)
    _audit(pool, "exhaustion release", out)
    return out


def _scenario_fuzz() -> list[Finding]:
    """Deterministic random churn over an oversubscribed pool: admissions,
    prefix reuse, releases, and pressure-driven evictions interleaved."""
    out: list[Finding] = []
    pool = _pool(12)
    rng = random.Random(0)
    prompts = [_prompt(i, rng.randrange(1, 3 * PAGE_SIZE)) for i in range(6)]
    live: dict[int, tuple[int, ...]] = {}
    for step in range(200):
        slot = rng.randrange(4)
        if slot in live:
            prompt = live.pop(slot)
            # half the releases register the prompt's pages for reuse
            pool.release(slot, prompt=prompt if rng.random() < 0.5 else None)
        else:
            prompt = rng.choice(prompts)
            need = len(prompt) + rng.randrange(1, 8)
            try:
                pool.admit(slot, prompt, need)
                live[slot] = prompt
            except RuntimeError:
                pass                       # oversubscribed: acceptable
        if pool.invariant_errors():
            _audit(pool, f"fuzz step {step}", out)
            break                          # first corruption is enough
    for slot in sorted(live):
        pool.release(slot, prompt=live[slot])
    _audit(pool, "fuzz drain", out)
    return out


def run() -> list[Finding]:
    """Model-check the page allocator through every scripted scenario."""
    return (_scenario_churn() + _scenario_prefix_reuse()
            + _scenario_eviction() + _scenario_exhaustion()
            + _scenario_fuzz())
