"""Retrace-hazard detector: prove the engines' ONE-fixed-shape promise.

Both engines jit exactly one step and promise a closed set of traced
signatures -- ``ServeEngine`` feeds token width ``prefill_chunk`` while any
slot prefilling and 1 for pure decode; ``VisionEngine`` always feeds
``batch_slots`` lanes.  A third signature sneaking in recompiles mid-serve,
which shows up as a multi-second latency spike the tests never catch
(they run warm).  This pass proves the promise two ways:

  * **state enumeration** -- the signature-deciding hooks
    (``serve.engine.step_width``, ``vision.engine.step_batch``) are pure
    functions of scheduler state, so enumerating every slot-state multiset
    (resp. admission count) and checking the produced signature against
    ``declared_step_widths`` / ``declared_step_batches`` is an exhaustive
    proof over a superset of the reachable states:

      RTR001  a reachable scheduler state produces an undeclared signature
      RTR002  a declared signature no state produces (dead declaration)

The decoupled-prefill lane (``ServeEngine(decouple_prefill=True)``) jits a
SECOND step whose token width comes from ``serve.engine.prefill_width`` --
the same enumeration argument applies: every prompt length must resolve to
a width in ``declared_prefill_widths`` or admission retraces the lane.

  * **AST discipline** -- the proof is only sound while the engines keep
    routing their shape decisions through the hooks:

      RTR003  ServeEngine.generate decides the token width without calling
              step_width
      RTR004  jax.jit called inside a serve loop (generate / infer / _wave
              / _admit / _prefill_request) instead of once at construction
      RTR005  VisionEngine.infer decides the lane padding without calling
              step_batch
      RTR006  the paged-cache page table passed into the step as a keyword
              (it must ride the caches pytree: a table baked in at trace
              time would retrace the chunk step on every admission)
      RTR007  ServeEngine._prefill_request decides the prefill token width
              without calling prefill_width
"""
from __future__ import annotations

import ast
import inspect
import itertools

from repro.analysis.findings import Finding, error, warning

PASS = "retrace"
# enumeration sizes: slot-state multisets are symmetric in slot order, so a
# handful of slots and chunk sizes covers every (any-prefill?, any-decode?)
# combination the hooks can distinguish
ENUM_SLOTS = 4
ENUM_PREFILL_CHUNKS = (1, 2, 16)
ENUM_BATCH_SLOTS = (1, 4, 8)
ENUM_PROMPT_LENS = range(1, 64)


# ---------------------------------------------------------------------------
# state enumeration
# ---------------------------------------------------------------------------


def _check_serve_widths() -> list[Finding]:
    from repro.serve import engine as se
    out: list[Finding] = []
    for chunk in ENUM_PREFILL_CHUNKS:
        declared = set(se.declared_step_widths(chunk))
        produced: set[int] = set()
        for n_slots in range(1, ENUM_SLOTS + 1):
            for states in itertools.combinations_with_replacement(
                    se.SLOT_STATES, n_slots):
                w = se.step_width(list(states), chunk)
                produced.add(w)
                if w not in declared:
                    out.append(error(
                        "RTR001", PASS, "ServeEngine",
                        f"slot states {states} with prefill_chunk={chunk} "
                        f"produce token width {w}, outside the declared "
                        f"set {sorted(declared)} -- this state would "
                        "retrace the chunk step mid-serve"))
        for w in declared - produced:
            out.append(warning(
                "RTR002", PASS, "ServeEngine",
                f"declared token width {w} (prefill_chunk={chunk}) is "
                "produced by no enumerated slot state; dead declaration"))
    return out


def _check_prefill_widths() -> list[Finding]:
    from repro.serve import engine as se
    out: list[Finding] = []
    for chunk in ENUM_PREFILL_CHUNKS:
        declared = set(se.declared_prefill_widths(chunk))
        produced: set[int] = set()
        for plen in ENUM_PROMPT_LENS:
            w = se.prefill_width(plen, chunk)
            produced.add(w)
            if w not in declared:
                out.append(error(
                    "RTR001", PASS, "ServeEngine(prefill)",
                    f"prompt length {plen} with prefill_chunk={chunk} "
                    f"produces prefill token width {w}, outside the "
                    f"declared set {sorted(declared)} -- this prompt would "
                    "retrace the decoupled prefill step mid-serve"))
        for w in declared - produced:
            out.append(warning(
                "RTR002", PASS, "ServeEngine(prefill)",
                f"declared prefill width {w} (prefill_chunk={chunk}) is "
                "produced by no enumerated prompt length; dead declaration"))
    return out


def _check_vision_batches() -> list[Finding]:
    from repro.vision import engine as ve
    out: list[Finding] = []
    for slots in ENUM_BATCH_SLOTS:
        declared = set(ve.declared_step_batches(slots))
        produced: set[int] = set()
        for n_admitted in range(slots + 1):
            b = ve.step_batch(n_admitted, slots)
            produced.add(b)
            if b not in declared:
                out.append(error(
                    "RTR001", PASS, "VisionEngine",
                    f"admitting {n_admitted} of {slots} lanes produces "
                    f"batch dim {b}, outside the declared set "
                    f"{sorted(declared)} -- this admission count would "
                    "retrace the infer step mid-serve"))
        for b in declared - produced:
            out.append(warning(
                "RTR002", PASS, "VisionEngine",
                f"declared batch dim {b} (batch_slots={slots}) is produced "
                "by no admission count; dead declaration"))
    return out


# ---------------------------------------------------------------------------
# AST discipline
# ---------------------------------------------------------------------------


def _calls_name(tree: ast.AST, name: str) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == name:
                return True
            if isinstance(fn, ast.Attribute) and fn.attr == name:
                return True
    return False


def _jit_calls(tree: ast.AST) -> list[int]:
    lines = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "jit"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "jax"):
            lines.append(node.lineno)
    return lines


def _method(cls_node: ast.ClassDef, name: str) -> ast.FunctionDef | None:
    for node in cls_node.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _module_ast(mod) -> tuple[ast.Module, str]:
    path = inspect.getsourcefile(mod)
    with open(path, encoding="utf-8") as f:
        return ast.parse(f.read()), path


def _page_table_kwargs(tree: ast.AST) -> list[int]:
    lines = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "page_table":
                    lines.append(node.lineno)
    return lines


def _check_serve_ast() -> list[Finding]:
    from repro.serve import engine as se
    tree, path = _module_ast(se)
    out: list[Finding] = []
    # the page table is dynamic per-admission state: inside serve/engine.py
    # it must only ever reach the jitted step THROUGH the caches pytree --
    # any `page_table=` keyword here means a concrete table was captured at
    # trace time, and every admission would retrace the step
    for line in _page_table_kwargs(tree):
        out.append(error(
            "RTR006", PASS, "serve.engine",
            "page_table passed as a keyword; the paged step must take the "
            "table through the caches pytree (see models.transformer."
            "init_caches) or every admission retraces",
            path=path, line=line))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef)
                and node.name == "ServeEngine"):
            continue
        gen = _method(node, "generate")
        if gen is None:
            continue
        if not _calls_name(gen, "step_width"):
            out.append(error(
                "RTR003", PASS, "ServeEngine.generate",
                "token width is decided without calling step_width(); the "
                "retrace proof only covers widths routed through the hook",
                path=path, line=gen.lineno))
        pre = _method(node, "_prefill_request")
        if pre is not None and not _calls_name(pre, "prefill_width"):
            out.append(error(
                "RTR007", PASS, "ServeEngine._prefill_request",
                "prefill token width is decided without calling "
                "prefill_width(); the retrace proof only covers widths "
                "routed through the hook", path=path, line=pre.lineno))
        for meth_name in ("generate", "_wave", "_admit", "_prefill_request"):
            meth = _method(node, meth_name)
            if meth is None:
                continue
            for line in _jit_calls(meth):
                out.append(error(
                    "RTR004", PASS, f"ServeEngine.{meth_name}",
                    "jax.jit inside the serve loop: steps must be jitted "
                    "once at construction", path=path, line=line))
    return out


def _check_vision_ast() -> list[Finding]:
    from repro.vision import engine as ve
    tree, path = _module_ast(ve)
    out: list[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef)
                and node.name == "VisionEngine"):
            continue
        inf = _method(node, "infer")
        if inf is None:
            continue
        if not _calls_name(inf, "step_batch"):
            out.append(error(
                "RTR005", PASS, "VisionEngine.infer",
                "lane padding is decided without calling step_batch(); the "
                "retrace proof only covers batch dims routed through the "
                "hook", path=path, line=inf.lineno))
        for line in _jit_calls(inf):
            out.append(error(
                "RTR004", PASS, "VisionEngine.infer",
                "jax.jit inside the serve loop: steps must be jitted once "
                "at construction", path=path, line=line))
    return out


def run() -> list[Finding]:
    """Run the retrace-hazard detector over both engines."""
    return (_check_serve_widths() + _check_prefill_widths()
            + _check_vision_batches()
            + _check_serve_ast() + _check_vision_ast())
