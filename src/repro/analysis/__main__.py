"""CLI for the static analyzer: ``python -m repro.analysis``.

Exits 0 when no ERROR-severity finding is present, 1 otherwise -- the CI
``analysis`` job runs this as a blocking gate before the test shards.

``--cache PATH`` keys the (deterministic) full-suite result on a hash of
every ``src/repro`` source file plus the jax version: a warm CI cache skips
the kernel abstract-eval entirely and replays the stored findings.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import sys
from pathlib import Path

from repro.analysis import PASSES, run_all
from repro.analysis.findings import (Finding, has_errors, render_json,
                                     render_text)


def _source_hash() -> str:
    import jax
    root = Path(__file__).resolve().parents[1]        # src/repro
    h = hashlib.sha256()
    h.update(jax.__version__.encode())
    for py in sorted(root.rglob("*.py")):
        h.update(str(py.relative_to(root)).encode())
        h.update(py.read_bytes())
    return h.hexdigest()


def _cache_load(path: Path, key: str, passes: tuple[str, ...]):
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if doc.get("key") != key or doc.get("passes") != list(passes):
        return None
    findings = [Finding(**f) for f in doc["findings"]]
    return findings, doc["counts"], doc["elapsed"]


def _cache_store(path: Path, key: str, passes: tuple[str, ...],
                 findings: list[Finding], counts, elapsed) -> None:
    doc = {"key": key, "passes": list(passes),
           "findings": [dataclasses.asdict(f) for f in findings],
           "counts": counts, "elapsed": elapsed}
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static kernel-contract / retrace-hazard analyzer")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the findings (in --format) to a file")
    ap.add_argument("--passes", nargs="+", choices=PASSES,
                    default=list(PASSES))
    ap.add_argument("--cache", type=Path, default=None,
                    help="replay/store results keyed on a source hash")
    args = ap.parse_args(argv)

    passes = tuple(args.passes)
    cached = None
    key = None
    if args.cache is not None:
        key = _source_hash()
        cached = _cache_load(args.cache, key, passes)
    if cached is not None:
        findings, counts, elapsed = cached
    else:
        findings, counts, elapsed = run_all(passes)
        if args.cache is not None:
            _cache_store(args.cache, key, passes, findings, counts, elapsed)

    render = render_json if args.format == "json" else render_text
    text = render(findings, counts, elapsed)
    print(text)
    if args.out is not None:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(text + "\n")
    return 1 if has_errors(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
