"""Repo-specific AST lint: the rules that keep the kernel/dispatch
architecture honest.

Runs over every module under ``src/repro`` (tests and benchmarks are out of
scope -- they deliberately poke deprecated and interpret-mode paths):

  LNT001  import of the deprecated ``repro.kernels.ops`` shim layer --
          in-repo code must call ``repro.axon`` instead
  LNT002  Python-level ``if``/``while`` on a ``pl.program_id`` value inside
          a Pallas kernel body (trace-time branching on a tracer;
          ``pl.when`` is the sanctioned conditional).  Static attribute
          tests (``ref.dtype`` / ``.shape`` / ``.ndim``) are fine.
  LNT003  host-side API inside a Pallas kernel body: ``np.*``,
          ``jax.jit`` / ``vmap`` / ``grad`` / ``pmap`` / ``device_put``,
          ``jax.random.*`` -- these trace outside the kernel or crash at
          lowering, never what a kernel body means
  LNT004  a registered kernel kind declares no VJP marker (``vjp="custom"``
          / ``"native"`` / ``"no_vjp"`` with a reason)
  LNT005  literal ``interpret=True`` outside policy.py -- interpret mode is
          the execution policy's decision, never hard-coded
  LNT006  ``jnp.einsum`` in ``models/`` or ``vision/`` -- contractions in
          model code must go through ``axon.einsum`` so policy dispatch
          (backend, precision, quantized routing) applies
  LNT007  a contraction-kernel module imported outside ``repro.axon`` /
          ``repro.kernels`` -- the registry is the only sanctioned route
  LNT008  a ``pl.pallas_call`` whose ``interpret=`` is missing or a
          literal -- it must thread a policy-derived variable
  LNT009  a host clock or ``repro.obs`` recording call inside a kernel
          body or jit-traced step -- under the tracer it stamps trace
          time / is dropped silently; record from the host loop.  The
          ``repro.obs.annotate`` scope API is whitelisted (jit-legal by
          design).
  LNT010  a dynamic annotation label (f-string interpolation, ``.format``,
          ``%``) on ``annotate.scope``/``host_scope`` anywhere, or on
          ``jax.named_scope`` / ``jax.profiler.TraceAnnotation`` in traced
          code -- labels must be static so scope cardinality stays bounded

Every rule reports ``path:line`` so findings are clickable.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.findings import Finding, error

PASS = "lint"

# contraction-kernel modules only the axon dispatch layer may import
# (reference helpers like kernels.ref and the attention kernel that
# models/layers.py wires in by design are NOT restricted)
_KERNEL_MODULES = ("axon_gemm", "gemv", "im2col_conv", "dwconv",
                   "quant_gemm", "zero_gate_gemm")
_KERNEL_IMPORTERS_OK = ("repro.axon", "repro.kernels")
_HOST_JAX_ATTRS = ("jit", "vmap", "pmap", "grad", "value_and_grad",
                   "device_put", "make_jaxpr", "eval_shape")


def _modname(path: Path, root: Path) -> str:
    rel = path.resolve().relative_to(root.resolve().parent)
    parts = list(rel.with_suffix("").parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _dotted(node: ast.AST) -> str | None:
    """'pl.pallas_call' for Attribute chains, 'name' for Names."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# kernel-body discovery
# ---------------------------------------------------------------------------


def _pallas_call_sites(tree: ast.Module) -> list[ast.Call]:
    return [node for node in ast.walk(tree)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "pallas_call"]


def _kernel_fn_names(tree: ast.Module) -> set[str]:
    """Names of functions passed (possibly via functools.partial) as the
    kernel argument of a pallas_call."""
    names: set[str] = set()
    for call in _pallas_call_sites(tree):
        if not call.args:
            continue
        arg = call.args[0]
        if isinstance(arg, ast.Name):
            names.add(arg.id)
        elif isinstance(arg, ast.Call) and arg.args:
            fn_name = _dotted(arg.func)
            if fn_name in ("functools.partial", "partial") \
                    and isinstance(arg.args[0], ast.Name):
                names.add(arg.args[0].id)
    return names


def _kernel_fn_defs(tree: ast.Module) -> list[ast.FunctionDef]:
    names = _kernel_fn_names(tree)
    return [node for node in ast.walk(tree)
            if isinstance(node, ast.FunctionDef) and node.name in names]


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


def _lnt001_ops_import(path: str, tree: ast.Module,
                       modname: str) -> list[Finding]:
    if modname.startswith("repro.kernels.ops"):
        return []
    out = []
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro.kernels.ops"):
                    hit = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module.startswith("repro.kernels.ops"):
                hit = node.module
            elif node.module == "repro.kernels" \
                    and any(a.name == "ops" for a in node.names):
                hit = "repro.kernels.ops"
        if hit:
            out.append(error(
                "LNT001", PASS, modname,
                f"imports deprecated shim {hit}; call repro.axon instead",
                path=path, line=node.lineno))
    return out


def _program_id_names(fn: ast.FunctionDef) -> set[str]:
    names: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = _dotted(node.value.func) or ""
            if callee.endswith("program_id"):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
    return names


def _lnt002_tracer_branch(path: str, tree: ast.Module,
                          modname: str) -> list[Finding]:
    out = []
    for fn in _kernel_fn_defs(tree):
        pid_names = _program_id_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
                continue
            for sub in ast.walk(node.test):
                is_pid = (isinstance(sub, ast.Name)
                          and sub.id in pid_names)
                is_call = (isinstance(sub, ast.Call)
                           and (_dotted(sub.func) or "").endswith(
                               "program_id"))
                if is_pid or is_call:
                    out.append(error(
                        "LNT002", PASS, f"{modname}.{fn.name}",
                        "Python-level branch on a pl.program_id value "
                        "inside a kernel body; use pl.when (tracers have "
                        "no truth value at lowering)",
                        path=path, line=node.lineno))
                    break
    return out


def _lnt003_host_ops(path: str, tree: ast.Module,
                     modname: str) -> list[Finding]:
    out = []
    for fn in _kernel_fn_defs(tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee is None:
                continue
            bad = (callee.startswith("np.")
                   or callee.startswith("numpy.")
                   or callee.startswith("jax.random.")
                   or (callee.startswith("jax.")
                       and callee.split(".")[1] in _HOST_JAX_ATTRS))
            if bad:
                out.append(error(
                    "LNT003", PASS, f"{modname}.{fn.name}",
                    f"host-side call {callee} inside a Pallas kernel body",
                    path=path, line=node.lineno))
    return out


def _lnt004_vjp_markers() -> list[Finding]:
    from repro.axon import registry
    out = []
    for kind in registry.kinds():
        meta = registry.meta(kind)
        if meta.vjp is None:
            out.append(error(
                "LNT004", PASS, kind,
                "registered kind declares no VJP marker; register with "
                'vjp="custom" / "native", or vjp="no_vjp" plus a '
                "vjp_reason"))
    return out


def _lnt005_interpret_literal(path: str, tree: ast.Module,
                              modname: str) -> list[Finding]:
    if modname == "repro.axon.policy":
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                out.append(error(
                    "LNT005", PASS, modname,
                    "literal interpret=True; interpret mode is the "
                    "execution policy's call (ExecutionPolicy.interpret())",
                    path=path, line=node.lineno))
    return out


def _lnt006_raw_einsum(path: str, tree: ast.Module,
                       modname: str) -> list[Finding]:
    if not (modname.startswith("repro.models")
            or modname.startswith("repro.vision")):
        return []
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and _dotted(node.func) in ("jnp.einsum", "numpy.einsum",
                                           "np.einsum", "jax.numpy.einsum")):
            out.append(error(
                "LNT006", PASS, modname,
                "raw jnp.einsum in model code bypasses policy dispatch "
                "(backend/precision/quantized routing); use axon.einsum",
                path=path, line=node.lineno))
    return out


def _lnt007_kernel_imports(path: str, tree: ast.Module,
                           modname: str) -> list[Finding]:
    if any(modname == ok or modname.startswith(ok + ".")
           for ok in _KERNEL_IMPORTERS_OK):
        return []
    out = []
    for node in ast.walk(tree):
        hits: list[str] = []
        if isinstance(node, ast.Import):
            hits = [a.name for a in node.names
                    if a.name.startswith("repro.kernels.")
                    and a.name.split(".")[2] in _KERNEL_MODULES]
        elif isinstance(node, ast.ImportFrom) and node.module:
            parts = node.module.split(".")
            if (node.module.startswith("repro.kernels.")
                    and parts[2] in _KERNEL_MODULES):
                hits = [node.module]
            elif node.module == "repro.kernels":
                hits = [f"repro.kernels.{a.name}" for a in node.names
                        if a.name in _KERNEL_MODULES]
        for hit in hits:
            out.append(error(
                "LNT007", PASS, modname,
                f"imports contraction kernel {hit} directly; dispatch "
                "through repro.axon (the registry is the only sanctioned "
                "route)", path=path, line=node.lineno))
    return out


def _lnt008_pallas_interpret_kwarg(path: str, tree: ast.Module,
                                   modname: str) -> list[Finding]:
    out = []
    for call in _pallas_call_sites(tree):
        kw = next((k for k in call.keywords if k.arg == "interpret"), None)
        if kw is None:
            out.append(error(
                "LNT008", PASS, modname,
                "pl.pallas_call without an interpret= kwarg; thread the "
                "policy-derived flag so the kernel runs everywhere",
                path=path, line=call.lineno))
        elif isinstance(kw.value, ast.Constant):
            out.append(error(
                "LNT008", PASS, modname,
                f"pl.pallas_call with literal interpret={kw.value.value}; "
                "thread a policy-derived variable instead",
                path=path, line=call.lineno))
    return out


_LNT009_CLOCKS = ("time", "perf_counter", "monotonic", "process_time")


def _jit_traced_fn_defs(tree: ast.Module) -> list[ast.FunctionDef]:
    """Functions that execute under a jit tracer: defs passed to
    ``jax.jit`` (directly or through ``functools.partial``), plus every
    inner def of a ``make_*step`` factory (the engines' step builders --
    their closures are exactly what gets traced)."""
    jitted: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func) in ("jax.jit", "jit") and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Name):
                jitted.add(arg.id)
            elif isinstance(arg, ast.Call) and arg.args \
                    and isinstance(arg.args[0], ast.Name):
                jitted.add(arg.args[0].id)
    defs = [n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef) and n.name in jitted]
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name.startswith("make_") \
                and node.name.endswith("step"):
            defs.extend(n for n in ast.walk(node)
                        if isinstance(n, ast.FunctionDef) and n is not node)
    return defs


def _obs_import_aliases(tree: ast.Module
                        ) -> tuple[set[str], set[str], set[str]]:
    """(clock_names, obs_roots, annotate_names) bound in this module.

    ``annotate_names`` holds aliases of ``repro.obs.annotate`` (and of its
    ``scope``/``host_scope`` functions): the one repro.obs API that is
    legal inside traced code -- it only pushes ``jax.named_scope`` there."""
    clock_names: set[str] = set()
    obs_roots: set[str] = set()
    annotate_names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    clock_names.update(
                        f"{a.asname or 'time'}.{c}" for c in _LNT009_CLOCKS)
                elif a.name == "repro.obs" or a.name.startswith("repro.obs."):
                    obs_roots.add((a.asname or a.name).split(".")[0])
                    if a.name == "repro.obs.annotate" and a.asname:
                        annotate_names.add(a.asname)
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module == "time":
                clock_names.update(a.asname or a.name for a in node.names
                                   if a.name in _LNT009_CLOCKS)
            elif node.module == "repro.obs":
                obs_roots.update(a.asname or a.name for a in node.names)
                annotate_names.update(a.asname or a.name for a in node.names
                                      if a.name == "annotate")
            elif node.module == "repro.obs.annotate":
                obs_roots.update(a.asname or a.name for a in node.names)
                annotate_names.update(a.asname or a.name for a in node.names
                                      if a.name in ("scope", "host_scope"))
            elif node.module.startswith("repro.obs."):
                obs_roots.update(a.asname or a.name for a in node.names)
            elif node.module == "repro":
                obs_roots.update(a.asname or a.name for a in node.names
                                 if a.name == "obs")
    return clock_names, obs_roots, annotate_names


def _is_annotate_call(name: str, annotate_names: set[str]) -> bool:
    """True when a dotted call name resolves to the annotate API."""
    root = name.split(".")[0]
    return (name in annotate_names or root in annotate_names
            or ".annotate." in f".{name}.")


def _lnt009_host_calls_in_traced(path: str, tree: ast.Module,
                                 modname: str) -> list[Finding]:
    """No host clocks or ``repro.obs`` calls inside kernel bodies or
    jit-traced step functions: both run under a tracer, where a
    ``time.perf_counter()`` stamps trace time (once, at compile -- a
    constant thereafter) and a metrics/optrace call is silently dropped by
    the tracer guard (or worse, records per-trace instead of per-step).
    The ``repro.obs.annotate`` API is whitelisted: it exists precisely to
    be called under the tracer (``jax.named_scope`` is jit-legal)."""
    clock_names, obs_roots, annotate_names = _obs_import_aliases(tree)
    out = []
    traced = {id(d): d for d in _kernel_fn_defs(tree)}
    traced.update((id(d), d) for d in _jit_traced_fn_defs(tree))
    for fn in traced.values():
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func) or ""
            if name in clock_names:
                out.append(error(
                    "LNT009", PASS, modname,
                    f"host clock {name}() inside traced function "
                    f"{fn.name!r}: under jit this stamps trace time once "
                    "at compile, not per step -- time on the host side",
                    path=path, line=node.lineno))
            elif name.split(".")[0] in obs_roots \
                    and not _is_annotate_call(name, annotate_names):
                out.append(error(
                    "LNT009", PASS, modname,
                    f"repro.obs call {name}() inside traced function "
                    f"{fn.name!r}: the tracer guard drops it silently -- "
                    "record from the host loop instead",
                    path=path, line=node.lineno))
    return out


# annotation label expressions that force a retrace or explode the scope
# cardinality: f-strings with interpolations, .format(), %-formatting
def _dynamic_label(expr: ast.expr | None) -> str | None:
    """Why a label expression is dynamic, or None when it is acceptable.

    Constants, names, attributes and ``+`` concatenations of them are fine
    (``"axon:" + kind`` resolves to a handful of values); interpolation
    baked per call site is not -- each distinct label is a distinct name
    stack entry, and values interpolated from tracers don't even render."""
    if isinstance(expr, ast.JoinedStr):
        if any(isinstance(v, ast.FormattedValue) for v in expr.values):
            return "f-string label"
        return None
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Attribute) \
            and expr.func.attr == "format":
        return ".format() label"
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Mod):
        return "%-formatted label"
    return None


def _lnt010_dynamic_annotation_labels(path: str, tree: ast.Module,
                                      modname: str) -> list[Finding]:
    """Annotation names must be static: a per-request or per-step label on
    ``annotate.scope``/``host_scope`` (anywhere) or on ``jax.named_scope``/
    ``jax.profiler.TraceAnnotation`` (inside traced defs) creates unbounded
    scope cardinality in profiles -- and under jit an interpolated tracer
    renders as its abstract value, once, at trace time."""
    _, _, annotate_names = _obs_import_aliases(tree)
    out: list[Finding] = []

    traced = {id(d) for d in _kernel_fn_defs(tree)}
    traced.update(id(d) for d in _jit_traced_fn_defs(tree))
    in_traced: set[int] = set()
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and id(fn) in traced:
            in_traced.update(id(n) for n in ast.walk(fn))

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        name = _dotted(node.func) or ""
        is_ann = annotate_names and _is_annotate_call(name, annotate_names)
        is_raw = name in ("jax.named_scope",
                          "jax.profiler.TraceAnnotation") \
            and id(node) in in_traced
        if not (is_ann or is_raw):
            continue
        why = _dynamic_label(node.args[0])
        if why:
            out.append(error(
                "LNT010", PASS, modname,
                f"{why} in {name}(): annotation names must be static "
                "(constant or a bounded concatenation) -- dynamic labels "
                "explode scope cardinality and render tracers as abstract "
                "values",
                path=path, line=node.lineno))
    return out


_FILE_RULES = (_lnt001_ops_import, _lnt002_tracer_branch, _lnt003_host_ops,
               _lnt005_interpret_literal, _lnt006_raw_einsum,
               _lnt007_kernel_imports, _lnt008_pallas_interpret_kwarg,
               _lnt009_host_calls_in_traced,
               _lnt010_dynamic_annotation_labels)


def check_file(path: str, tree: ast.Module, modname: str) -> list[Finding]:
    """All file-scoped lint rules on one parsed module."""
    out: list[Finding] = []
    for rule in _FILE_RULES:
        out.extend(rule(path, tree, modname))
    return out


def run(root: Path | None = None) -> list[Finding]:
    """Run the lint pass over ``src/repro`` (or a fixture tree)."""
    if root is None:
        root = Path(__file__).resolve().parents[1]     # src/repro
    out: list[Finding] = []
    for py in sorted(root.rglob("*.py")):
        try:
            tree = ast.parse(py.read_text(encoding="utf-8"))
        except SyntaxError as e:
            out.append(error("LNT001", PASS, str(py),
                             f"unparseable source: {e}", path=str(py)))
            continue
        out.extend(check_file(str(py), tree, _modname(py, root)))
    out.extend(_lnt004_vjp_markers())
    return out
