"""Pytree invariant checker for :class:`repro.quant.QuantizedTensor`.

The container's layout rules -- negative channel axis, keepdims scale
shapes, int4 nibble-packing along ``-2``, broadcast-trivial ``act_scale``
trailing dims -- are exactly what lets a scan-stacked ``(L, ...)`` weight
survive ``lax.scan``'s leading-axis slicing with no special cases.  This
pass verifies them three ways:

  * **representative constructions** (QTI001-QTI004): build every storage
    format (int8 / int4 / fp8; plain, scan-stacked, calibrated) through the
    public constructors and run ``QuantizedTensor.layout_errors()`` on each:

      QTI001  non-negative channel axis (or positive-axis construction)
      QTI002  scale is not keepdims-broadcastable against the logical shape
      QTI003  int4 packing violation (pack axis, pack_size, channel axis)
      QTI004  act_scale trailing dims are not all 1

  * **scan sliceability** (QTI005): under ``eval_shape`` (nothing runs),
    slice a stacked tensor two ways -- ``slice_leading`` (the repo's
    oracle) and a real ``lax.scan`` over the xs pytree -- and require the
    per-layer structures to match exactly.

  * **AST scan** (QTI006): every in-repo call site of
    ``QuantizedTensor(...)`` / ``quantize_weight(...)`` with a literal
    ``axis=`` argument must pass it negative.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding, error
from repro.quant import qtensor as qt

PASS = "qt_invariants"

_RULE_BY_KEYWORD = (
    ("channel axis", "QTI001"),
    ("scale ndim", "QTI002"),
    ("scale dim", "QTI002"),
    ("int4", "QTI003"),
    ("packed axis", "QTI003"),
    ("pack_size", "QTI003"),
    ("bits=4", "QTI003"),
    ("act_scale", "QTI004"),
)


def _rule_for(err: str) -> str:
    for key, rule in _RULE_BY_KEYWORD:
        if key in err:
            return rule
    return "QTI002"


def check_tensor(t: qt.QuantizedTensor, subject: str) -> list[Finding]:
    """QTI001-QTI004 on one constructed tensor."""
    return [error(_rule_for(e), PASS, subject, e)
            for e in t.layout_errors()]


# ---------------------------------------------------------------------------
# representative constructions
# ---------------------------------------------------------------------------


def _representatives() -> list[tuple[str, qt.QuantizedTensor]]:
    rng = np.random.default_rng(0)
    w2 = jnp.asarray(rng.standard_normal((96, 64)).astype(np.float32))
    w2_odd = jnp.asarray(rng.standard_normal((33, 64)).astype(np.float32))
    w4 = jnp.asarray(
        rng.standard_normal((3, 3, 16, 32)).astype(np.float32))
    stacked = jnp.asarray(
        rng.standard_normal((4, 96, 64)).astype(np.float32))
    reps: list[tuple[str, qt.QuantizedTensor]] = []
    for fmt in ("int8", "int4", "fp8"):
        reps.append((f"quantize_weight (96,64) {fmt}",
                     qt.quantize_weight(w2, fmt=fmt)))
        reps.append((f"quantize_weight odd-K (33,64) {fmt}",
                     qt.quantize_weight(w2_odd, fmt=fmt)))
        reps.append((f"quantize_weight stacked (4,96,64) {fmt}",
                     qt.quantize_weight(stacked, reduce_axes=(-2,),
                                        fmt=fmt)))
    reps.append(("quantize_weight conv (3,3,16,32) int8",
                 qt.quantize_weight(w4, fmt="int8")))
    # calibrated: per-tensor and per-layer (scan-stacked) activation scales
    base = qt.quantize_weight(w2, fmt="int8")
    reps.append(("calibrated per-tensor act_scale", dataclasses.replace(
        base, act_scale=jnp.asarray(0.5, jnp.float32).reshape(()))))
    st = qt.quantize_weight(stacked, reduce_axes=(-2,), fmt="int8")
    reps.append(("calibrated per-layer act_scale", dataclasses.replace(
        st, act_scale=jnp.full((4, 1, 1), 0.5, jnp.float32))))
    return reps


def _check_constructions() -> list[Finding]:
    out: list[Finding] = []
    for label, t in _representatives():
        out.extend(check_tensor(t, label))
    return out


# ---------------------------------------------------------------------------
# scan sliceability (QTI005)
# ---------------------------------------------------------------------------


def _scan_slice_structs(stacked: qt.QuantizedTensor):
    """Per-layer structure exactly as ``lax.scan`` slices it: trace a scan
    over the xs pytree with ``make_jaxpr`` (abstract -- nothing executes)
    and capture the sliced pytree the body receives."""
    captured: list = []

    def body(carry, layer):
        captured.append(layer)
        return carry, carry

    jax.make_jaxpr(
        lambda s: jax.lax.scan(body, jnp.int32(0), s)[0])(stacked)
    return captured[0]


def _struct_of(t) -> list[tuple]:
    leaves, treedef = jax.tree_util.tree_flatten(
        t, is_leaf=lambda x: x is None)
    return [(str(treedef))] + [
        None if l is None else (tuple(l.shape), jnp.dtype(l.dtype).name)
        for l in leaves]


def _check_scan_sliceability() -> list[Finding]:
    out: list[Finding] = []
    rng = np.random.default_rng(1)
    stacked_w = jnp.asarray(
        rng.standard_normal((4, 96, 64)).astype(np.float32))
    for fmt in ("int8", "int4", "fp8"):
        subject = f"scan-stacked (4,96,64) {fmt}"
        st = qt.quantize_weight(stacked_w, reduce_axes=(-2,), fmt=fmt)
        if fmt == "int8":
            st = dataclasses.replace(
                st, act_scale=jnp.full((4, 1, 1), 0.5, jnp.float32))
        try:
            scanned = _scan_slice_structs(st)
        except Exception as e:               # noqa: BLE001
            out.append(error(
                "QTI005", PASS, subject,
                f"lax.scan cannot slice the stacked tensor: "
                f"{type(e).__name__}: {e}"))
            continue
        oracle = qt.slice_leading(st, 0)
        if _struct_of(scanned) != _struct_of(oracle):
            out.append(error(
                "QTI005", PASS, subject,
                f"lax.scan per-layer slice {_struct_of(scanned)} != "
                f"slice_leading oracle {_struct_of(oracle)}"))
            continue
        out.extend(check_tensor(oracle, subject + " (sliced)"))
    return out


# ---------------------------------------------------------------------------
# AST scan of construction sites (QTI006)
# ---------------------------------------------------------------------------

_CONSTRUCTORS = ("QuantizedTensor", "quantize_weight")


def _literal_int(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.Constant)
            and isinstance(node.operand.value, int)):
        return -node.operand.value
    return None


def check_source(path: str, tree: ast.Module) -> list[Finding]:
    """QTI006 on one parsed source file."""
    out: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if name not in _CONSTRUCTORS:
            continue
        for kw in node.keywords:
            if kw.arg != "axis":
                continue
            val = _literal_int(kw.value)
            if val is not None and val >= 0:
                out.append(error(
                    "QTI006", PASS, name,
                    f"construction passes literal axis={val}; channel "
                    "axes must be negative so the tensor survives "
                    "leading-axis slicing", path=path, line=node.lineno))
    return out


def _check_sources(root: Path | None = None) -> list[Finding]:
    if root is None:
        root = Path(__file__).resolve().parents[1]     # src/repro
    out: list[Finding] = []
    for py in sorted(root.rglob("*.py")):
        try:
            tree = ast.parse(py.read_text(encoding="utf-8"))
        except SyntaxError as e:
            out.append(error("QTI006", PASS, str(py),
                             f"unparseable source: {e}"))
            continue
        out.extend(check_source(str(py), tree))
    return out


def run(root: Path | None = None) -> list[Finding]:
    """Run the QuantizedTensor invariant checker."""
    return (_check_constructions() + _check_scan_sliceability()
            + _check_sources(root))
