"""Structured findings: the one record type every analysis pass emits.

A :class:`Finding` pins a rule ID (``AXC*`` contracts, ``RTR*`` retrace,
``QTI*`` qt-invariants, ``LNT*`` lint), a severity, the subject it fired on
(a kernel kind + shape, an engine, a file:line), and a human message.  The
CLI renders a list of findings as text or JSON and exits nonzero iff any
ERROR-severity finding is present.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

SEVERITIES = ("ERROR", "WARNING", "INFO")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis result.

    ``rule``     : stable rule ID (e.g. ``"AXC004"``) -- tests and CI
                   grep on these, never on message text.
    ``severity`` : ``"ERROR"`` (gates CI), ``"WARNING"``, or ``"INFO"``.
    ``pass_name``: which pass produced it (``contracts`` / ``retrace`` /
                   ``qt_invariants`` / ``lint``).
    ``subject``  : what it fired on -- ``"gemm[(192,320)x(320,160) f32
                   order=WS]"``, ``"ServeEngine"``, a dotted module name.
    ``message``  : human-readable description of the violation.
    ``path`` / ``line``: source location when the pass is AST-based.
    """

    rule: str
    severity: str
    pass_name: str
    subject: str
    message: str
    path: str | None = None
    line: int | None = None

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    def render(self) -> str:
        loc = f"{self.path}:{self.line}: " if self.path else ""
        return (f"{self.severity:7s} {self.rule} [{self.pass_name}] "
                f"{loc}{self.subject}: {self.message}")


def error(rule: str, pass_name: str, subject: str, message: str,
          **kw) -> Finding:
    return Finding(rule, "ERROR", pass_name, subject, message, **kw)


def warning(rule: str, pass_name: str, subject: str, message: str,
            **kw) -> Finding:
    return Finding(rule, "WARNING", pass_name, subject, message, **kw)


def info(rule: str, pass_name: str, subject: str, message: str,
         **kw) -> Finding:
    return Finding(rule, "INFO", pass_name, subject, message, **kw)


def has_errors(findings: Iterable[Finding]) -> bool:
    return any(f.severity == "ERROR" for f in findings)


def render_text(findings: list[Finding],
                counts: dict[str, int] | None = None,
                elapsed: dict[str, float] | None = None) -> str:
    lines = [f.render() for f in findings]
    n_err = sum(f.severity == "ERROR" for f in findings)
    n_warn = sum(f.severity == "WARNING" for f in findings)
    summary = (f"repro.analysis: {len(findings)} finding(s) "
               f"({n_err} error, {n_warn} warning)")
    if counts:
        per = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        summary += f" [{per}]"
    if elapsed:
        per = ", ".join(f"{k}={v:.1f}s" for k, v in sorted(elapsed.items()))
        summary += f" ({per})"
    lines.append(summary)
    return "\n".join(lines)


def render_json(findings: list[Finding],
                counts: dict[str, int] | None = None,
                elapsed: dict[str, float] | None = None) -> str:
    doc: dict[str, Any] = {
        "findings": [f.to_dict() for f in findings],
        "errors": sum(f.severity == "ERROR" for f in findings),
        "warnings": sum(f.severity == "WARNING" for f in findings),
    }
    if counts is not None:
        doc["per_pass_findings"] = counts
    if elapsed is not None:
        doc["per_pass_seconds"] = {k: round(v, 3)
                                   for k, v in elapsed.items()}
    return json.dumps(doc, indent=2, sort_keys=True)
