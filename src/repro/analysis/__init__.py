"""``repro.analysis``: static contract analysis gating CI.

Four passes, one CLI (``python -m repro.analysis``):

  * ``contracts``      -- abstract-eval every registered kernel kind over a
                          representative shape/dtype grid and verify VMEM
                          budgets, grid/index-map coverage, revisit safety,
                          divisibility, and accumulation-dtype rules
                          against the registry's declared contracts (AXC*).
  * ``retrace``        -- prove the serve/vision engines' ONE-fixed-shape
                          step promise by enumerating scheduler states
                          against the declared traced signatures (RTR*).
  * ``qt_invariants``  -- verify QuantizedTensor layout rules (negative
                          axes, keepdims scales, scan sliceability) on
                          representative constructions and call sites
                          (QTI*).
  * ``lint``           -- repo-specific AST rules (deprecated imports,
                          tracer branching, policy discipline) (LNT*).
  * ``pagetable``      -- model-check the paged KV-cache allocator
                          (``repro.serve.kvcache.PagePool``) through
                          scripted admission/release/prefix/eviction
                          scenarios: no page aliased by two writable
                          slots, freed pages never referenced, refcounts
                          consistent (PGT*).

Everything traces abstractly -- no kernel executes -- so the whole suite
runs in seconds and the CI gate exits nonzero on any ERROR finding.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.analysis.findings import (Finding, has_errors, render_json,
                                     render_text)

PASSES: tuple[str, ...] = ("contracts", "retrace", "qt_invariants", "lint",
                           "pagetable")


def _pass_runner(name: str) -> Callable[[], list[Finding]]:
    # imported lazily so `--passes lint` does not pay for kernel tracing
    if name == "contracts":
        from repro.analysis import contracts
        return contracts.run
    if name == "retrace":
        from repro.analysis import retrace
        return retrace.run
    if name == "qt_invariants":
        from repro.analysis import qt_invariants
        return qt_invariants.run
    if name == "lint":
        from repro.analysis import lint
        return lint.run
    if name == "pagetable":
        from repro.analysis import pagetable
        return pagetable.run
    raise ValueError(f"unknown pass {name!r}; have {PASSES}")


def run_all(passes: tuple[str, ...] | list[str] = PASSES
            ) -> tuple[list[Finding], dict[str, int], dict[str, float]]:
    """Run the requested passes; returns (findings, per-pass finding
    counts, per-pass wall seconds)."""
    findings: list[Finding] = []
    counts: dict[str, int] = {}
    elapsed: dict[str, float] = {}
    for name in passes:
        runner = _pass_runner(name)
        t0 = time.perf_counter()
        fs = runner()
        elapsed[name] = time.perf_counter() - t0
        counts[name] = len(fs)
        findings.extend(fs)
    return findings, counts, elapsed


__all__ = ["Finding", "PASSES", "has_errors", "render_json", "render_text",
           "run_all"]
