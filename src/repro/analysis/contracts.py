"""Kernel contract checker: abstract-eval every registered kind, verify the
pallas_call it traces to against the declared :class:`~repro.axon.registry.
KernelMeta` contract and the Pallas/TPU structural rules.

For every kind in ``axon.registry.kinds()`` a driver grid of representative
shapes/dtypes traces the registered implementation with ``jax.make_jaxpr``
on ``ShapeDtypeStruct``s -- nothing executes -- and each ``pallas_call``
equation found in the jaxpr is checked:

  AXC000  kind has no driver coverage (a new registration must add one)
  AXC001  per-invocation VMEM working set exceeds the tile budget
  AXC002  grid x index-map coverage leaves an output tile unwritten
  AXC003  an output index map emits an out-of-bounds tile
  AXC004  output-revisit hazard: a grid dim the output's index map ignores
          is not innermost, so revisits are non-consecutive and partial
          sums are lost on real TPU (interpret mode hides this)
  AXC005  a dot_general accumulates in a dtype the physics or the declared
          contract forbids (int8 x int8 -> int32, fp8 -> f32, float -> f32)
  AXC006  an output array dim is not divisible by its block dim (the repo's
          kernels pad explicitly; a ragged tail here means masked writes
          the kernels do not implement)
  AXC007  a pallas-backed kind ignores ``policy.accum_dtype`` (tracing with
          an unimplementable accumulation dtype must raise)

Index maps are evaluated concretely over the whole grid product (grids in
the driver set are small by construction), so coverage/OOB/revisit findings
are exact, not heuristic.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding, error, warning
from repro.axon import registry
from repro.axon.policy import ExecutionPolicy
from repro.core.dataflows import Dataflow
from repro.core.hw import VMEM_TILE_BUDGET

PASS = "contracts"
# full-grid index-map evaluation cap; drivers stay far below this
MAX_GRID_POINTS = 20_000


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _subjaxprs(eqn) -> list:
    out = []
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jax.core.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jax.core.ClosedJaxpr):
                    out.append(item.jaxpr)
                elif isinstance(item, jax.core.Jaxpr):
                    out.append(item)
    return out


def iter_eqns(jaxpr):
    """All equations in ``jaxpr``, recursing into call/scan/custom-vjp
    sub-jaxprs (pallas kernel bodies are NOT descended into here -- the
    checks read them explicitly via ``eqn.params["jaxpr"]``)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for sub in _subjaxprs(eqn):
            yield from iter_eqns(sub)


def find_pallas_calls(jaxpr) -> list:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


# ---------------------------------------------------------------------------
# pallas_call introspection helpers
# ---------------------------------------------------------------------------


def _block_shape(bm) -> tuple[int, ...]:
    return tuple(1 if d is None else int(d) for d in bm.block_shape)


def _eval_index_map(bm, idx: tuple[int, ...]) -> tuple[int, ...]:
    cj = bm.index_map_jaxpr
    out = jax.core.eval_jaxpr(cj.jaxpr, cj.consts, *idx)
    return tuple(int(v) for v in out)


def _grid_points(grid: tuple[int, ...]):
    return itertools.product(*(range(int(g)) for g in grid))


def _vmem_bytes(eqn) -> int:
    """Working-set estimate: all operand/output blocks double-buffered
    (Pallas pipelines block DMAs) plus scratch, in bytes."""
    gm = eqn.params["grid_mapping"]
    total = 0
    for bm in gm.block_mappings:
        shape = _block_shape(bm)
        itemsize = jnp.dtype(bm.array_shape_dtype.dtype).itemsize
        n = 1
        for d in shape:
            n *= d
        total += 2 * n * itemsize
    kernel_jaxpr = eqn.params["jaxpr"]
    n_blocked = len(gm.block_mappings)
    for var in kernel_jaxpr.invars[n_blocked:]:          # scratch operands
        aval = var.aval
        n = 1
        for d in aval.shape:
            n *= d
        total += n * jnp.dtype(aval.dtype).itemsize
    return total


def _accum_findings(eqn, kind: str, subject: str) -> list[Finding]:
    """AXC005 on every dot_general inside the kernel body."""
    out: list[Finding] = []
    meta = registry.meta(kind)
    allowed = meta.accum_dtypes
    kernel_jaxpr = eqn.params["jaxpr"]
    for keqn in iter_eqns(kernel_jaxpr):
        if keqn.primitive.name != "dot_general":
            continue
        lhs_dt = jnp.dtype(keqn.invars[0].aval.dtype)
        rhs_dt = jnp.dtype(keqn.invars[1].aval.dtype)
        acc_dt = jnp.dtype(keqn.outvars[0].aval.dtype)
        both_int = (jnp.issubdtype(lhs_dt, jnp.integer)
                    and jnp.issubdtype(rhs_dt, jnp.integer))
        any_fp8 = any(jnp.dtype(d).itemsize == 1
                      and jnp.issubdtype(d, jnp.floating)
                      for d in (lhs_dt, rhs_dt))
        if both_int and acc_dt != jnp.int32:
            out.append(error(
                "AXC005", PASS, subject,
                f"int x int dot_general ({lhs_dt.name} x {rhs_dt.name}) "
                f"accumulates in {acc_dt.name}; int8 paths must accumulate "
                "in int32 (narrower overflows, float drops low bits)"))
        elif not both_int and acc_dt != jnp.float32:
            hint = "fp8 operands" if any_fp8 else "float operands"
            out.append(error(
                "AXC005", PASS, subject,
                f"dot_general ({lhs_dt.name} x {rhs_dt.name}) accumulates "
                f"in {acc_dt.name}; {hint} must accumulate in float32"))
        if allowed and acc_dt.name not in allowed:
            out.append(error(
                "AXC005", PASS, subject,
                f"dot_general accumulates in {acc_dt.name} but the "
                f"registered contract for {kind!r} declares accum="
                f"{meta.accum!r}"))
    return out


def check_pallas_eqn(eqn, kind: str, subject: str) -> list[Finding]:
    """All structural checks (AXC001-AXC006) on one pallas_call equation."""
    out: list[Finding] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)

    # AXC001 -- VMEM working set
    used = _vmem_bytes(eqn)
    if used > VMEM_TILE_BUDGET:
        out.append(error(
            "AXC001", PASS, subject,
            f"VMEM working set {used / 2**20:.1f} MiB exceeds the "
            f"{VMEM_TILE_BUDGET / 2**20:.0f} MiB tile budget "
            f"(grid={grid})"))

    n_points = 1
    for g in grid:
        n_points *= g
    if n_points > MAX_GRID_POINTS:
        out.append(warning(
            "AXC002", PASS, subject,
            f"grid {grid} has {n_points} points (> {MAX_GRID_POINTS}); "
            "coverage/revisit checks skipped -- shrink the driver shapes"))
        out.extend(_accum_findings(eqn, kind, subject))
        return out

    out_mappings = list(gm.block_mappings_output)
    for oi, bm in enumerate(out_mappings):
        block = _block_shape(bm)
        ashape = tuple(bm.array_shape_dtype.shape)
        tiles_per_dim = tuple(-(-d // b) for d, b in zip(ashape, block))

        # AXC006 -- divisibility (the kernels pad; ragged outputs would
        # need masked writes they do not implement)
        ragged = [f"dim {i}: {d} % {b}" for i, (d, b)
                  in enumerate(zip(ashape, block)) if d % b]
        if ragged:
            out.append(error(
                "AXC006", PASS, subject,
                f"output {oi} array shape {ashape} not divisible by block "
                f"{block} ({'; '.join(ragged)})"))

        seen: set[tuple[int, ...]] = set()
        influences = [False] * len(grid)
        prev_by_rest: dict[tuple, dict[int, tuple]] = {}
        oob_reported = False
        for point in _grid_points(grid):
            idx = _eval_index_map(bm, point)
            seen.add(idx)
            if not oob_reported and any(
                    i < 0 or i >= t for i, t in zip(idx, tiles_per_dim)):
                out.append(error(
                    "AXC003", PASS, subject,
                    f"output {oi} index map sends grid point {point} to "
                    f"tile {idx}, outside the {tiles_per_dim} tile range"))
                oob_reported = True
            # influence: does varying grid dim d (others fixed) move idx?
            for d in range(len(grid)):
                rest = (d, point[:d] + point[d + 1:])
                prev = prev_by_rest.setdefault(rest, {})
                for other_coord, other_idx in prev.items():
                    if other_idx != idx:
                        influences[d] = True
                prev[point[d]] = idx
                if len(prev) > 2:      # two distinct coords are enough
                    prev.pop(next(iter(prev)))

        # AXC002 -- coverage
        want = set(itertools.product(*(range(t) for t in tiles_per_dim)))
        missing = want - seen
        if missing:
            ex = sorted(missing)[:3]
            out.append(error(
                "AXC002", PASS, subject,
                f"output {oi} index map never writes {len(missing)} of "
                f"{len(want)} tiles (e.g. {ex}); those output blocks are "
                "garbage"))

        # AXC004 -- revisit hazard
        ignored = [d for d in range(len(grid))
                   if not influences[d] and grid[d] > 1]
        if ignored:
            last_influencing = max(
                (d for d in range(len(grid)) if influences[d]), default=-1)
            bad = [d for d in ignored if d < last_influencing]
            if bad:
                out.append(error(
                    "AXC004", PASS, subject,
                    f"output {oi} index map ignores grid dim(s) {bad} of "
                    f"grid {grid} but they are not innermost: revisits to "
                    "the same output block are non-consecutive, so partial "
                    "sums are silently lost on real TPU (interpret mode "
                    "hides this)"))

    out.extend(_accum_findings(eqn, kind, subject))
    return out


# ---------------------------------------------------------------------------
# driver grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Driver:
    """One representative invocation of a registered impl.

    ``make(pol)`` returns ``(fn, args)`` such that ``jax.make_jaxpr(fn)
    (*args)`` traces the registered implementation under ``pol``."""

    kind: str
    label: str
    make: Callable[[ExecutionPolicy], tuple[Callable, tuple]]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _f32(*shape):
    return _sds(shape, jnp.float32)


def _i8(*shape):
    return _sds(shape, jnp.int8)


def _gemm_driver(order: Dataflow, M, K, N) -> Driver:
    def make(pol):
        pol = dataclasses.replace(pol, block=(128, 128, 128), order=order)
        fn = lambda a, b: registry.get("gemm")(a, b, pol, jnp.float32)
        return fn, (_f32(1, M, K), _f32(1, K, N))
    return Driver("gemm", f"({M},{K})x({K},{N}) f32 order={order.name}", make)


def _build_drivers() -> list[Driver]:
    ds: list[Driver] = []
    for order in (Dataflow.OS, Dataflow.WS, Dataflow.IS):
        ds.append(_gemm_driver(order, 192, 320, 160))    # ragged tails
        ds.append(_gemm_driver(order, 128, 256, 128))    # exact multiples

    def gemv(pol):
        fn = lambda a, b: registry.get("gemv")(a, b, pol, jnp.float32)
        return fn, (_f32(1, 4, 768), _f32(1, 768, 1280))
    ds.append(Driver("gemv", "(4,768)x(768,1280) f32", gemv))

    def zg(pol):
        pol = dataclasses.replace(pol, block=(128, 128, 128))
        fn = lambda a, b: registry.get("zero_gate")(a, b, pol, jnp.float32)
        return fn, (_f32(1, 192, 320), _f32(1, 320, 160))
    ds.append(Driver("zero_gate", "(192,320)x(320,160) f32", zg))

    for stride in (1, 2):
        def conv(pol, stride=stride):
            fn = lambda x, w: registry.get("conv2d")(
                x, w, pol, (stride, stride), ((1, 1), (1, 1)), 1, jnp.float32)
            return fn, (_f32(1, 28, 28, 64), _f32(3, 3, 64, 96))
        ds.append(Driver("conv2d", f"(1,28,28,64)x(3,3,64,96) s{stride}",
                         conv))

        def dw(pol, stride=stride):
            fn = lambda x, w: registry.get("dwconv")(
                x, w, pol, (stride, stride), ((1, 1), (1, 1)), jnp.float32)
            return fn, (_f32(1, 28, 28, 64), _f32(3, 3, 64))
        ds.append(Driver("dwconv", f"(1,28,28,64)x(3,3,64) s{stride}", dw))

        def qconv(pol, stride=stride):
            fn = lambda x, w, s: registry.get("quant_conv2d")(
                x, w, s, pol, (stride, stride), ((1, 1), (1, 1)),
                jnp.float32)
            return fn, (_i8(1, 28, 28, 64), _i8(3, 3, 64, 96), _f32(96))
        ds.append(Driver("quant_conv2d",
                         f"int8 (1,28,28,64)x(3,3,64,96) s{stride}", qconv))

    def qg_full(pol):
        fn = lambda a, b, s: registry.get("quant_gemm")(
            a, b, s, pol, jnp.float32)
        return fn, (_i8(192, 320), _i8(320, 160), _f32(160))
    ds.append(Driver("quant_gemm", "int8 (192,320)x(320,160)", qg_full))

    def qg_wo(pol):
        fn = lambda a, b, s: registry.get("quant_gemm")(
            a, b, s, pol, jnp.float32)
        return fn, (_f32(192, 320), _i8(320, 160), _f32(160))
    ds.append(Driver("quant_gemm", "weight-only f32x(320,160)", qg_wo))

    def qg_gemv(pol):
        fn = lambda a, b, s: registry.get("quant_gemm")(
            a, b, s, pol, jnp.float32)
        return fn, (_f32(4, 768), _i8(768, 1280), _f32(1280))
    ds.append(Driver("quant_gemm", "weight-only gemv (4,768)", qg_gemv))

    def i4(pol):
        fn = lambda a, b, s: registry.get("int4_gemm")(
            a, b, s, 321, pol, jnp.float32)
        return fn, (_f32(192, 321), _i8(161, 160), _f32(160))
    ds.append(Driver("int4_gemm", "odd-K (192,321) packed(161,160)", i4))

    def i4_gemv(pol):
        fn = lambda a, b, s: registry.get("int4_gemm")(
            a, b, s, 321, pol, jnp.float32)
        return fn, (_f32(4, 321), _i8(161, 160), _f32(160))
    ds.append(Driver("int4_gemm", "odd-K gemv (4,321)", i4_gemv))

    def f8(pol):
        fn = lambda a, b, s: registry.get("fp8_gemm")(
            a, b, s, pol, jnp.float32)
        return fn, (_sds((192, 256), jnp.float8_e4m3fn),
                    _sds((256, 160), jnp.float8_e4m3fn), _f32(160))
    ds.append(Driver("fp8_gemm", "e4m3 (192,256)x(256,160)", f8))

    def xe(pol):
        fn = lambda a, b: registry.get("xla_einsum")("mk,kn->mn", a, b)
        return fn, (_f32(64, 64), _f32(64, 64))
    ds.append(Driver("xla_einsum", "mk,kn->mn f32", xe))

    def xc(pol):
        fn = lambda x, w: registry.get("xla_conv2d")(
            x, w, stride=(1, 1), padding=((1, 1), (1, 1)), groups=1,
            out_dtype=jnp.float32)
        return fn, (_f32(1, 16, 16, 32), _f32(3, 3, 32, 32))
    ds.append(Driver("xla_conv2d", "(1,16,16,32)x(3,3,32,32)", xc))

    def xd(pol):
        fn = lambda x, w: registry.get("xla_dwconv")(
            x, w, stride=(1, 1), padding=((1, 1), (1, 1)),
            out_dtype=jnp.float32)
        return fn, (_f32(1, 16, 16, 32), _f32(3, 3, 32))
    ds.append(Driver("xla_dwconv", "(1,16,16,32)x(3,3,32)", xd))

    return ds


DRIVERS = _build_drivers()


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------


def _trace_policy() -> ExecutionPolicy:
    # force_interpret=True keeps tracing host-independent: the jaxpr still
    # records the full grid_mapping either way
    return ExecutionPolicy(backend="pallas", force_interpret=True)


def check_driver(driver: Driver) -> list[Finding]:
    subject = f"{driver.kind}[{driver.label}]"
    fn, args = driver.make(_trace_policy())
    try:
        jaxpr = jax.make_jaxpr(fn)(*args)
    except Exception as e:                       # noqa: BLE001
        return [error("AXC000", PASS, subject,
                      f"driver failed to trace: {type(e).__name__}: {e}")]
    calls = find_pallas_calls(jaxpr.jaxpr)
    meta = registry.meta(driver.kind)
    if meta.backend == "xla":
        if calls:
            return [error(
                "AXC000", PASS, subject,
                f"kind is declared backend='xla' but traces to "
                f"{len(calls)} pallas_call(s)")]
        return []
    if not calls:
        return [error("AXC000", PASS, subject,
                      "pallas-backed kind traced to zero pallas_calls")]
    out: list[Finding] = []
    for eqn in calls:
        out.extend(check_pallas_eqn(eqn, driver.kind, subject))
    return out


def _probe_accum_policy(kind: str) -> list[Finding]:
    """AXC007: tracing with accum_dtype=bfloat16 must raise
    NotImplementedError on every pallas-backed kind."""
    drivers = [d for d in DRIVERS if d.kind == kind]
    if not drivers:
        return []
    driver = drivers[0]
    pol = dataclasses.replace(_trace_policy(), accum_dtype=jnp.bfloat16)
    fn, args = driver.make(pol)
    try:
        jax.make_jaxpr(fn)(*args)
    except NotImplementedError:
        return []
    except Exception as e:                       # noqa: BLE001
        return [error(
            "AXC007", PASS, f"{kind}[{driver.label}]",
            f"accum_dtype=bfloat16 probe raised {type(e).__name__} "
            "instead of NotImplementedError")]
    return [error(
        "AXC007", PASS, f"{kind}[{driver.label}]",
        "impl traced successfully under policy accum_dtype=bfloat16; the "
        "kernels only implement float32/int32 accumulation, so the policy "
        "knob is being silently ignored")]


def run(kinds: list[str] | None = None) -> list[Finding]:
    """Run the contract checker over the live registry (or a subset)."""
    all_kinds = registry.kinds() if kinds is None else kinds
    findings: list[Finding] = []
    covered = {d.kind for d in DRIVERS}
    for kind in all_kinds:
        if kind not in covered:
            findings.append(error(
                "AXC000", PASS, kind,
                "registered kind has no contract-checker driver; add one "
                "to repro.analysis.contracts.DRIVERS"))
    for driver in DRIVERS:
        if driver.kind not in all_kinds:
            continue
        findings.extend(check_driver(driver))
    for kind in all_kinds:
        if kind in covered and registry.meta(kind).backend == "pallas":
            findings.extend(_probe_accum_policy(kind))
    return findings
