"""Multi-head Latent Attention (DeepSeek-V2/V3).

Prefill: queries via a low-rank bottleneck (q_lora); keys/values via a shared
compressed latent c_kv (kv_lora) plus a single shared RoPE key channel.
Decode: the *absorbed* formulation -- w_kv_b folds into the query/output
projections so attention runs directly against the compressed latent cache
(B, S, kv_lora + rope) instead of expanded K/V.  The cache is ~14x smaller
than GQA at these dims (576 vs 2 * 128 * 128 floats/token... per layer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import axon
from repro.obs import annotate as _ann
from repro.models.layers import (
    Params,
    _dense_init,
    _NEG_INF,
    apply_rope,
    flash_attention,
    init_rmsnorm,
    rmsnorm,
)
from repro.parallel.sharding import constrain
from repro.serve import kvcache as KV


def init_mla(key, cfg, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora, cfg.kv_lora
    dn, dr, dv = cfg.nope_head, cfg.rope_head, cfg.v_head
    ks = jax.random.split(key, 6)
    return {
        "q_a": _dense_init(ks[0], (d, ql), dtype),
        "q_a_norm": init_rmsnorm(ql, dtype),
        "q_b": _dense_init(ks[1], (ql, h * (dn + dr)), dtype),
        "kv_a": _dense_init(ks[2], (d, kvl + dr), dtype),
        "kv_a_norm": init_rmsnorm(kvl, dtype),
        "kv_b": _dense_init(ks[3], (kvl, h * (dn + dv)), dtype),
        "wo": _dense_init(ks[4], (h * dv, d), dtype),
    }


def _project_qkv_latent(p: Params, x: jax.Array, cfg, positions):
    """Shared between prefill and decode: q (nope/rope), latent c, k_pe."""
    B, S, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.nope_head, cfg.rope_head

    q = rmsnorm(p["q_a_norm"], axon.einsum("bsd,dq->bsq", x, p["q_a"]))
    q = axon.einsum("bsq,qe->bse", q, p["q_b"]).reshape(B, S, h, dn + dr)
    q = constrain(q, "batch", None, "model", None)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv = axon.einsum("bsd,de->bse", x, p["kv_a"])
    c = rmsnorm(p["kv_a_norm"], ckv[..., : cfg.kv_lora])
    k_pe = apply_rope(ckv[..., cfg.kv_lora:][:, :, None, :], positions,
                      cfg.rope_theta)                      # (B, S, 1, dr)
    return q_nope, q_pe, c, k_pe


def mla_fwd(p: Params, x: jax.Array, cfg, *, positions,
            exact_causal: bool = False,
            cache: Params | None = None,
            valid: jax.Array | None = None,
            page_table: jax.Array | None = None,
            paged=None) -> tuple[jax.Array, Params | None]:
    B, S, D = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head, cfg.rope_head, cfg.v_head
    kvl = cfg.kv_lora

    q_nope, q_pe, c, k_pe = _project_qkv_latent(p, x, cfg, positions)

    if cache is None:
        kv = axon.einsum("bsc,ce->bse", c, p["kv_b"]).reshape(B, S, h, dn + dv)
        kv = constrain(kv, "batch", None, "model", None)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (B, S, h, dr))],
                            axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = flash_attention(q, k, v, causal=True, exact_causal=exact_causal)
        new_cache = None
    else:
        # absorbed decode / chunked prefill against the compressed cache.
        # ``len`` is per-slot; S >= 1 teacher-forced tokens per step with
        # padded tokens' writes dropped (mode="drop"), so inactive serving
        # lanes cannot pollute live ones.  ``positions`` is (B, S) absolute.
        pos0 = cache["len"]                                   # (B,)
        v_mask = valid if valid is not None else jnp.ones((B, S), bool)
        if paged is not None and "c_pages" in cache:
            # paged latent cache: scatter (quantize) this chunk's rows
            # FIRST -- absorbed attention reads the post-write cache (the
            # ``j <= positions`` mask includes self) -- then gather the
            # per-slot contiguous view back through the page table.
            n_buf = paged.seq_pages(0)                        # MLA: no SWA
            S_c = n_buf * paged.page_size
            paged_cache = dict(cache)
            with _ann.scope("kv_scatter"):
                paged_cache.update(KV.write_seq(cache, "c", page_table, c,
                                                positions, v_mask, paged.fmt))
                paged_cache.update(KV.write_seq(cache, "k_pe", page_table,
                                                k_pe[:, :, 0], positions,
                                                v_mask, paged.fmt))
            with _ann.scope("kv_gather"):
                c_cache = KV.read_seq(paged_cache, "c", page_table, n_buf,
                                      dtype=paged.dtype)
                pe_cache = KV.read_seq(paged_cache, "k_pe", page_table, n_buf,
                                       dtype=paged.dtype)
        else:
            paged_cache = None
            S_c = cache["c"].shape[1]
            wpos = jnp.where(v_mask, positions, S_c)          # OOB -> dropped
            b_idx = jnp.arange(B)[:, None]
            c_cache = cache["c"].at[b_idx, wpos].set(
                c.astype(cache["c"].dtype), mode="drop")
            pe_cache = cache["k_pe"].at[b_idx, wpos].set(
                k_pe[:, :, 0].astype(cache["k_pe"].dtype), mode="drop")
        w_kv = p["kv_b"].reshape(kvl, h, dn + dv)
        w_k, w_v = w_kv[..., :dn], w_kv[..., dn:]
        # fold k_nope projection into q:  (B,S,h,dn) x (kvl,h,dn) -> (B,S,h,kvl)
        # all cache-sized contractions stay in the cache dtype with fp32
        # accumulation -- no fp32 copies of the latent cache.
        q_eff = axon.einsum("bthn,chn->bthc", q_nope, w_k
                           ).astype(c_cache.dtype)
        # absorbed attention is per-head independent against a head-less
        # latent cache: pin the effective queries head-sharded over 'model'
        # so scores/PV stay TP and only the tiny (B,T,h,·) outputs move
        q_eff = constrain(q_eff, "batch", None, "model", None)
        scale = (dn + dr) ** -0.5
        s = (axon.einsum("bthc,bsc->bths", q_eff, c_cache,
                        preferred_element_type=jnp.float32)
             + axon.einsum("bthr,bsr->bths", q_pe.astype(pe_cache.dtype),
                          pe_cache, preferred_element_type=jnp.float32)) * scale
        # cache index == absolute position (full attention, no rolling):
        # per-(slot, token) causal mask over the slot's own written prefix
        mask = jnp.arange(S_c)[None, None, :] <= positions[:, :, None]
        s = jnp.where(mask[:, :, None, :], s, _NEG_INF)
        attn = jax.nn.softmax(s, axis=-1)
        ctx = axon.einsum("bths,bsc->bthc", attn.astype(c_cache.dtype),
                         c_cache, preferred_element_type=jnp.float32)
        out = axon.einsum("bthc,chv->bthv", ctx.astype(w_v.dtype), w_v,
                         preferred_element_type=jnp.float32)
        out = out.astype(x.dtype)
        new_len = pos0 + v_mask.sum(-1).astype(pos0.dtype)
        if paged_cache is not None:
            new_cache = {**paged_cache, "len": new_len}
        else:
            new_cache = {"c": c_cache, "k_pe": pe_cache, "len": new_len}

    out = out.reshape(B, S, h * dv)
    out = axon.einsum("bse,ed->bsd", out, p["wo"])
    return constrain(out, "batch", None, None), new_cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.rope_head), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
