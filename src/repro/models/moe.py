"""Mixture-of-Experts FFN: per-row top-k routing into static capacity buffers.

Design (pjit-friendly, static shapes, no cross-data-shard routing):
  * routing/sort/position bookkeeping is PER BATCH ROW (the GShard "group" =
    one sequence), so the argsort/scatter never crosses the data axis -- the
    only cross-shard movement is the dispatch into the expert-sharded buffer
    (the EP all-to-all), which XLA inserts at the scatter/gather.
  * tokens are processed in chunks of ``cfg.moe_chunk`` along the sequence
    (checkpointed lax.map): the (tokens * top_k, d_model) dispatch tensors
    never exceed one chunk.  First implementation routed *globally* and
    unchunked -- the dry-run measured 484 GiB/device on deepseek-v3
    prefill_32k; this version brings it to chunk-sized buffers.
  * scatter into an (E, capacity) buffer per row; one batched GeMM per
    projection: (B, E, C, D) x (E, D, F); overflow tokens drop (capacity
    factor).

Sharding: 'ep' shards the expert dim over ``model`` (DeepSeek: 256 / 16);
'tp' shards the expert FFN dim over ``model`` (Mixtral: 8 experts don't
divide 16).  Load-balancing aux loss follows Switch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import axon
from repro.models.layers import Params, _dense_init
from repro.obs import annotate as _ann
from repro.parallel.sharding import constrain


def init_moe(key, cfg, dtype=jnp.float32) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),  # router in fp32
        "w_gate": _dense_init(ks[1], (e, d, f), dtype),
        "w_up": _dense_init(ks[2], (e, d, f), dtype),
        "w_down": _dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def _route_chunk(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) one token chunk -> (out (B, S, D), aux scalar)."""
    B, S, D = x.shape
    k, E = cfg.top_k, cfg.n_experts
    Tk = S * k

    with _ann.scope("moe_route"):
        logits = axon.einsum("bsd,de->bse", x.astype(jnp.float32),
                            p["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)            # (B, S, E)
        vals, idx = jax.lax.top_k(probs, k)                # (B, S, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * mean_e(frac tokens -> e) * mean_e(router prob)
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=2)   # (B, S, E)
    frac = sel.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * probs.mean(axis=(0, 1))) / k

    # ---- per-row sort by expert id ------------------------------------
    flat_e = idx.reshape(B, Tk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)      # (B, Tk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_of_slot = order // k
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    pos_in_e = jnp.arange(Tk)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)

    cap = int(math.ceil(Tk / E * cfg.capacity_factor))
    cap = max(4, -(-cap // 4) * 4)
    dest = sorted_e * cap + pos_in_e
    valid = pos_in_e < cap
    dest = jnp.where(valid, dest, E * cap)                 # OOB -> dropped

    gathered = jnp.take_along_axis(x, tok_of_slot[..., None], axis=1)
    # d_model-sharded dispatch tensors: token-dim sharding was tried and
    # REFUTED (XLA resolves the scatter into the expert-sharded buffer by
    # replicate+all-reduce: 39 TiB/step collectives -- §Perf iteration 6a)
    gathered = constrain(gathered, "batch", None, "model")  # (B, Tk, D)

    buf = jnp.zeros((B, E * cap, D), x.dtype)
    buf = buf.at[jnp.arange(B)[:, None], dest].set(gathered, mode="drop")
    buf = buf.reshape(B, E, cap, D)
    spec = ("model", None) if cfg.expert_shard == "ep" else (None, "model")
    buf = constrain(buf, "batch", spec[0], None, None)

    with _ann.scope("moe_experts"):
        g = axon.einsum("becd,edf->becf", buf, p["w_gate"])
        u = axon.einsum("becd,edf->becf", buf, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        h = constrain(h, "batch", spec[0], None, spec[1])
        y = axon.einsum("becf,efd->becd", h,
                        p["w_down"]).reshape(B, E * cap, D)

    # gather back to slots, un-sort, combine with router weights.  The
    # combine mirrors the dispatch decision above: leave the EP all-to-all
    # at this boundary and keep the slot tensors d_model-sharded -- an
    # unconstrained y lets the partitioner replicate the expert buffers
    # through the gather instead
    y = constrain(y, "batch", None, "model")
    y = jnp.concatenate([y, jnp.zeros((B, 1, D), y.dtype)], axis=1)
    slots = jnp.take_along_axis(y, dest[..., None], axis=1)   # sorted order
    inv = jnp.argsort(order, axis=-1, stable=True)
    slots = jnp.take_along_axis(slots, inv[..., None], axis=1)
    slots = constrain(slots, "batch", None, "model")
    out = slots.reshape(B, S, k, D)
    out = (out * vals[..., None].astype(out.dtype)).sum(axis=2)
    return out, aux


def moe_fwd(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    chunk = max(1, min(cfg.moe_chunk, S))
    if S % chunk:
        chunk = S  # ragged fallback: route in one piece

    if chunk == S:
        out, aux = _route_chunk(p, x, cfg)
    else:
        nc = S // chunk
        xs = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def one(xc):
            return _route_chunk(p, xc, cfg)

        outs, auxs = jax.lax.map(one, xs)
        out = outs.transpose(1, 0, 2, 3).reshape(B, S, D)
        aux = auxs.mean()

    if cfg.n_shared_experts:
        from repro.models.layers import mlp_fwd
        out = out + mlp_fwd(p["shared"], x)
    return out, aux
