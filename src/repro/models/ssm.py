"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD, chunked).

Mamba-1 (falcon-mamba): diagonal per-(channel, state) recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t + D x_t
computed with an associative scan over the sequence.

Mamba-2 (zamba2): scalar-per-head A with the SSD chunked algorithm --
quadratic attention-like form inside chunks of length ``chunk``, linear
state recurrence across chunks (lax.scan).  Matches a sequential-scan oracle
in the tests.

Both expose a one-token ``*_step`` for decoding with O(1) state:
(conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import axon
from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm
from repro.parallel.sharding import constrain

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared: causal depthwise conv1d
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, L, C), w: (K, C), b: (C,) -- causal, per-channel."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x_t: (B, C); conv_state: (B, K-1, C) of previous inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, K, C)
    out = axon.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out.astype(x_t.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def init_mamba1(key, cfg, dtype=jnp.float32) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = cfg.dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_k, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * n), dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), dtype, scale=dt_rank**-0.5),
        "dt_bias": jnp.zeros((di,), dtype) + jnp.log(jnp.expm1(0.01)).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def _selective_scan(abar: jax.Array, bx: jax.Array) -> jax.Array:
    """h_t = abar_t * h_{t-1} + bx_t via associative scan over axis 1."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    return h


def mamba1_fwd(p: Params, x: jax.Array, cfg) -> jax.Array:
    B, L, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = axon.einsum("bld,de->ble", x, p["in_proj"])
    xz = constrain(xz, "batch", None, "model")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)

    dbc = axon.einsum("bld,de->ble", x_c, p["x_proj"])
    dt, b_ssm, c_ssm = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        axon.einsum("blr,rd->bld", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))               # (B, L, di)
    A = -jnp.exp(p["A_log"])                               # (di, n)

    abar = jnp.exp(dt[..., None] * A)                      # (B, L, di, n)
    bx = (dt * x_c.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[:, :, None, :]           # (B, L, di, n)
    h = _selective_scan(abar, bx)                          # (B, L, di, n)
    y = axon.einsum("bldn,bln->bld", h, c_ssm.astype(jnp.float32))
    y = y + p["D"] * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return constrain(axon.einsum("bld,de->ble", y, p["out_proj"]),
                     "batch", None, None)


def init_mamba1_cache(cfg, batch: int, dtype=jnp.float32) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.conv_k - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba1_step(p: Params, x: jax.Array, cache: Params, cfg
                ) -> tuple[jax.Array, Params]:
    """x: (B, 1, D) single token."""
    B = x.shape[0]
    n = cfg.ssm_state
    xz = axon.einsum("bd,de->be", x[:, 0], p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = conv1d_step(x_in, cache["conv"], p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    dbc = axon.einsum("bd,de->be", x_c, p["x_proj"])
    dt, b_ssm, c_ssm = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        axon.einsum("br,rd->bd", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                # (B, di)
    A = -jnp.exp(p["A_log"])
    abar = jnp.exp(dt[..., None] * A)                      # (B, di, n)
    bx = (dt * x_c.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[:, None, :]
    h = abar * cache["ssm"] + bx
    y = axon.einsum("bdn,bn->bd", h, c_ssm.astype(jnp.float32))
    y = y + p["D"] * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = axon.einsum("bd,de->be", y, p["out_proj"])[:, None]
    return out, {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg, dtype=jnp.float32) -> Params:
    """Projections are SPLIT per output stream (z, x, B, C, dt) instead of
    one fused in_proj: the fused layout slices the TP-sharded feature dim at
    non-shard-aligned boundaries, forcing a reshard every layer (measured:
    the dominant all-gather source on the zamba2 train cell).  Splitting is
    mathematically identical (depthwise conv is per-channel)."""
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    ks = jax.random.split(key, 8)
    return {
        "in_z": _dense_init(ks[0], (d, di), dtype),
        "in_x": _dense_init(ks[1], (d, di), dtype),
        "in_b": _dense_init(ks[2], (d, n), dtype),
        "in_c": _dense_init(ks[3], (d, n), dtype),
        "in_dt": _dense_init(ks[4], (d, nh), dtype),
        "conv_x_w": _dense_init(ks[5], (cfg.conv_k, di), dtype, scale=0.5),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_b_w": _dense_init(ks[6], (cfg.conv_k, n), dtype, scale=0.5),
        "conv_b_b": jnp.zeros((n,), dtype),
        "conv_c_w": _dense_init(ks[7], (cfg.conv_k, n), dtype, scale=0.5),
        "conv_c_b": jnp.zeros((n,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),            # A = -exp(0) = -1 init
        "D": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": _dense_init(ks[2], (di, d), dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L) -> (..., L, L) with S[i, j] = sum_{j < k <= i} a_k (i >= j)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, d, _NEG_INF)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, b: jax.Array,
                c: jax.Array, *, chunk: int = 64,
                init_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD with chunked computation.

    x: (B, L, H, P); dt: (B, L, H); A: (H,); b, c: (B, L, N) (1 group).
    Returns (y: (B, L, H, P), final_state: (B, H, P, N)).
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    a = dtc * A                                            # (B, nc, l, H)
    a_t = a.transpose(0, 1, 3, 2)                          # (B, nc, H, l)
    a_cum = jnp.cumsum(a_t, axis=-1)                       # (B, nc, H, l)
    xdt = xc * dtc[..., None]                              # (B, nc, l, H, P)

    # intra-chunk (quadratic within chunk)
    L_mat = jnp.exp(_segsum(a_t))                          # (B, nc, H, l, l)
    y_diag = axon.einsum("bcin,bcjn,bchij,bcjhp->bcihp", cc, bc, L_mat, xdt)

    # per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)        # (B, nc, H, l)
    states = axon.einsum("bcjn,bchj,bcjhp->bchpn", bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                  # (B, nc, H)
    s0 = jnp.zeros((B, H, P, N), y_diag.dtype) if init_state is None \
        else init_state

    def chunk_step(state, inp):
        st_c, dec_c = inp                                  # (B,H,P,N), (B,H)
        prev = state
        state = state * dec_c[..., None, None] + st_c
        return state, prev

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    final, prevs = jax.lax.scan(chunk_step, s0, xs)
    prev_states = prevs.transpose(1, 0, 2, 3, 4)           # (B, nc, H, P, N)

    state_decay_out = jnp.exp(a_cum)                       # (B, nc, H, l)
    y_off = axon.einsum("bcin,bchpn,bchi->bcihp", cc, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(B, nc * chunk, H, P)
    return y[:, :L], final


def mamba2_fwd(p: Params, x: jax.Array, cfg) -> jax.Array:
    B, L, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    ph = cfg.mamba_headdim

    z = constrain(axon.einsum("bld,de->ble", x, p["in_z"]),
                  "batch", None, "model")
    x_in = constrain(axon.einsum("bld,de->ble", x, p["in_x"]),
                     "batch", None, "model")
    b_ssm = axon.einsum("bld,de->ble", x, p["in_b"])        # (B, L, n): small
    c_ssm = axon.einsum("bld,de->ble", x, p["in_c"])
    dt = axon.einsum("bld,de->ble", x, p["in_dt"])

    x_in = jax.nn.silu(causal_conv1d(x_in, p["conv_x_w"], p["conv_x_b"])
                       .astype(jnp.float32)).astype(x.dtype)
    x_in = constrain(x_in, "batch", None, "model")
    b_ssm = jax.nn.silu(causal_conv1d(b_ssm, p["conv_b_w"], p["conv_b_b"])
                        .astype(jnp.float32)).astype(x.dtype)
    c_ssm = jax.nn.silu(causal_conv1d(c_ssm, p["conv_c_w"], p["conv_c_b"])
                        .astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, nh)
    A = -jnp.exp(p["A_log"])                               # (nh,)

    xh = x_in.reshape(B, L, nh, ph).astype(jnp.float32)
    xh = constrain(xh, "batch", None, "model", None)
    y, _ = ssd_chunked(xh, dt, A, b_ssm.astype(jnp.float32),
                       c_ssm.astype(jnp.float32), chunk=cfg.ssd_chunk)
    y = y + p["D"][None, None, :, None] * xh
    y = constrain(y, "batch", None, "model", None)
    y = y.reshape(B, L, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32)))
    # gated RMSNorm: reduction over the sharded di axis -> XLA psums the
    # scalar sums; the activation itself stays sharded
    y = rmsnorm(p["norm"], y.astype(x.dtype))
    y = constrain(y, "batch", None, "model")
    return constrain(axon.einsum("bld,de->ble", y, p["out_proj"]),
                     "batch", None, None)


def init_mamba2_cache(cfg, batch: int, dtype=jnp.float32) -> Params:
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    return {
        "conv_x": jnp.zeros((batch, cfg.conv_k - 1, di), dtype),
        "conv_b": jnp.zeros((batch, cfg.conv_k - 1, n), dtype),
        "conv_c": jnp.zeros((batch, cfg.conv_k - 1, n), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.mamba_headdim, n), jnp.float32),
    }


def mamba2_step(p: Params, x: jax.Array, cache: Params, cfg
                ) -> tuple[jax.Array, Params]:
    B = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.mamba_headdim
    ph = cfg.mamba_headdim

    xt = x[:, 0]
    z = axon.einsum("bd,de->be", xt, p["in_z"])
    x_in = axon.einsum("bd,de->be", xt, p["in_x"])
    b_ssm = axon.einsum("bd,de->be", xt, p["in_b"])
    c_ssm = axon.einsum("bd,de->be", xt, p["in_c"])
    dt = axon.einsum("bd,de->be", xt, p["in_dt"])

    x_in, conv_x = conv1d_step(x_in, cache["conv_x"], p["conv_x_w"],
                               p["conv_x_b"])
    b_ssm, conv_b = conv1d_step(b_ssm, cache["conv_b"], p["conv_b_w"],
                                p["conv_b_b"])
    c_ssm, conv_c = conv1d_step(c_ssm, cache["conv_c"], p["conv_c_w"],
                                p["conv_c_b"])
    x_in = jax.nn.silu(x_in.astype(jnp.float32)).astype(x.dtype)
    b_ssm = jax.nn.silu(b_ssm.astype(jnp.float32)).astype(x.dtype)
    c_ssm = jax.nn.silu(c_ssm.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                  # (B, nh)
    xh = x_in.reshape(B, nh, ph).astype(jnp.float32)
    db = dt[..., None, None] * b_ssm.astype(jnp.float32)[:, None, None, :]
    h = cache["ssm"] * dec[..., None, None] + db * xh[..., None]
    y = axon.einsum("bhpn,bn->bhp", h, c_ssm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype)[:, None])[:, 0]
    out = axon.einsum("bd,de->be", y, p["out_proj"])[:, None]
    return out, {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
                 "ssm": h}
