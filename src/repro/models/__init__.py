"""Model substrate: layers, SSM blocks, MoE, and the block-spec transformer."""
