"""Core layers: RMSNorm, RoPE, GQA/SWA attention (flash-style), SwiGLU MLP.

Everything is pure-functional: ``init_*`` builds parameter pytrees (plain
dicts), ``*_fwd`` consumes them.  Softmax statistics and normalizations run
in fp32 regardless of the compute dtype.  Sharding constraints use the
divisibility-guarded helpers in ``repro.parallel.sharding`` so one code path
serves every architecture and mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import axon
from repro.kernels.flash_attention import int8_flash_attention_fwd
from repro.obs import annotate as _ann
from repro.parallel.sharding import constrain, constrain_priority
from repro.serve import kvcache as KV

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh) rotated pairwise; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention (prefill) + cached decode
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def flash_attention(
    q: jax.Array,                 # (B, Sq, H, dh)
    k: jax.Array,                 # (B, Skv, KvH, dh)
    v: jax.Array,                 # (B, Skv, KvH, dv)
    *,
    causal: bool = True,
    window: int = 0,              # 0 = full; >0 = sliding window
    q_offset: int = 0,            # absolute position of q[0] (for caches)
    block_q: int = 512,
    block_kv: int = 1024,
    exact_causal: bool = False,   # python-loop q chunks w/ static kv extents
) -> jax.Array:
    """Blockwise-softmax attention with online max/denominator (fp32 stats).

    ``exact_causal`` unrolls the q-chunk loop so each chunk only visits the
    kv blocks its causal band touches -- exact causal FLOPs at the price of a
    larger HLO (a §Perf lever); the default single-scan version masks instead.
    """
    B, Sq, H, dh = q.shape
    Skv, KvH, dv = k.shape[1], k.shape[2], v.shape[-1]
    rep = H // KvH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)

    q_pad = (-Sq) % bq
    kv_pad = (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    nq = qp.shape[1] // bq
    nkv = kp.shape[1] // bkv

    scale = dh ** -0.5
    qp = (qp.astype(jnp.float32) * scale).astype(q.dtype)
    # (nq, B, bq, KvH, rep, dh)
    qs = qp.reshape(B, nq, bq, KvH, rep, dh).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, nkv, bkv, KvH, dh).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nkv, bkv, KvH, dv).transpose(1, 0, 2, 3, 4)

    def run_chunk(qi, off, k_blocks, v_blocks, kv_block_ids):
        """Online-softmax over the given kv blocks for one q chunk."""
        m0 = jnp.full((B, bq, KvH, rep), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, bq, KvH, rep), jnp.float32)
        a0 = jnp.zeros((B, bq, KvH, rep, dv), jnp.float32)

        # FlashAttention-style backward: rematerialize the block probability
        # matrix instead of saving it -- without this the scan backward
        # stacks p for every (q, kv) block pair = the full S x S attention
        # matrix in fp32 (measured 21 GiB/device at 4k seq on the dry-run).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            # bf16 x bf16 -> fp32 accumulation (preferred_element_type):
            # never materialize fp32 copies of K/V blocks.
            s = axon.einsum("bqgrd,bkgd->bqgrk", qi, kj,
                           preferred_element_type=jnp.float32)
            q_idx = q_offset + off + jnp.arange(bq)
            kv_idx = j * bkv + jnp.arange(bkv)
            mask = kv_idx[None, :] < Skv
            if causal:
                mask = mask & (kv_idx[None, :] <= q_idx[:, None])
            if window:
                mask = mask & (kv_idx[None, :] > q_idx[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + axon.einsum(
                "bqgrk,bkgd->bqgrd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_blocks, v_blocks, kv_block_ids))
        return acc / jnp.maximum(l[..., None], 1e-30)

    if exact_causal:
        # Unrolled q chunks: each visits only the kv blocks in its causal
        # (or banded) extent -- exact attention FLOPs, larger HLO.
        outs = []
        for i in range(nq):
            hi = min(nkv, -(-(q_offset + (i + 1) * bq) // bkv)) if causal else nkv
            lo = max(0, (q_offset + i * bq - window + 1) // bkv) if window else 0
            out_i = run_chunk(qs[i], i * bq, ks[lo:hi], vs[lo:hi],
                              jnp.arange(lo, hi))
            outs.append(out_i.reshape(B, bq, H, dv))
        out = jnp.concatenate(outs, axis=1)
    else:
        # Single compiled body: scan over q chunks, masked kv sweep inside.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def q_step(_, qi_and_off):
            qi, off = qi_and_off
            out = run_chunk(qi, off, ks, vs, jnp.arange(nkv))
            return None, out.reshape(B, bq, H, dv)

        _, stacked = jax.lax.scan(q_step, None, (qs, jnp.arange(nq) * bq))
        out = stacked.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, dv)
    return out[:, :Sq].astype(q.dtype)


def cached_attention(
    q: jax.Array,          # (B, T, H, dh) chunk queries
    k_old: jax.Array,      # (B, S, KvH, dh) cache contents BEFORE this step
    v_old: jax.Array,      # (B, S, KvH, dv)
    k_new: jax.Array,      # (B, T, KvH, dh) this step's keys (cache dtype)
    v_new: jax.Array,      # (B, T, KvH, dv)
    *,
    q_pos: jax.Array,      # (B, T) absolute position of each chunk token
    k_valid: jax.Array,    # (B, T) live-token mask for the chunk
    start: jax.Array,      # (B,) tokens already in the cache per slot
    window: int = 0,       # 0 = full attention; >0 = rolling cache of S slots
) -> jax.Array:
    """Chunk attention against a per-slot cache plus the in-chunk keys.

    Generalizes single-token decode to T >= 1 teacher-forced tokens per slot
    with *per-slot* lengths.  Attention runs against the cache as it was
    BEFORE this step's writes plus the chunk's own keys, so a rolling (SWA)
    buffer's in-window history is still visible even when the chunk's writes
    will overwrite those slots.  Masks are per-slot absolute-position masks:
    query t of slot b sees cache entries at positions <= q_pos[b, t] (inside
    the sliding window when ``window`` > 0) and earlier valid chunk tokens.
    Padded queries (k_valid False) produce garbage rows the caller discards.

    Under ``ExecutionPolicy(attn_int8=True)`` (kernel backends only) the
    whole computation routes through the int8 flash kernel: Q and the
    cache+chunk K/V quantize per head, QK^T and PV run on int8 operands with
    int32 accumulation (float softmax), and the per-slot masks pass through
    unchanged -- the decode step's KV byte stream at 1 B/elem.
    """
    B, T, H, dh = q.shape
    S, KvH, dv = k_old.shape[1], k_old.shape[2], v_old.shape[-1]
    rep = H // KvH
    scale = dh ** -0.5
    j = jnp.arange(S)
    if window:
        # absolute position held by rolling slot j before this step's writes
        last = start[:, None] - 1                              # (B, 1)
        abs_old = last - ((last - j[None, :]) % S)             # (B, S)
        ok_old = ((abs_old >= 0)[:, None, :]
                  & (abs_old[:, None, :] <= q_pos[:, :, None])
                  & (abs_old[:, None, :] > q_pos[:, :, None] - window))
    else:
        ok_old = ((j[None, :] < start[:, None])[:, None, :]
                  & (j[None, None, :] <= q_pos[:, :, None]))
    ok_new = k_valid[:, None, :] & (q_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        ok_new = ok_new & (q_pos[:, None, :] > q_pos[:, :, None] - window)

    pol = axon.current_policy()
    if pol.attn_int8 and pol.resolved_backend() != "xla":
        mask = jnp.concatenate([ok_old, ok_new], axis=-1)    # (B, T, S + T)
        # zero never-written / stale / padded positions BEFORE quantizing:
        # reset_slots leaves old requests' KV contents in place (the float
        # path only masks scores), and a stale outlier entering the per-head
        # abs-max would coarsen every live token's quantization
        live_old = (abs_old >= 0) if window \
            else (j[None, :] < start[:, None])               # (B, S)
        k_all = jnp.concatenate(
            [jnp.where(live_old[:, :, None, None], k_old, 0),
             jnp.where(k_valid[:, :, None, None], k_new, 0)], axis=1)
        v_all = jnp.concatenate(
            [jnp.where(live_old[:, :, None, None], v_old, 0),
             jnp.where(k_valid[:, :, None, None], v_new, 0)], axis=1)
        # the int8 path must respect the same sharded cache layout as the
        # float path below: Q by (kv-)heads over 'model', and the
        # concatenated cache+chunk K/V pinned to the cache's kv-head shard
        # -- without these the partitioner was free to gather the whole
        # quantized cache to every device before the kernel
        q = constrain_priority(q, 1, [2])
        k_all = constrain_priority(k_all, 1, [2])
        v_all = constrain_priority(v_all, 1, [2])
        out = int8_flash_attention_fwd(
            q.transpose(0, 2, 1, 3),                         # (B, H, T, dh)
            k_all.transpose(0, 2, 1, 3),
            v_all.transpose(0, 2, 1, 3),
            mask=mask, scale=scale,
            block_q=min(128, T), block_kv=min(128, S + T),
            interpret=pol.interpret())
        return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B, T, H, dv)

    qf = ((q.reshape(B, T, KvH, rep, dh).astype(jnp.float32) * scale)
          .astype(k_old.dtype))
    # match the cache layout (kv-heads over 'model' when divisible; with a
    # seq-sharded cache q stays replicated over 'model' and the scores come
    # out S-sharded)
    qf = constrain_priority(qf, 1, [2])
    # keep the cache in its storage dtype; accumulate in fp32 via
    # preferred_element_type (no fp32 copy of the cache is materialized)
    s_old = axon.einsum("btgrd,bsgd->btgrs", qf, k_old,
                        preferred_element_type=jnp.float32)
    s_new = axon.einsum("btgrd,bugd->btgru", qf, k_new,
                        preferred_element_type=jnp.float32)
    s_old = jnp.where(ok_old[:, :, None, None, :], s_old, _NEG_INF)
    s_new = jnp.where(ok_new[:, :, None, None, :], s_new, _NEG_INF)
    p = jax.nn.softmax(jnp.concatenate([s_old, s_new], axis=-1), axis=-1)
    out = (axon.einsum("btgrs,bsgd->btgrd", p[..., :S].astype(v_old.dtype),
                       v_old, preferred_element_type=jnp.float32)
           + axon.einsum("btgru,bugd->btgrd", p[..., S:].astype(v_new.dtype),
                         v_new, preferred_element_type=jnp.float32))
    return out.reshape(B, T, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype=jnp.float32) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh), dtype),
        "wk": _dense_init(ks[1], (d, kv * dh), dtype),
        "wv": _dense_init(ks[2], (d, kv * dh), dtype),
        "wo": _dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def attention_fwd(
    p: Params,
    x: jax.Array,                  # (B, S, D)
    cfg,
    *,
    positions: jax.Array,          # (S,) absolute positions; (B, S) w/ cache
    window: int = 0,
    cache: Params | None = None,   # cached: {"k","v","len"} (len per slot)
    exact_causal: bool = False,
    valid: jax.Array | None = None,  # (B, S) live-token mask (cached path)
    page_table: jax.Array | None = None,  # (B, pages) paged-cache table
    paged=None,                    # kvcache.PagedCacheConfig (static)
) -> tuple[jax.Array, Params | None]:
    B, S, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = axon.einsum("bsd,de->bse", x, p["wq"])
    k = axon.einsum("bsd,de->bse", x, p["wk"])
    v = axon.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, dh)
    k = k.reshape(B, S, kv, dh)
    v = v.reshape(B, S, kv, dh)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = flash_attention(q, k, v, causal=True, window=window,
                              exact_causal=exact_causal)
    elif paged is not None and "k_pages" in cache:
        # paged path: the slot's logical KV sequence lives in pool pages
        # addressed through ``page_table``; reads gather (and dequantize)
        # a contiguous per-slot view, writes scatter (and quantize) this
        # chunk's rows through the same table.  A rolling SWA buffer spans
        # ``seq_pages(window)`` whole pages -- the modulo runs over the
        # page-aligned span so page arithmetic stays uniform; the masks in
        # cached_attention bound visibility to the true window either way.
        pos0 = cache["len"]                                   # (B,)
        n_buf = paged.seq_pages(window)
        size = n_buf * paged.page_size
        v_mask = valid if valid is not None else jnp.ones((B, S), bool)
        with _ann.scope("kv_gather"):
            k_old = KV.read_seq(cache, "k", page_table, n_buf,
                                dtype=paged.dtype)
            v_old = KV.read_seq(cache, "v", page_table, n_buf,
                                dtype=paged.dtype)
        k_in = k.astype(paged.dtype)
        v_in = v.astype(paged.dtype)
        out = cached_attention(q, k_old, v_old, k_in, v_in,
                               q_pos=positions, k_valid=v_mask, start=pos0,
                               window=window)
        idx = positions % size if window else positions       # (B, S) logical
        new_cache = dict(cache)
        with _ann.scope("kv_scatter"):
            new_cache.update(KV.write_seq(cache, "k", page_table, k_in, idx,
                                          v_mask, paged.fmt))
            new_cache.update(KV.write_seq(cache, "v", page_table, v_in, idx,
                                          v_mask, paged.fmt))
        new_cache["len"] = pos0 + v_mask.sum(-1).astype(pos0.dtype)
    else:
        # slot-cached path: decode (S=1) or a teacher-forced prefill chunk.
        # ``len`` is per-slot; writes for padded tokens are dropped so
        # inactive serving lanes cannot pollute live ones.
        pos0 = cache["len"]                                   # (B,)
        size = cache["k"].shape[1]
        v_mask = valid if valid is not None else jnp.ones((B, S), bool)
        # match the cache layout before the insert so the scatter never
        # triggers a full cache reshard
        k_in = constrain_priority(k.astype(cache["k"].dtype), 1, [2])
        v_in = constrain_priority(v.astype(cache["v"].dtype), 1, [2])
        out = cached_attention(q, cache["k"], cache["v"], k_in, v_in,
                               q_pos=positions, k_valid=v_mask, start=pos0,
                               window=window)
        slot = positions % size if window else positions      # (B, S)
        slot = jnp.where(v_mask, slot, size)                  # OOB -> dropped
        b_idx = jnp.arange(B)[:, None]
        k_cache = cache["k"].at[b_idx, slot].set(k_in, mode="drop")
        v_cache = cache["v"].at[b_idx, slot].set(v_in, mode="drop")
        new_cache = {"k": k_cache, "v": v_cache,
                     "len": pos0 + v_mask.sum(-1).astype(pos0.dtype)}

    out = out.reshape(B, S, h * dh)
    out = axon.einsum("bse,ed->bsd", out, p["wo"])
    return constrain(out, "batch", None, None), new_cache


def init_attention_cache(cfg, batch: int, max_len: int, *, window: int = 0,
                         dtype=jnp.bfloat16) -> Params:
    size = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv, cfg.d_head), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f), dtype),
        "w_up": _dense_init(ks[1], (d, f), dtype),
        "w_down": _dense_init(ks[2], (f, d), dtype),
    }


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    g = axon.einsum("bsd,df->bsf", x, p["w_gate"])
    u = axon.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "batch", None, "model")
    return constrain(axon.einsum("bsf,fd->bsd", h, p["w_down"]),
                     "batch", None, None)
