"""Core layers: RMSNorm, RoPE, GQA/SWA attention (flash-style), SwiGLU MLP.

Everything is pure-functional: ``init_*`` builds parameter pytrees (plain
dicts), ``*_fwd`` consumes them.  Softmax statistics and normalizations run
in fp32 regardless of the compute dtype.  Sharding constraints use the
divisibility-guarded helpers in ``repro.parallel.sharding`` so one code path
serves every architecture and mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import axon
from repro.parallel.sharding import constrain, constrain_priority

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh) rotated pairwise; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention (prefill) + cached decode
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def flash_attention(
    q: jax.Array,                 # (B, Sq, H, dh)
    k: jax.Array,                 # (B, Skv, KvH, dh)
    v: jax.Array,                 # (B, Skv, KvH, dv)
    *,
    causal: bool = True,
    window: int = 0,              # 0 = full; >0 = sliding window
    q_offset: int = 0,            # absolute position of q[0] (for caches)
    block_q: int = 512,
    block_kv: int = 1024,
    exact_causal: bool = False,   # python-loop q chunks w/ static kv extents
) -> jax.Array:
    """Blockwise-softmax attention with online max/denominator (fp32 stats).

    ``exact_causal`` unrolls the q-chunk loop so each chunk only visits the
    kv blocks its causal band touches -- exact causal FLOPs at the price of a
    larger HLO (a §Perf lever); the default single-scan version masks instead.
    """
    B, Sq, H, dh = q.shape
    Skv, KvH, dv = k.shape[1], k.shape[2], v.shape[-1]
    rep = H // KvH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)

    q_pad = (-Sq) % bq
    kv_pad = (-Skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    nq = qp.shape[1] // bq
    nkv = kp.shape[1] // bkv

    scale = dh ** -0.5
    qp = (qp.astype(jnp.float32) * scale).astype(q.dtype)
    # (nq, B, bq, KvH, rep, dh)
    qs = qp.reshape(B, nq, bq, KvH, rep, dh).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, nkv, bkv, KvH, dh).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nkv, bkv, KvH, dv).transpose(1, 0, 2, 3, 4)

    def run_chunk(qi, off, k_blocks, v_blocks, kv_block_ids):
        """Online-softmax over the given kv blocks for one q chunk."""
        m0 = jnp.full((B, bq, KvH, rep), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, bq, KvH, rep), jnp.float32)
        a0 = jnp.zeros((B, bq, KvH, rep, dv), jnp.float32)

        # FlashAttention-style backward: rematerialize the block probability
        # matrix instead of saving it -- without this the scan backward
        # stacks p for every (q, kv) block pair = the full S x S attention
        # matrix in fp32 (measured 21 GiB/device at 4k seq on the dry-run).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            # bf16 x bf16 -> fp32 accumulation (preferred_element_type):
            # never materialize fp32 copies of K/V blocks.
            s = axon.einsum("bqgrd,bkgd->bqgrk", qi, kj,
                           preferred_element_type=jnp.float32)
            q_idx = q_offset + off + jnp.arange(bq)
            kv_idx = j * bkv + jnp.arange(bkv)
            mask = kv_idx[None, :] < Skv
            if causal:
                mask = mask & (kv_idx[None, :] <= q_idx[:, None])
            if window:
                mask = mask & (kv_idx[None, :] > q_idx[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + axon.einsum(
                "bqgrk,bkgd->bqgrd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_blocks, v_blocks, kv_block_ids))
        return acc / jnp.maximum(l[..., None], 1e-30)

    if exact_causal:
        # Unrolled q chunks: each visits only the kv blocks in its causal
        # (or banded) extent -- exact attention FLOPs, larger HLO.
        outs = []
        for i in range(nq):
            hi = min(nkv, -(-(q_offset + (i + 1) * bq) // bkv)) if causal else nkv
            lo = max(0, (q_offset + i * bq - window + 1) // bkv) if window else 0
            out_i = run_chunk(qs[i], i * bq, ks[lo:hi], vs[lo:hi],
                              jnp.arange(lo, hi))
            outs.append(out_i.reshape(B, bq, H, dv))
        out = jnp.concatenate(outs, axis=1)
    else:
        # Single compiled body: scan over q chunks, masked kv sweep inside.
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def q_step(_, qi_and_off):
            qi, off = qi_and_off
            out = run_chunk(qi, off, ks, vs, jnp.arange(nkv))
            return None, out.reshape(B, bq, H, dv)

        _, stacked = jax.lax.scan(q_step, None, (qs, jnp.arange(nq) * bq))
        out = stacked.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, dv)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,          # (B, 1, H, dh)
    k_cache: jax.Array,    # (B, S, KvH, dh)
    v_cache: jax.Array,    # (B, S, KvH, dv)
    cache_len: jax.Array,  # () current valid length (positions < cache_len)
    *,
    window: int = 0,
    rolling: bool = False,
) -> jax.Array:
    """Single-token attention over a cache.

    ``rolling=True``: the cache is a circular buffer of the last ``S`` tokens
    (SWA) -- every written slot is in-window by construction, so masking is
    just slot validity.  Otherwise slots are absolute positions.
    """
    B, _, H, dh = q.shape
    S, KvH, dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[-1]
    rep = H // KvH
    scale = dh ** -0.5
    qf = ((q.reshape(B, KvH, rep, dh).astype(jnp.float32) * scale)
          .astype(k_cache.dtype))
    # match the cache layout (kv-heads over 'model' when divisible; with a
    # seq-sharded cache q stays replicated over 'model' and the scores come
    # out S-sharded)
    qf = constrain_priority(qf, 1, [1])
    # keep the cache in its storage dtype; accumulate in fp32 via
    # preferred_element_type (no fp32 copy of the cache is materialized)
    s = axon.einsum("bgrd,bkgd->bgrk", qf, k_cache,
                   preferred_element_type=jnp.float32)
    kv_idx = jnp.arange(S)
    mask = kv_idx < cache_len
    if window and not rolling:
        mask = mask & (kv_idx >= cache_len - window)
    s = jnp.where(mask[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = axon.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype=jnp.float32) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh), dtype),
        "wk": _dense_init(ks[1], (d, kv * dh), dtype),
        "wv": _dense_init(ks[2], (d, kv * dh), dtype),
        "wo": _dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def attention_fwd(
    p: Params,
    x: jax.Array,                  # (B, S, D)
    cfg,
    *,
    positions: jax.Array,          # (S,) absolute positions
    window: int = 0,
    cache: Params | None = None,   # decode: {"k","v","len"}
    exact_causal: bool = False,
) -> tuple[jax.Array, Params | None]:
    B, S, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = axon.einsum("bsd,de->bse", x, p["wq"])
    k = axon.einsum("bsd,de->bse", x, p["wk"])
    v = axon.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, dh)
    k = k.reshape(B, S, kv, dh)
    v = v.reshape(B, S, kv, dh)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = flash_attention(q, k, v, causal=True, window=window,
                              exact_causal=exact_causal)
    else:
        # single-token decode: insert into the (rolling, if SWA) cache, attend
        pos = cache["len"]
        size = cache["k"].shape[1]
        slot = pos % size if window else pos
        # match the cache layout before the insert so the
        # dynamic-update-slice never triggers a full cache reshard
        k_in = constrain_priority(k.astype(cache["k"].dtype), 1, [2])
        v_in = constrain_priority(v.astype(cache["v"].dtype), 1, [2])
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k_in, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v_in, (0, slot, 0, 0))
        out = decode_attention(q, k_cache, v_cache, pos + 1,
                               window=window, rolling=bool(window))
        new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}

    out = out.reshape(B, S, h * dh)
    out = axon.einsum("bse,ed->bsd", out, p["wo"])
    return constrain(out, "batch", None, None), new_cache


def init_attention_cache(cfg, batch: int, max_len: int, *, window: int = 0,
                         dtype=jnp.bfloat16) -> Params:
    size = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv, cfg.d_head), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f), dtype),
        "w_up": _dense_init(ks[1], (d, f), dtype),
        "w_down": _dense_init(ks[2], (f, d), dtype),
    }


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    g = axon.einsum("bsd,df->bsf", x, p["w_gate"])
    u = axon.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "batch", None, "model")
    return constrain(axon.einsum("bsf,fd->bsd", h, p["w_down"]),
                     "batch", None, None)
