"""Block-spec decoder LM covering all ten assigned architectures.

A model is a sequence of *stages*; each stage is a homogeneous run of layers
executed with ``lax.scan`` over stacked parameters (HLO size independent of
depth) and rematerialized per layer.  Stage kinds:

  dense   : [attn (gqa|mla, optional SWA)] + SwiGLU MLP
  moe     : attn + MoE FFN (optional shared experts)
  mamba1  : Mamba-1 mixer
  mamba2  : Mamba-2 (SSD) mixer
  hybrid  : mamba2 stack with a weight-shared attn+MLP block applied every
            ``shared_attn_every`` layers (Zamba2 pattern)

Frontends ('audio', 'vlm') consume precomputed embeddings per the brief.
Decode uses per-layer caches stacked along the scan dimension.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import axon
from repro.configs.base import ModelConfig, StageCfg
from repro.models import layers as L
from repro.obs import annotate as _ann
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.parallel.sharding import constrain
from repro.serve import kvcache as KV

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, stage: StageCfg) -> Params:
    dtype = cfg.pdtype
    ks = jax.random.split(key, 4)
    if stage.block in ("dense", "moe"):
        p = {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
             "ln2": L.init_rmsnorm(cfg.d_model, dtype)}
        p["attn"] = (MLA.init_mla(ks[0], cfg, dtype) if stage.attn == "mla"
                     else L.init_attention(ks[0], cfg, dtype))
        p["ffn"] = (MOE.init_moe(ks[1], cfg, dtype) if stage.block == "moe"
                    else L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype))
        return p
    if stage.block == "mamba1":
        return {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
                "mixer": SSM.init_mamba1(ks[0], cfg, dtype)}
    if stage.block in ("mamba2", "hybrid"):
        return {"ln1": L.init_rmsnorm(cfg.d_model, dtype),
                "mixer": SSM.init_mamba2(ks[0], cfg, dtype)}
    raise ValueError(stage.block)


def init_shared_attn(key, cfg: ModelConfig) -> Params:
    """Weight-shared attention+MLP block for hybrid stages (Zamba2)."""
    dtype = cfg.pdtype
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _attn_apply(p, h, cfg, stage, positions, cache, exact_causal, valid=None,
                page_table=None, paged=None):
    if stage.attn == "mla":
        return MLA.mla_fwd(p, h, cfg, positions=positions,
                           exact_causal=exact_causal, cache=cache,
                           valid=valid, page_table=page_table, paged=paged)
    return L.attention_fwd(p, h, cfg, positions=positions,
                           window=stage.window, cache=cache,
                           exact_causal=exact_causal, valid=valid,
                           page_table=page_table, paged=paged)


def block_fwd(p: Params, x: jax.Array, cfg: ModelConfig, stage: StageCfg, *,
              positions, cache=None, exact_causal=False, valid=None,
              page_table=None, paged=None):
    """-> (x, new_cache, aux_loss).

    With ``cache`` the block consumes S >= 1 teacher-forced tokens per slot
    (S=1: plain decode; S>1: a prefill chunk).  ``valid`` (B, S) marks live
    tokens -- padded tokens neither write the KV caches nor advance the SSM
    state, so ragged prompts across slots stay isolated.
    """
    aux = jnp.zeros((), jnp.float32)
    if stage.block in ("dense", "moe"):
        with _ann.scope("attention"):
            h = L.rmsnorm(p["ln1"], x)
            a, new_attn_cache = _attn_apply(p["attn"], h, cfg, stage, positions,
                                            None if cache is None else cache["attn"],
                                            exact_causal, valid,
                                            page_table, paged)
            x = x + a
        if stage.block == "moe":
            with _ann.scope("moe"):
                h = L.rmsnorm(p["ln2"], x)
                f, aux = MOE.moe_fwd(p["ffn"], h, cfg)
        else:
            with _ann.scope("mlp"):
                h = L.rmsnorm(p["ln2"], x)
                f = L.mlp_fwd(p["ffn"], h)
        x = x + f
        new_cache = None if cache is None else {"attn": new_attn_cache}
        return x, new_cache, aux

    # ssm blocks
    with _ann.scope("ssm"):
        return _ssm_block(p, x, aux, cfg, stage, cache, valid)


def _ssm_block(p, x, aux, cfg, stage, cache, valid):
    h = L.rmsnorm(p["ln1"], x)
    fwd_fn = SSM.mamba1_fwd if stage.block == "mamba1" else SSM.mamba2_fwd
    step_fn = SSM.mamba1_step if stage.block == "mamba1" else SSM.mamba2_step
    if cache is None:
        y = fwd_fn(p["mixer"], h, cfg)
        new_cache = None
    elif h.shape[1] == 1 and valid is None:
        y, new_ssm = step_fn(p["mixer"], h, cache["ssm"], cfg)
        new_cache = {"ssm": new_ssm}
    else:
        # chunked teacher-forcing: the recurrent state advances token by
        # token inside one compiled step, gated so padded tokens leave the
        # state untouched
        v_mask = valid if valid is not None else jnp.ones(h.shape[:2], bool)

        def tok(state, inp):
            ht, vt = inp                                   # (B, D), (B,)
            yt, new_state = step_fn(p["mixer"], ht[:, None], state, cfg)
            gated = jax.tree.map(
                lambda n, o: jnp.where(
                    vt.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                new_state, state)
            return gated, yt[:, 0]

        new_ssm, ys = jax.lax.scan(
            tok, cache["ssm"], (h.transpose(1, 0, 2), v_mask.T))
        y = ys.transpose(1, 0, 2)
        new_cache = {"ssm": new_ssm}
    return x + y, new_cache, aux


def shared_attn_fwd(p: Params, x, cfg, positions, cache, exact_causal,
                    valid=None):
    h = L.rmsnorm(p["ln1"], x)
    stage = StageCfg(n_layers=1, block="dense", attn="gqa")
    a, new_cache = L.attention_fwd(p["attn"], h, cfg, positions=positions,
                                   cache=cache, exact_causal=exact_causal,
                                   valid=valid)
    x = x + a
    x = x + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], x))
    return x, new_cache


# ---------------------------------------------------------------------------
# stages (scan over stacked layer params)
# ---------------------------------------------------------------------------


def init_stage(key, cfg: ModelConfig, stage: StageCfg) -> Params:
    k_layers, k_shared = jax.random.split(key)
    keys = jax.random.split(k_layers, stage.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, stage))(keys)
    p = {"layers": stacked}
    if stage.shared_attn_every:
        p["shared"] = init_shared_attn(k_shared, cfg)
    return p


def stage_fwd(p: Params, x, cfg: ModelConfig, stage: StageCfg, *,
              positions, exact_causal=False):
    every = stage.shared_attn_every

    def body(carry, inp):
        h, aux = carry
        layer_p, idx = inp
        if every:
            def with_attn(h):
                out, _ = shared_attn_fwd(p["shared"], h, cfg, positions,
                                         None, exact_causal)
                return out
            h = jax.lax.cond(idx % every == 0, with_attn, lambda h: h, h)
        h, _, a = block_fwd(layer_p, h, cfg, stage, positions=positions,
                            exact_causal=exact_causal)
        if cfg.seq_shard:
            # sequence-parallel residual carry: the activation saved by remat
            # between layers is sharded over 'model' on the seq dim
            # (divisibility-guarded; no-op without a mesh or at decode).
            h = constrain(h, "batch", "model", None)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (p["layers"], jnp.arange(stage.n_layers)))
    return x, aux


def stage_decode(p: Params, x, caches, cfg: ModelConfig, stage: StageCfg, *,
                 positions, valid=None, page_table=None, paged=None):
    every = stage.shared_attn_every
    shared_cache = caches.get("shared") if every else None

    def body(carry, inp):
        h, sc = carry
        layer_p, cache, idx = inp
        if every:
            def with_attn(args):
                h, sc = args
                out, new_sc = shared_attn_fwd(p["shared"], h, cfg, positions,
                                              sc, False, valid=valid)
                return out, new_sc
            h, sc = jax.lax.cond(idx % every == 0, with_attn,
                                 lambda a: a, (h, sc))
        h, new_cache, _ = block_fwd(layer_p, h, cfg, stage,
                                    positions=positions, cache=cache,
                                    valid=valid, page_table=page_table,
                                    paged=paged)
        return (h, sc), new_cache

    (x, shared_cache), new_layer_caches = jax.lax.scan(
        body, (x, shared_cache),
        (p["layers"], caches["layers"], jnp.arange(stage.n_layers)))
    new_caches = {"layers": new_layer_caches}
    if every:
        new_caches["shared"] = shared_cache
    return x, new_caches


def init_stage_caches(cfg: ModelConfig, stage: StageCfg, batch: int,
                      max_len: int, dtype=jnp.bfloat16,
                      paged: KV.PagedCacheConfig | None = None) -> Params:
    def one_layer():
        if stage.block in ("dense", "moe"):
            if paged is not None:
                if stage.attn == "mla":
                    feats = {"c": (cfg.kv_lora,), "k_pe": (cfg.rope_head,)}
                    # the latent stays at the cache dtype: MLA's cache IS
                    # the compression (kv_lora + rope_head per token), and
                    # int8 error in c re-expands through the up-projection
                    # into every head's K and V (see init_paged_seq_cache)
                    return {"attn": KV.init_paged_seq_cache(
                        feats, batch, paged, float_names=frozenset({"c"}))}
                feats = {"k": (cfg.n_kv, cfg.d_head),
                         "v": (cfg.n_kv, cfg.d_head)}
                return {"attn": KV.init_paged_seq_cache(feats, batch, paged)}
            if stage.attn == "mla":
                return {"attn": MLA.init_mla_cache(cfg, batch, max_len, dtype)}
            return {"attn": L.init_attention_cache(
                cfg, batch, max_len, window=stage.window, dtype=dtype)}
        if stage.block == "mamba1":
            return {"ssm": SSM.init_mamba1_cache(cfg, batch)}
        return {"ssm": SSM.init_mamba2_cache(cfg, batch)}

    single = one_layer()
    stacked = jax.tree.map(
        lambda a: jnp.zeros((stage.n_layers,) + a.shape, a.dtype), single)
    caches = {"layers": stacked}
    if stage.shared_attn_every:
        caches["shared"] = L.init_attention_cache(cfg, batch, max_len,
                                                  dtype=dtype)
    return caches


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.pdtype
    ks = jax.random.split(key, len(cfg.stages) + 4)
    p: Params = {}
    p["embed"] = (jax.random.normal(ks[0], (cfg.vocab_pad, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype)
    p["stages"] = [init_stage(ks[1 + i], cfg, s)
                   for i, s in enumerate(cfg.stages)]
    p["final_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(ks[-2], (cfg.d_model, cfg.vocab_pad), dtype)
    if cfg.mtp:
        mtp_stage = StageCfg(n_layers=1, block="dense", attn="mla")
        p["mtp"] = {
            "proj": L._dense_init(ks[-1], (2 * cfg.d_model, cfg.d_model), dtype),
            "block": init_block(ks[-1], cfg, mtp_stage),
            "norm": L.init_rmsnorm(cfg.d_model, dtype),
        }
    return p


def _embed_inputs(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    with _ann.scope("embed"):
        if cfg.frontend == "audio":
            x = batch["embeds"].astype(cfg.cdtype)   # stubbed EnCodec frontend
        elif cfg.frontend == "vlm":
            tok = jnp.take(params["embed"], batch["tokens"], axis=0)
            x = jnp.concatenate(
                [batch["pixel_embeds"].astype(tok.dtype), tok], axis=1)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return constrain(x.astype(cfg.cdtype), "batch", None, None)


def forward(params: Params, batch: dict, cfg: ModelConfig, *,
            exact_causal: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """-> (hidden (B, S, D) post-final-norm, aux_loss)."""
    exact_causal = cfg.exact_causal if exact_causal is None else exact_causal
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    for p_s, s in zip(params["stages"], cfg.stages):
        x, a = stage_fwd(p_s, x, cfg, s, positions=positions,
                         exact_causal=exact_causal)
        aux = aux + a
    with _ann.scope("norm"):
        return L.rmsnorm(params["final_norm"], x), aux


def _lm_head(params: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_ce_loss(params: Params, hidden: jax.Array, labels: jax.Array,
                    mask: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Cross-entropy over seq chunks (never materializes (B, S, V) at once)."""
    B, S, D = hidden.shape
    head = _lm_head(params, cfg)
    chunk = min(cfg.loss_chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    pad_mask = jnp.arange(cfg.vocab_pad) >= cfg.vocab    # padded logit columns

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one(args):
        h, y, m = args
        logits = axon.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "model")
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return ((logz - gold) * m).sum()

    hs = hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, chunk).transpose(1, 0, 2)
    totals = jax.lax.map(one, (hs, ys, ms))
    return totals.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig
            ) -> tuple[jax.Array, dict]:
    hidden, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if cfg.frontend == "vlm":
        # hidden covers [patches; text] -- loss only on text positions
        hidden = hidden[:, cfg.n_patches:]
    ce = chunked_ce_loss(params, hidden, labels, mask, cfg)
    metrics = {"ce": ce, "aux": aux}
    loss = ce + cfg.aux_loss_weight * aux
    if cfg.mtp:
        # multi-token prediction: combine h_t with emb(token_{t+1}) and
        # predict token_{t+2} through one extra block (DeepSeek-V3 §MTP).
        emb_next = jnp.take(params["embed"], batch["tokens"][:, 1:], axis=0)
        h_in = jnp.concatenate(
            [hidden[:, :-1], emb_next.astype(hidden.dtype)], axis=-1)
        h_mtp = axon.einsum("bsd,de->bse", h_in, params["mtp"]["proj"])
        positions = jnp.arange(h_mtp.shape[1])
        h_mtp, _, _ = block_fwd(params["mtp"]["block"], h_mtp, cfg,
                                StageCfg(1, "dense", attn="mla"),
                                positions=positions)
        h_mtp = L.rmsnorm(params["mtp"]["norm"], h_mtp)
        mtp_ce = chunked_ce_loss(params, h_mtp, labels[:, 1:], mask[:, 1:], cfg)
        metrics["mtp_ce"] = mtp_ce
        loss = loss + cfg.mtp_weight * mtp_ce
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16,
                paged: KV.PagedCacheConfig | None = None) -> Params:
    caches = {
        "pos": jnp.zeros((batch,), jnp.int32),   # per-slot position counters
        "stages": [init_stage_caches(cfg, s, batch, max_len, dtype, paged)
                   for s in cfg.stages],
    }
    if paged is not None:
        # slot -> physical page table, owned by the host-side PagePool
        # mirror; rides the caches pytree so it is always a step ARGUMENT
        # (a captured table would retrace the step on every admission)
        caches[KV.PAGE_TABLE_KEY] = jnp.zeros(
            (batch, paged.pages_per_slot), jnp.int32)
    return caches


def _head_logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    with _ann.scope("lm_head"):
        logits = axon.einsum("bsd,dv->bsv", x, _lm_head(params, cfg))
        logits = jnp.where(jnp.arange(cfg.vocab_pad) >= cfg.vocab, -1e30,
                           logits.astype(jnp.float32))[..., : cfg.vocab_pad]
        return logits[..., : cfg.vocab]


def decode_step(params: Params, caches: Params, batch: dict,
                cfg: ModelConfig,
                paged: KV.PagedCacheConfig | None = None
                ) -> tuple[jax.Array, Params]:
    """One-token decode: batch['tokens'] (B, 1) (or 'embeds' (B, 1, D))."""
    with _ann.scope("embed"):
        if cfg.frontend == "audio":
            x = batch["embeds"].astype(cfg.cdtype)
        else:
            x = jnp.take(params["embed"], batch["tokens"],
                         axis=0).astype(cfg.cdtype)
        x = constrain(x, "batch", None, None)
    positions = caches["pos"][:, None]                  # (B, 1) per slot
    page_table = caches.get(KV.PAGE_TABLE_KEY)
    new_stage_caches = []
    for p_s, s, c_s in zip(params["stages"], cfg.stages, caches["stages"]):
        x, nc = stage_decode(p_s, x, c_s, cfg, s, positions=positions,
                             page_table=page_table, paged=paged)
        new_stage_caches.append(nc)
    with _ann.scope("norm"):
        x = L.rmsnorm(params["final_norm"], x)
    new_caches = {"pos": caches["pos"] + 1, "stages": new_stage_caches}
    if page_table is not None:
        new_caches[KV.PAGE_TABLE_KEY] = page_table
    return _head_logits(params, x, cfg), new_caches


def prefill_step(params: Params, caches: Params, batch: dict,
                 valid: jax.Array, cfg: ModelConfig,
                 paged: KV.PagedCacheConfig | None = None
                 ) -> tuple[jax.Array, Params]:
    """Teacher-forced chunk step: batch['tokens'] (B, C) (or 'embeds'
    (B, C, D)); ``valid`` (B, C) marks each slot's live tokens and must be a
    left-aligned prefix per row.

    Processes up to C prompt (or feedback) tokens per slot in one fixed-shape
    step -- the prefill GeMMs run batched over the whole chunk instead of
    token-at-a-time.  Padded tokens write nothing (their cache scatters are
    dropped, SSM state updates are gated) and each slot's position counter
    advances by its own valid count, so slots at different phases coexist in
    one batch.  Returns full per-position logits (B, C, vocab); the logits at
    a slot's last valid token are its next-token distribution.

    Chunk width is output-neutral for dense/SSM stages.  MoE capacity
    buffers are sized per routed chunk, so with token dropping enabled
    (finite ``capacity_factor``) WHICH tokens drop can depend on C -- the
    standard capacity-vs-chunking trade of GShard-style MoE serving.
    Batch-of-N vs batch-of-1 identity is unaffected (routing is per row).
    """
    with _ann.scope("embed"):
        if cfg.frontend == "audio":
            x = batch["embeds"].astype(cfg.cdtype)
        else:
            x = jnp.take(params["embed"], batch["tokens"],
                         axis=0).astype(cfg.cdtype)
        x = constrain(x, "batch", None, None)
    valid = valid.astype(bool)
    C = x.shape[1]
    positions = caches["pos"][:, None] + jnp.arange(C)[None, :]   # (B, C)
    page_table = caches.get(KV.PAGE_TABLE_KEY)
    new_stage_caches = []
    for p_s, s, c_s in zip(params["stages"], cfg.stages, caches["stages"]):
        x, nc = stage_decode(p_s, x, c_s, cfg, s, positions=positions,
                             valid=valid, page_table=page_table, paged=paged)
        new_stage_caches.append(nc)
    with _ann.scope("norm"):
        x = L.rmsnorm(params["final_norm"], x)
    new_caches = {
        "pos": caches["pos"] + valid.sum(-1).astype(jnp.int32),
        "stages": new_stage_caches,
    }
    if page_table is not None:
        new_caches[KV.PAGE_TABLE_KEY] = page_table
    return _head_logits(params, x, cfg), new_caches


# attention-content leaves reset_slots leaves in place: with the slot's
# position counter back at 0 they are unreachable (cached_attention masks
# j < start / negative rolling abs positions; MLA masks j <= positions) and
# the next request overwrites them position by position.  Everything else
# (counters, recurrent SSM/conv state, future cache kinds) is zeroed.
_STALE_OK = ("k", "v", "c", "k_pe")


def reset_slots(caches: Params, mask: jax.Array,
                lens: jax.Array | None = None) -> Params:
    """Clear per-slot cache state where ``mask`` (B,) is True.

    Zeroes position counters and SSM/conv state along the slot (batch) axis
    -- leading layer-stack axes are detected from the pytree path -- so a
    freed slot can be re-admitted without leaking the previous request's
    state.  KV/latent contents are NOT rewritten (O(layers * batch) instead
    of a full cache sweep per admission): stale entries are masked out by
    the zeroed counters until overwritten.

    Paged-cache leaves are left untouched entirely: the pool tensors have
    no slot axis (a freed slot's pages return to the host-side allocator)
    and the page table is rewritten by the engine's host mirror.  With
    ``lens`` (B,) given, reset slots' position counters start there instead
    of 0 -- the prefix-cache hit path, where shared pages already hold the
    slot's first ``lens[b]`` tokens."""
    def _clear(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        name = next((n for n in reversed(names) if isinstance(n, str)), None)
        if name == KV.PAGE_TABLE_KEY or (
                isinstance(name, str)
                and name.endswith(KV.PAGED_LEAF_SUFFIXES)):
            return leaf
        if name in _STALE_OK:
            return leaf
        axis = 1 if "layers" in names else 0
        shape = (1,) * axis + (-1,) + (1,) * (leaf.ndim - axis - 1)
        m = mask.reshape(shape)
        if lens is not None and name in ("pos", "len"):
            return jnp.where(m, lens.reshape(shape).astype(leaf.dtype), leaf)
        return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)

    return jax.tree_util.tree_map_with_path(_clear, caches)


def insert_slot(dst: Params, src: Params, slot: jax.Array,
                src_slot: int = 0) -> Params:
    """Hand one lane of a prefill cache off into slot ``slot`` of a decode
    cache (the insert of the prefill/insert/generate split).

    Every leaf of ``src`` is a same-shape-per-slot twin of its ``dst`` leaf
    (the prefill lane runs at the decode engine's own batch width so both
    caches trace identically -- that is what keeps mesh-sharded prefill
    bit-identical to inline serving); the slot (batch) axis is detected
    from the pytree path exactly like :func:`reset_slots`.  ``slot`` is a
    traced scalar, so one jitted insert serves every destination slot
    without retracing, and because the copy includes the position counters
    and recurrent SSM/conv state the destination slot needs no separate
    reset.  Dense caches only: paged pools have no slot axis to insert
    into (their handoff is a page-table rewrite, owned by the host-side
    allocator)."""
    def _ins(path, d, s):
        names = [getattr(k, "key", None) for k in path]
        name = next((n for n in reversed(names) if isinstance(n, str)), None)
        if name == KV.PAGE_TABLE_KEY or (
                isinstance(name, str)
                and name.endswith(KV.PAGED_LEAF_SUFFIXES)):
            raise ValueError(
                "insert_slot is dense-cache only: paged pools have no slot "
                "axis (hand off pages through the page table instead)")
        axis = 1 if "layers" in names else 0
        piece = jax.lax.dynamic_slice_in_dim(s, src_slot, 1, axis)
        return jax.lax.dynamic_update_slice_in_dim(
            d, piece.astype(d.dtype), slot, axis)
    return jax.tree_util.tree_map_with_path(_ins, dst, src)
