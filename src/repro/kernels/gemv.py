"""Memory-bound GEMV Pallas kernel.

GEMV is the paper's 2x showcase (Fig. 14): runtime is dominated by streaming
the weight matrix, so the kernel reads W exactly once (K innermost, output
block resident in VMEM) with wide N blocks to keep the HBM pipe saturated.
This is the TPU mirror of Axon's no-skew, low-fill feeding: the prologue is
one block DMA rather than a pipeline walk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemv_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemv(
    x: jax.Array,            # (K,) or (B, K) small-batch
    w: jax.Array,            # (K, N)
    *,
    block_k: int = 512,
    block_n: int = 1024,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    B, K = x.shape
    K2, N = w.shape
    assert K == K2
    out_dtype = out_dtype or x.dtype
    bk = min(block_k, K)
    bn = min(block_n, N)

    x_p = jnp.pad(x, ((0, 0), (0, (-K) % bk)))
    w_p = jnp.pad(w, ((0, (-K) % bk), (0, (-N) % bn)))
    nk = x_p.shape[1] // bk
    nn = w_p.shape[1] // bn

    out = pl.pallas_call(
        functools.partial(_gemv_kernel, nk=nk),
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((B, bk), lambda n, k: (0, k)),
            pl.BlockSpec((bk, bn), lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        interpret=interpret,
    )(x_p, w_p)
    out = out[:, :N]
    return out[0] if squeeze else out
