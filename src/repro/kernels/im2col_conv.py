"""Implicit-im2col convolution: the TPU-native adaptation of Axon's on-chip
im2col (paper §3.2, Fig. 3b).

The paper's insight: consecutive conv windows share ``n (n - 1)`` of their
``n^2`` elements, so a 2-to-1 MUX between feeder PEs lets the array reuse
each IFMAP element from *on-chip* storage instead of re-streaming it from
memory -- the im2col matrix is never materialized.

TPU mapping: each IFMAP row-tile (halo included) is DMA'd HBM->VMEM exactly
once per (batch, row-tile, cin-block) and every element is then reused
``kh * kw`` times *from VMEM* as the MXU consumes shifted views as GeMM
operands.  HBM sees only the unique IFMAP bytes (plus a ``kh - stride`` row
halo), not the ``kh * kw``-fold im2col expansion -- the same >60 % traffic
reduction the paper measures, achieved with block indexing instead of MUXes.

Halo handling: Pallas block offsets are multiples of the block shape, so an
overlapping read is expressed by passing the *same* input array twice with
adjacent row-block index maps ("two-block halo trick") and concatenating
inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import conv_out_hw, normalize_padding, normalize_stride


def _conv_kernel(x_ref, halo_ref, w_ref, o_ref, acc_ref, *,
                 kh: int, kw: int, sh: int, sw: int, th: int, w_out: int,
                 nci: int):
    ci = pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One VMEM-resident tile covering this row-tile plus its halo.
    tile = jnp.concatenate([x_ref[0], halo_ref[0]], axis=0)  # (2*th*sh, Wp, bci)

    acc = acc_ref[...]
    for dh in range(kh):
        for dw in range(kw):
            # Shifted strided view: rows dh + sh*[0..th), cols dw + sw*[0..w_out)
            view = jax.lax.slice(
                tile,
                (dh, dw, 0),
                (dh + sh * (th - 1) + 1, dw + sw * (w_out - 1) + 1,
                 tile.shape[2]),
                (sh, sw, 1),
            )  # (th, w_out, bci)
            lhs = view.reshape(th * w_out, tile.shape[2])
            acc += jnp.dot(lhs, w_ref[dh, dw],
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == nci - 1)
    def _store():
        o_ref[...] = acc_ref[...].reshape(1, th, w_out, -1).astype(o_ref.dtype)


def im2col_conv(
    x: jax.Array,              # (N, H, W, C_in)
    w: jax.Array,              # (kh, kw, C_in, C_out)
    *,
    stride=1,                  # int or (sh, sw)
    padding=0,                 # int, (ph, pw), or ((pt, pb), (pl, pr))
    block_rows: int = 8,       # output rows per tile (th)
    block_cout: int = 128,
    block_cin: int = 512,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    N, H, W, C_in = x.shape
    kh, kw, C_in2, C_out = w.shape
    assert C_in == C_in2
    sh, sw = normalize_stride(stride)
    (pt, pb), (pleft, pr) = normalize_padding(padding)
    H_out, W_out = conv_out_hw(H, W, kh, kw, (sh, sw), padding)
    if H_out < 1 or W_out < 1:
        raise ValueError(
            f"im2col_conv: zero-area output ({H_out}x{W_out}) for input "
            f"{H}x{W}, kernel {kh}x{kw}, stride ({sh},{sw}), padding "
            f"(({pt},{pb}),({pleft},{pr})); use the XLA reference path "
            "(axon.conv2d routes this automatically)")
    out_dtype = out_dtype or x.dtype

    th = min(block_rows, H_out)
    # tile must cover its own halo: rows needed = (th-1)*sh + kh <= 2*th*sh
    while (th - 1) * sh + kh > 2 * th * sh:
        th += 1
    bco = min(block_cout, C_out)
    bci = min(block_cin, C_in)

    n_h = -(-H_out // th)
    # Pad: spatial conv padding + enough bottom rows that row-block n_h is
    # always a valid (zero) halo block, and W covers the last window.
    h_span = (n_h + 1) * th * sh + kh         # generous zero tail
    w_span = (W_out - 1) * sw + kw
    x_p = jnp.pad(
        x,
        ((0, 0),
         (pt, max(0, h_span - (H + pt))),
         (pleft, max(0, w_span - (W + pleft))),
         (0, (-C_in) % bci)),
    )
    Wp = x_p.shape[2]
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, (-C_in) % bci), (0, (-C_out) % bco)))
    n_co = w_p.shape[3] // bco
    n_ci = w_p.shape[2] // bci

    grid = (N, n_h, n_co, n_ci)  # cin innermost -> IFMAP tile stays resident
    out = pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw, sh=sh, sw=sw, th=th,
                          w_out=W_out, nci=n_ci),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, th * sh, Wp, bci), lambda b, h, co, ci: (b, h, 0, ci)),
            pl.BlockSpec((1, th * sh, Wp, bci),
                         lambda b, h, co, ci: (b, h + 1, 0, ci)),
            pl.BlockSpec((kh, kw, bci, bco), lambda b, h, co, ci: (0, 0, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, th, W_out, bco),
                               lambda b, h, co, ci: (b, h, 0, co)),
        out_shape=jax.ShapeDtypeStruct((N, n_h * th, W_out, n_co * bco), out_dtype),
        scratch_shapes=[pltpu.VMEM((th * W_out, bco), jnp.float32)],
        interpret=interpret,
    )(x_p, x_p, w_p)
    return out[:, :H_out, :, :C_out]


def hbm_traffic_model(x_shape, w_shape, *, stride=1, padding=0,
                      bytes_per_elem=2) -> dict[str, float]:
    """Modeled HBM bytes: this kernel vs a materialized-im2col GeMM.

    Used by the benchmarks to tie the kernel to the paper's Fig. 11 claim.
    """
    N, H, W, C_in = x_shape
    kh, kw, _, C_out = w_shape
    sh, sw = normalize_stride(stride)
    H_out, W_out = conv_out_hw(H, W, kh, kw, stride, padding)
    implicit = N * H * W * C_in * (1 + (kh - sh) / max(H, 1))  # + row halo
    im2col = N * H_out * W_out * kh * kw * C_in
    return {
        "implicit_bytes": implicit * bytes_per_elem,
        "im2col_bytes": im2col * bytes_per_elem,
        "reduction": 1.0 - implicit / max(im2col, 1),
    }
