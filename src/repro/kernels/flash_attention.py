"""Flash-attention Pallas kernels (forward): online softmax, causal, GQA.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost.  The query block
and the fp32 (m, l, acc) statistics stay VMEM-resident across the kv sweep;
K/V blocks stream.  GQA needs no materialized head repeat: the K/V BlockSpec
index map folds ``q_head // rep`` so each query head reads its group's KV.

``int8_flash_attention_fwd`` is the operand-width variant: Q/K/V stream as
per-head symmetric int8, both GeMMs (QK^T and PV) run on int8 operands with
int32 accumulation, and only the softmax statistics stay float -- the
probability block requantizes to int8 (scale 1/127, exact for p in [0, 1])
before the PV product.  An explicit boolean mask input replaces the
index-derived causal mask so the serve engine's per-slot cached-decode
masks (rolling SWA windows, per-slot lengths) drop in unchanged.

These are the MXU counterparts of the model-level ``layers.flash_attention``
/ ``layers.cached_attention`` (pure-jnp), which serve as their oracles in
the tests.  Causal masking skips nothing structurally (masked blocks are
computed) -- the exact-causal grid shaving is a documented follow-up; the
model-level path already supports it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_EPS = 1e-9


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nk: int, bq: int, bkv: int, scale: float, causal: bool,
               seq_len: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                 # (bq, dh)
    k = k_ref[0, 0]                                 # (bkv, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bkv)

    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_idx = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = k_idx < seq_len
    if causal:
        mask = mask & (k_idx <= q_idx)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,          # (B, H, Sq, dh)
    k: jax.Array,          # (B, KvH, Skv, dh)
    v: jax.Array,          # (B, KvH, Skv, dh)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, dh = q.shape
    KvH, Skv = k.shape[1], k.shape[2]
    rep = H // KvH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    scale = dh ** -0.5

    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    nq = q_p.shape[2] // bq
    nk = k_p.shape[2] // bkv

    out = pl.pallas_call(
        functools.partial(_fa_kernel, nk=nk, bq=bq, bkv=bkv, scale=scale,
                          causal=causal, seq_len=Skv),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            # GQA: query head h reads KV group h // rep -- no repeat
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q_p, k_p, v_p)
    return out[:, :, :Sq]


# ---------------------------------------------------------------------------
# int8 attention: quantized QK^T and PV, float softmax, per-head scales
# ---------------------------------------------------------------------------


def _fa_int8_kernel(q_ref, k_ref, v_ref, mask_ref, sqk_ref, sv_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                 # (bq, dh) int8
    k = k_ref[0, 0]                                 # (bkv, dh) int8
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32
    ).astype(jnp.float32) * sqk_ref[0, 0]           # dequant + softmax scale
    s = jnp.where(mask_ref[0], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    # requantize the probability block: p in [0, 1] -> round(p * 127).  The
    # denominator uses the SAME quantized p so numerator and normalization
    # stay consistent.
    pq = jnp.round(p * 127.0).astype(jnp.int8)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = (l_ref[...] * corr
                  + pq.astype(jnp.float32).sum(axis=1, keepdims=True) / 127.0)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        pq, v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_ref[...] = (acc_ref[...] * corr
                    + pv.astype(jnp.float32) * (sv_ref[0, 0] / 127.0))

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _per_head_quantize(x: jax.Array, qmax: float = 127.0):
    """(B, H, S, dh) float -> (int8 payload, (H, 1) f32 per-head scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(0, 2, 3))
    scale = jnp.maximum(amax, _EPS) / qmax                     # (H,)
    xq = jnp.clip(jnp.round(x.astype(jnp.float32)
                            / scale[None, :, None, None]), -qmax, qmax
                  ).astype(jnp.int8)
    return xq, scale[:, None].astype(jnp.float32)


def int8_flash_attention_fwd(
    q: jax.Array,          # (B, H, Sq, dh) float
    k: jax.Array,          # (B, KvH, Skv, dh) float
    v: jax.Array,          # (B, KvH, Skv, dh) float
    *,
    mask: jax.Array | None = None,   # (B, Sq, Skv) bool; None = causal
    causal: bool = True,
    scale: float | None = None,      # None = dh ** -0.5
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Int8-operand flash attention with float softmax statistics.

    Q/K/V are symmetrically quantized per head on the way in (the KV-cache
    byte stream the decode step is bound by shrinks 2-4x vs bf16/f32);
    scores dequantize via the per-head scale product before the online
    softmax, and the probability block requantizes for the int8 PV product.
    ``mask`` replaces the built-in causal mask when given -- the cached
    decode path passes its per-slot position masks straight through.
    """
    B, H, Sq, dh = q.shape
    KvH, Skv = k.shape[1], k.shape[2]
    rep = H // KvH
    sm_scale = dh ** -0.5 if scale is None else scale
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)

    qq, sq = _per_head_quantize(q)                 # (H, 1)
    kq, sk = _per_head_quantize(k)                 # (KvH, 1)
    vq, sv = _per_head_quantize(v)                 # (KvH, 1)
    # combined per-q-head dequant scale for the score block
    sqk = sq * sk[jnp.arange(H) // rep] * sm_scale  # (H, 1)

    if mask is None:
        q_idx = jnp.arange(Sq)
        kv_idx = jnp.arange(Skv)
        m2 = kv_idx[None, :] <= q_idx[:, None] if causal \
            else jnp.ones((Sq, Skv), bool)
        mask = jnp.broadcast_to(m2[None], (B, Sq, Skv))
    mask = jnp.pad(mask, ((0, 0), (0, (-Sq) % bq), (0, (-Skv) % bkv)))

    q_p = jnp.pad(qq, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
    k_p = jnp.pad(kq, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    v_p = jnp.pad(vq, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    nq = q_p.shape[2] // bq
    nk = k_p.shape[2] // bkv

    out = pl.pallas_call(
        functools.partial(_fa_int8_kernel, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, bq, bkv), lambda b, h, qi, ki: (b, qi, ki)),
            pl.BlockSpec((1, 1), lambda b, h, qi, ki: (h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, qi, ki, rep=rep: (h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q_p, k_p, v_p, mask, sqk, sv)
    return out[:, :, :Sq]
