"""Flash-attention Pallas kernel (forward): online softmax, causal, GQA.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost.  The query block
and the fp32 (m, l, acc) statistics stay VMEM-resident across the kv sweep;
K/V blocks stream.  GQA needs no materialized head repeat: the K/V BlockSpec
index map folds ``q_head // rep`` so each query head reads its group's KV.

This is the MXU counterpart of the model-level ``layers.flash_attention``
(pure-jnp scan), which serves as its oracle in the tests.  Causal masking
skips nothing structurally (masked blocks are computed) -- the exact-causal
grid shaving is a documented follow-up; the model-level path already
supports it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nk: int, bq: int, bkv: int, scale: float, causal: bool,
               seq_len: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                 # (bq, dh)
    k = k_ref[0, 0]                                 # (bkv, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bkv)

    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_idx = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = k_idx < seq_len
    if causal:
        mask = mask & (k_idx <= q_idx)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,          # (B, H, Sq, dh)
    k: jax.Array,          # (B, KvH, Skv, dh)
    v: jax.Array,          # (B, KvH, Skv, dh)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, dh = q.shape
    KvH, Skv = k.shape[1], k.shape[2]
    rep = H // KvH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    scale = dh ** -0.5

    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, (-Skv) % bkv), (0, 0)))
    nq = q_p.shape[2] // bq
    nk = k_p.shape[2] // bkv

    out = pl.pallas_call(
        functools.partial(_fa_kernel, nk=nk, bq=bq, bkv=bkv, scale=scale,
                          causal=causal, seq_len=Skv),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            # GQA: query head h reads KV group h // rep -- no repeat
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q_p, k_p, v_p)
    return out[:, :, :Sq]
