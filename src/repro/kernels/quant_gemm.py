"""Quantized Pallas GeMM / im2col-conv kernels (sub-byte operands, wide acc).

The precision axis of the paper's claims: Axon's runtime and energy wins
are per *operand byte* streamed from DRAM, so shrinking operands from
bf16/f32 to int8 -- and below, to packed int4 and fp8 -- compounds directly
with the on-chip-im2col traffic cut (cf. low-precision systolic arrays for
CNN inference, arXiv:2005.08098; DiP's traffic-per-MAC argument for
transformer GeMMs).

All kernels carry a fused dequant-rescale epilogue (the wide accumulator is
scaled by the combined ``act_scale * weight_scale[channel]`` column vector
and cast ONCE, at the final K/C_in grid step -- no int32 or f32
intermediate ever round-trips to HBM):

  * ``quant_gemm``       : ``(M, K) int8 x (K, N) int8 -> out_dtype``, also
                           the weight-only form (float lhs, int8 rhs cast
                           up in VMEM -- halves weight HBM bytes vs bf16).
  * ``wq_gemv``          : the decode-step shape -- small-M float
                           activations against a streamed int8 weight.
  * ``int4_gemm`` /
    ``int4_gemv``        : weight-only against a nibble-packed int4 weight
                           streamed at 0.5 B/elem; the unpack (sign-extend
                           + interleave) is fused into the VMEM epilogue of
                           each K step, so HBM only ever sees packed bytes.
  * ``fp8_gemm``         : e4m3 activation x e4m3 weight at 1 B/elem each,
                           f32 accumulation, scale-cast epilogue.
  * ``quant_im2col_conv``: the implicit-im2col conv with int8 IFMAP/filter
                           blocks; symmetric quantization makes the zero
                           spatial padding exact (zero-point is 0).

Accumulation bound: |a|,|b| <= 127 so each product is < 2^14; int32 holds
exact sums for K up to ~2^17 -- far beyond any zoo layer's K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import conv_out_hw, normalize_padding, normalize_stride
from repro.quant.qtensor import FP8_DTYPE, unpack_int4


def _pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, multiples)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


# ---------------------------------------------------------------------------
# blocked GeMM: int8 x int8 (int32 acc) and weight-only (f32 acc)
# ---------------------------------------------------------------------------


def _qgemm_kernel(a_ref, b_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if acc_ref.dtype == jnp.int32:
        acc_ref[...] += jnp.dot(a, b_ref[...],
                                preferred_element_type=jnp.int32)
    else:
        # weight-only: int8 values (<= 127) are exact in any float dtype
        acc_ref[...] += jnp.dot(a, b_ref[...].astype(a.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[...]).astype(o_ref.dtype)


def quant_gemm(
    a: jax.Array,              # (M, K) int8, or float for weight-only
    b: jax.Array,              # (K, N) int8
    scale: jax.Array,          # (N,) f32 combined dequant scale per column
    *,
    block: tuple[int, int, int] = (256, 256, 256),
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """``dequant(a @ b)``: int32 (or f32) accumulate, scale-cast epilogue."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert scale.shape == (N,), (scale.shape, N)
    bm, bk, bn = block
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)

    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    s_p = _pad_to(scale.astype(jnp.float32), (bn,))[None, :]   # (1, Np)
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    nm, nk, nn = Mp // bm, Kp // bk, Np // bn
    acc_dtype = jnp.int32 if a.dtype == jnp.int8 else jnp.float32

    out = pl.pallas_call(
        functools.partial(_qgemm_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a_p, b_p, s_p)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# weight-only GEMV: the serve engine's decode-step shape
# ---------------------------------------------------------------------------


def _wq_gemv_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...].astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def wq_gemv(
    x: jax.Array,              # (B, K) float, B small (decode rows)
    w: jax.Array,              # (K, N) int8
    scale: jax.Array,          # (N,) f32 per-column dequant scale
    *,
    block_k: int = 512,
    block_n: int = 1024,
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Streaming weight-only GEMV: W read once, at 1 byte per element."""
    B, K = x.shape
    K2, N = w.shape
    assert K == K2 and scale.shape == (N,)
    bk = min(block_k, K)
    bn = min(block_n, N)

    x_p = jnp.pad(x, ((0, 0), (0, (-K) % bk)))
    w_p = jnp.pad(w, ((0, (-K) % bk), (0, (-N) % bn)))
    s_p = _pad_to(scale.astype(jnp.float32), (bn,))[None, :]
    nk = x_p.shape[1] // bk
    nn = w_p.shape[1] // bn

    out = pl.pallas_call(
        functools.partial(_wq_gemv_kernel, nk=nk),
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((B, bk), lambda n, k: (0, k)),
            pl.BlockSpec((bk, bn), lambda n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        interpret=interpret,
    )(x_p, w_p, s_p)
    return out[:, :N]


# ---------------------------------------------------------------------------
# packed int4 weight-only GeMM / GEMV (fused unpack-dequant epilogue)
# ---------------------------------------------------------------------------


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """(bk/2, bn) packed int8 -> (bk, bn) int8 in [-8, 7], VMEM-local.

    One packing convention lives in ``qtensor.unpack_int4`` (sign-extending
    shifts + sublane interleave); it lowers inside the kernel body without
    the unpacked values ever touching HBM."""
    return unpack_int4(packed, 2 * packed.shape[0], axis=0)


def _int4_gemm_kernel(a_ref, b_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    w = _unpack_nibbles(b_ref[...])
    acc_ref[...] += jnp.dot(a, w.astype(a.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def int4_gemm(
    a: jax.Array,              # (M, K) float activations
    b_packed: jax.Array,       # (ceil(K/2), N) int8: two nibbles per byte
    scale: jax.Array,          # (N,) f32 combined dequant scale per column
    *,
    k_size: int,               # logical (unpacked) K
    block: tuple[int, int, int] = (256, 256, 256),
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Weight-only GeMM against a nibble-packed int4 weight.

    The weight streams from HBM at 0.5 B/elem; each K-step block unpacks in
    VMEM and feeds the MXU at the activation dtype (int4 values are exact in
    any float format)."""
    M, K = a.shape
    K2, N = b_packed.shape
    assert K == k_size and K2 == (K + 1) // 2, (a.shape, b_packed.shape)
    assert scale.shape == (N,), (scale.shape, N)
    bm, bk, bn = block
    bm, bn = min(bm, M), min(bn, N)
    bk = min(bk, K)
    bk += bk % 2                              # packed pairs: bk must be even

    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b_packed, (bk // 2, bn))
    s_p = _pad_to(scale.astype(jnp.float32), (bn,))[None, :]
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    nm, nk, nn = Mp // bm, Kp // bk, Np // bn

    out = pl.pallas_call(
        functools.partial(_int4_gemm_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p, s_p)
    return out[:M, :N]


def _int4_gemv_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = _unpack_nibbles(w_ref[...])
    acc_ref[...] += jnp.dot(x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def int4_gemv(
    x: jax.Array,              # (B, K) float, B small (decode rows)
    w_packed: jax.Array,       # (ceil(K/2), N) int8 packed nibbles
    scale: jax.Array,          # (N,) f32 per-column dequant scale
    *,
    k_size: int,
    block_k: int = 512,
    block_n: int = 1024,
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Streaming int4 weight-only GEMV: W read once, at half a byte per
    element -- the decode step's memory-bound shape at its narrowest."""
    B, K = x.shape
    K2, N = w_packed.shape
    assert K == k_size and K2 == (K + 1) // 2 and scale.shape == (N,)
    bk = min(block_k, K)
    bk += bk % 2
    bn = min(block_n, N)

    x_p = jnp.pad(x, ((0, 0), (0, (-K) % bk)))
    w_p = _pad_to(w_packed, (bk // 2, bn))
    s_p = _pad_to(scale.astype(jnp.float32), (bn,))[None, :]
    nk = x_p.shape[1] // bk
    nn = w_p.shape[1] // bn

    out = pl.pallas_call(
        functools.partial(_int4_gemv_kernel, nk=nk),
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((B, bk), lambda n, k: (0, k)),
            pl.BlockSpec((bk // 2, bn), lambda n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        interpret=interpret,
    )(x_p, w_p, s_p)
    return out[:, :N]


# ---------------------------------------------------------------------------
# fp8 (e4m3) GeMM: 1-byte operands on BOTH sides, f32 accumulation
# ---------------------------------------------------------------------------


def _fp8_gemm_kernel(a_ref, b_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # upcast in VMEM: HBM streamed 1 B/elem either way, and f32 MACs keep
    # the kernel exact on every backend (e4m3 -> f32 is value-preserving)
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def fp8_gemm(
    a: jax.Array,              # (M, K) e4m3 (or float for weight-only)
    b: jax.Array,              # (K, N) e4m3
    scale: jax.Array,          # (N,) f32 combined dequant scale per column
    *,
    block: tuple[int, int, int] = (256, 256, 256),
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """``dequant(a @ b)`` with e4m3 operands and float32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert scale.shape == (N,), (scale.shape, N)
    assert b.dtype == FP8_DTYPE, b.dtype
    bm, bk, bn = block
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)

    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    s_p = _pad_to(scale.astype(jnp.float32), (bn,))[None, :]
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    nm, nk, nn = Mp // bm, Kp // bk, Np // bn

    out = pl.pallas_call(
        functools.partial(_fp8_gemm_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p, s_p)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# int8 implicit-im2col conv (mirrors kernels/im2col_conv.py)
# ---------------------------------------------------------------------------


def _qconv_kernel(x_ref, halo_ref, w_ref, s_ref, o_ref, acc_ref, *,
                  kh: int, kw: int, sh: int, sw: int, th: int, w_out: int,
                  nci: int):
    ci = pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tile = jnp.concatenate([x_ref[0], halo_ref[0]], axis=0)

    acc = acc_ref[...]
    for dh in range(kh):
        for dw in range(kw):
            view = jax.lax.slice(
                tile,
                (dh, dw, 0),
                (dh + sh * (th - 1) + 1, dw + sw * (w_out - 1) + 1,
                 tile.shape[2]),
                (sh, sw, 1),
            )
            lhs = view.reshape(th * w_out, tile.shape[2])
            acc += jnp.dot(lhs, w_ref[dh, dw],
                           preferred_element_type=jnp.int32)
    acc_ref[...] = acc

    @pl.when(ci == nci - 1)
    def _store():
        deq = acc_ref[...].astype(jnp.float32) * s_ref[...]
        o_ref[...] = deq.reshape(1, th, w_out, -1).astype(o_ref.dtype)


def quant_im2col_conv(
    x: jax.Array,              # (N, H, W, C_in) int8 (pre-quantized IFMAP)
    w: jax.Array,              # (kh, kw, C_in, C_out) int8
    scale: jax.Array,          # (C_out,) f32 combined dequant scale
    *,
    stride=1,
    padding=0,
    block_rows: int = 8,
    block_cout: int = 128,
    block_cin: int = 512,
    out_dtype: jnp.dtype = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Int8 implicit-im2col conv: IFMAP bytes stream at 1 B/elem, reuse
    ``kh * kw``-fold from VMEM, int32 accumulate, scale-cast epilogue."""
    N, H, W, C_in = x.shape
    kh, kw, C_in2, C_out = w.shape
    assert C_in == C_in2 and scale.shape == (C_out,)
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8, (x.dtype, w.dtype)
    sh, sw = normalize_stride(stride)
    (pt, pb), (pleft, pr) = normalize_padding(padding)
    H_out, W_out = conv_out_hw(H, W, kh, kw, (sh, sw), padding)
    if H_out < 1 or W_out < 1:
        raise ValueError(
            f"quant_im2col_conv: zero-area output ({H_out}x{W_out}); the "
            "axon front door routes these to the XLA reference path")

    th = min(block_rows, H_out)
    while (th - 1) * sh + kh > 2 * th * sh:
        th += 1
    bco = min(block_cout, C_out)
    bci = min(block_cin, C_in)

    n_h = -(-H_out // th)
    h_span = (n_h + 1) * th * sh + kh
    w_span = (W_out - 1) * sw + kw
    # zero padding is exact: symmetric quantization has zero-point 0
    x_p = jnp.pad(
        x,
        ((0, 0),
         (pt, max(0, h_span - (H + pt))),
         (pleft, max(0, w_span - (W + pleft))),
         (0, (-C_in) % bci)),
    )
    Wp = x_p.shape[2]
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, (-C_in) % bci), (0, (-C_out) % bco)))
    s_p = _pad_to(scale.astype(jnp.float32), (bco,))[None, :]
    n_co = w_p.shape[3] // bco
    n_ci = w_p.shape[2] // bci

    grid = (N, n_h, n_co, n_ci)
    out = pl.pallas_call(
        functools.partial(_qconv_kernel, kh=kh, kw=kw, sh=sh, sw=sw, th=th,
                          w_out=W_out, nci=n_ci),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, th * sh, Wp, bci),
                         lambda b, h, co, ci: (b, h, 0, ci)),
            pl.BlockSpec((1, th * sh, Wp, bci),
                         lambda b, h, co, ci: (b, h + 1, 0, ci)),
            pl.BlockSpec((kh, kw, bci, bco),
                         lambda b, h, co, ci: (0, 0, ci, co)),
            pl.BlockSpec((1, bco), lambda b, h, co, ci: (0, co)),
        ],
        out_specs=pl.BlockSpec((1, th, W_out, bco),
                               lambda b, h, co, ci: (b, h, 0, co)),
        out_shape=jax.ShapeDtypeStruct((N, n_h * th, W_out, n_co * bco),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((th * W_out, bco), jnp.int32)],
        interpret=interpret,
    )(x_p, x_p, w_p, s_p)
    return out[:, :H_out, :, :C_out]
