"""DEPRECATED: thin shims over the unified ``repro.axon`` operator API.

This module was the public face of the Pallas kernels; every entry point now
delegates to ``repro.axon`` (policy-scoped, mapper-cached dispatch).  New
code should call ``axon.matmul`` / ``axon.einsum`` / ``axon.conv2d`` under a
``with axon.policy(...)`` scope instead of threading ``interpret=`` /
``block=`` / ``order=`` kwargs per call.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro import axon
from repro.axon.policy import ExecutionPolicy
from repro.core.dataflows import Dataflow
from repro.kernels.zero_gate_gemm import block_mask  # re-export (unchanged)

# importing the shim layer at all is deprecated (each entry point warns
# again at call time); the AST lint rule LNT001 blocks in-repo imports
warnings.warn(
    "repro.kernels.ops is deprecated; use the repro.axon operator API",
    DeprecationWarning, stacklevel=2)


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use {repl} "
        f"(see repro.axon)", DeprecationWarning, stacklevel=3)


def _policy(interpret, block=None, order=None) -> ExecutionPolicy:
    # interpret=None -> auto (interpreted off-TPU); an explicit bool is
    # honored, so interpret=False still surfaces compile errors on CPU as
    # the old kwargs-based API did.
    backend = "interpret" if interpret else "pallas"
    return ExecutionPolicy(backend=backend, block=block, order=order,
                           force_interpret=interpret)


def gemm(a, b, *, block=(128, 128, 128), order=Dataflow.OS, out_dtype=None,
         interpret=None):
    _warn("gemm", "axon.matmul with policy(block=..., order=...)")
    out = axon.matmul(a, b, policy=_policy(interpret, block, order),
                      preferred_element_type=out_dtype)
    return out if out_dtype else out.astype(a.dtype)


def auto_gemm(a, b, *, out_dtype=None, interpret=None):
    """GeMM with mapper-selected blocking + loop order (cached per shape)."""
    _warn("auto_gemm", "axon.matmul")
    out = axon.matmul(a, b, policy=_policy(interpret),
                      preferred_element_type=out_dtype)
    return out if out_dtype else out.astype(a.dtype)


def conv2d(x, w, *, stride=1, padding=0, block_rows=8, block_cout=128,
           block_cin=512, out_dtype=None, interpret=None):
    _warn("conv2d", "axon.conv2d")
    return axon.conv2d(x, w, stride=stride, padding=padding,
                       block_rows=block_rows, block_cout=block_cout,
                       block_cin=block_cin, out_dtype=out_dtype,
                       policy=_policy(interpret))


def depthwise_conv2d(x, w, *, stride=1, padding=0, block_rows=8, block_c=128,
                     out_dtype=None, interpret=None):
    _warn("depthwise_conv2d", "axon.depthwise_conv2d")
    return axon.depthwise_conv2d(x, w, stride=stride, padding=padding,
                                 block_rows=block_rows, block_c=block_c,
                                 out_dtype=out_dtype,
                                 policy=_policy(interpret))


def matvec(x, w, *, block_k=512, block_n=1024, out_dtype=None, interpret=None):
    _warn("matvec", "axon.einsum('k,kn->n', ...)")
    # bm=8: batched inputs beyond the gemv kernel's small-batch window (M>8)
    # fall to the GeMM kernel with a full-sublane row block, not bm=1
    pol = _policy(interpret, block=(8, block_k, block_n))
    if x.ndim == 1:
        out = axon.einsum("k,kn->n", x, w, policy=pol,
                          preferred_element_type=out_dtype)
    else:
        out = axon.einsum("bk,kn->bn", x, w, policy=pol,
                          preferred_element_type=out_dtype)
    return out if out_dtype else out.astype(x.dtype)


def sparse_gemm(a, b, *, block=(128, 128, 128), out_dtype=None,
                interpret=None):
    _warn("sparse_gemm", "axon.matmul with policy(zero_gate=True)")
    pol = dataclasses.replace(_policy(interpret, block, Dataflow.OS),
                              zero_gate=True)
    out = axon.matmul(a, b, policy=pol, preferred_element_type=out_dtype)
    return out if out_dtype else out.astype(a.dtype)


__all__ = [
    "auto_gemm", "block_mask", "conv2d", "depthwise_conv2d", "gemm",
    "matvec", "sparse_gemm",
]
