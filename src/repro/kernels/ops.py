"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) kernels execute under ``interpret=True``; on TPU the
same ``pallas_call`` lowers to Mosaic.  ``auto_gemm`` routes block shape and
loop order through the Axon mapper (``repro.core.mapper``) -- the paper's
runtime model acting as the framework's kernel auto-tuner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dataflows import Dataflow, GemmShape
from repro.core.mapper import select_tpu_blocking
from repro.kernels.axon_gemm import axon_gemm
from repro.kernels.dwconv import dwconv
from repro.kernels.gemv import gemv
from repro.kernels.im2col_conv import im2col_conv
from repro.kernels.zero_gate_gemm import block_mask, zero_gate_gemm


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block", "order", "out_dtype", "interpret"))
def gemm(a, b, *, block=(128, 128, 128), order=Dataflow.OS, out_dtype=None,
         interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return axon_gemm(a, b, block=block, order=order, out_dtype=out_dtype,
                     interpret=interpret)


def auto_gemm(a, b, *, out_dtype=None, interpret=None):
    """GeMM with mapper-selected blocking + loop order (static per shape)."""
    M, K = a.shape
    _, N = b.shape
    sel = select_tpu_blocking(GemmShape(M, K, N),
                              bytes_per_elem=a.dtype.itemsize)
    return gemm(a, b, block=(sel.bm, sel.bk, sel.bn), order=sel.loop_order,
                out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "block_rows", "block_cout", "block_cin",
    "out_dtype", "interpret"))
def conv2d(x, w, *, stride=1, padding=0, block_rows=8, block_cout=128,
           block_cin=512, out_dtype=None, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return im2col_conv(x, w, stride=stride, padding=padding,
                       block_rows=block_rows, block_cout=block_cout,
                       block_cin=block_cin, out_dtype=out_dtype,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "block_rows", "block_c", "out_dtype", "interpret"))
def depthwise_conv2d(x, w, *, stride=1, padding=0, block_rows=8, block_c=128,
                     out_dtype=None, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return dwconv(x, w, stride=stride, padding=padding, block_rows=block_rows,
                  block_c=block_c, out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n", "out_dtype",
                                             "interpret"))
def matvec(x, w, *, block_k=512, block_n=1024, out_dtype=None, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return gemv(x, w, block_k=block_k, block_n=block_n, out_dtype=out_dtype,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def sparse_gemm(a, b, *, block=(128, 128, 128), out_dtype=None, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return zero_gate_gemm(a, b, block=block, out_dtype=out_dtype,
                          interpret=interpret)


__all__ = [
    "auto_gemm", "block_mask", "conv2d", "depthwise_conv2d", "gemm",
    "matvec", "sparse_gemm",
]
