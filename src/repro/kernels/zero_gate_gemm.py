"""Block-sparse zero-gated GeMM: the TPU analogue of the paper's zero gating.

The paper skips individual MACs when either operand is zero, saving *power*
(5.3 % at 10 % sparsity, §5.2.1).  A TPU cannot clock-gate single MACs from
software, so the idiomatic translation converts the power saving into a
*time* saving at block granularity: a precomputed block-occupancy mask lets
the kernel skip entire (bm, bk) x (bk, bn) MXU passes whose A-block is all
zero (``@pl.when`` on a mask operand).  With structured sparsity (pruned
experts, padded capacity buffers, masked attention rows) whole blocks are
zero and the skip rate approaches the element sparsity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.axon_gemm import _pad_to


def _zg_kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _mac():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_mask(a: jax.Array, bm: int, bk: int) -> jax.Array:
    """(ceil(M/bm), ceil(K/bk)) int32 occupancy mask of A's blocks."""
    a_p = _pad_to(a, (bm, bk))
    Mp, Kp = a_p.shape
    blocks = a_p.reshape(Mp // bm, bm, Kp // bk, bk)
    return jnp.any(blocks != 0, axis=(1, 3)).astype(jnp.int32)


def zero_gate_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    mask: jax.Array | None = None,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bk, bn = block
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    out_dtype = out_dtype or a.dtype

    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    if mask is None:
        mask = block_mask(a, bm, bk)
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    nm, nk, nn = Mp // bm, Kp // bk, Np // bn
    assert mask.shape == (nm, nk), (mask.shape, (nm, nk))

    out = pl.pallas_call(
        functools.partial(_zg_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, l: (i, l)),
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(mask, a_p, b_p)
    return out[:M, :N]


def skip_fraction(mask: jax.Array) -> float:
    """Fraction of MXU block passes gated off (the 'time' analogue of the
    paper's power saving)."""
    return float(1.0 - jnp.mean(mask.astype(jnp.float32)))
