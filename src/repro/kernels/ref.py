"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normalize_stride(stride) -> tuple[int, int]:
    """int -> (s, s); (sh, sw) -> (sh, sw)."""
    if isinstance(stride, int):
        return (stride, stride)
    sh, sw = stride
    return (int(sh), int(sw))


def normalize_padding(padding) -> tuple[tuple[int, int], tuple[int, int]]:
    """int | (ph, pw) | ((pt, pb), (pl, pr)) -> ((pt, pb), (pl, pr)).

    String padding ("SAME"/"VALID") is resolved against the input shape by
    the ``axon`` front door before it reaches the kernels/oracles."""
    if isinstance(padding, str):
        raise TypeError(
            f"string padding {padding!r} must be resolved to explicit pad "
            "amounts before reaching the kernel layer (use axon.conv2d)")
    if isinstance(padding, int):
        return ((padding, padding), (padding, padding))
    a, b = padding
    if isinstance(a, int) and isinstance(b, int):
        return ((a, a), (b, b))
    (pt, pb), (pl, pr) = a, b
    return ((int(pt), int(pb)), (int(pl), int(pr)))


def conv_out_hw(h: int, w: int, kh: int, kw: int, stride, padding
                ) -> tuple[int, int]:
    """Output spatial dims; <= 0 means a zero-area output (kernel larger
    than the padded input, or stride overshoot)."""
    (sh, sw) = normalize_stride(stride)
    (pt, pb), (pl, pr) = normalize_padding(padding)
    return ((h + pt + pb - kh) // sh + 1, (w + pl + pr - kw) // sw + 1)


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def gemv_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride=1, padding=0,
               groups: int = 1, out_dtype=None) -> jax.Array:
    """NHWC x HWIO -> NHWC, fp32 accumulation.

    ``stride`` is an int or ``(sh, sw)``; ``padding`` an int, ``(ph, pw)``,
    or explicit ``((pt, pb), (pl, pr))`` pairs; ``groups`` is lax's
    ``feature_group_count`` (w: ``(kh, kw, C_in // groups, C_out)``)."""
    out_dtype = out_dtype or x.dtype
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=normalize_stride(stride),
        padding=list(normalize_padding(padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return out.astype(out_dtype)


def dwconv_ref(x: jax.Array, w: jax.Array, *, stride=1,
               padding=0, out_dtype=None) -> jax.Array:
    """NHWC x (kh, kw, C) depthwise -> NHWC."""
    out_dtype = out_dtype or x.dtype
    C = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w[:, :, None, :].astype(jnp.float32),   # (kh, kw, 1, C) HWIO w/ groups
        window_strides=normalize_stride(stride),
        padding=list(normalize_padding(padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=C,
    )
    return out.astype(out_dtype)


def zero_gate_gemm_ref(a: jax.Array, b: jax.Array, bm: int, bk: int,
                       out_dtype=None) -> jax.Array:
    """Matmul with A's all-zero (bm, bk) blocks contributing nothing --
    identical to a plain matmul (zero blocks contribute zero); exists so the
    sparse kernel has an explicitly-stated semantic oracle."""
    return gemm_ref(a, b, out_dtype)
