"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def gemv_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: int = 0, out_dtype=None) -> jax.Array:
    """NHWC x HWIO -> NHWC, fp32 accumulation."""
    out_dtype = out_dtype or x.dtype
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(out_dtype)


def dwconv_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: int = 0, out_dtype=None) -> jax.Array:
    """NHWC x (kh, kw, C) depthwise -> NHWC."""
    out_dtype = out_dtype or x.dtype
    C = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w[:, :, None, :].astype(jnp.float32),   # (kh, kw, 1, C) HWIO w/ groups
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=C,
    )
    return out.astype(out_dtype)


def zero_gate_gemm_ref(a: jax.Array, b: jax.Array, bm: int, bk: int,
                       out_dtype=None) -> jax.Array:
    """Matmul with A's all-zero (bm, bk) blocks contributing nothing --
    identical to a plain matmul (zero blocks contribute zero); exists so the
    sparse kernel has an explicitly-stated semantic oracle."""
    return gemm_ref(a, b, out_dtype)
