"""Blocked GeMM Pallas kernel with OS / WS / IS loop orders.

The paper's dataflow taxonomy (§2.1, §4) maps onto a blocked TPU GeMM as
*which operand stays VMEM-resident across the innermost grid dimension*:

  OS: grid (M, N, K), K innermost -- the fp32 accumulator block is resident
      (output stationary); A and B blocks stream.
  WS: grid (N, K, M), M innermost -- the B (weight) block is resident; each
      K slab writes its own fp32 partial-sum plane to HBM (reduced outside
      the kernel), which is exactly the WS partial-sum-movement cost the
      paper describes.
  IS: grid (M, K, N), N innermost -- the A (input) block is resident; same
      per-slab partial-sum layout as WS.

The WS/IS output is (nk, M, N): Pallas only guarantees an output block's
revisits happen on *consecutive* grid steps when every grid dimension its
index map ignores is innermost, and the WS/IS orders put the K dimension in
the middle by design -- so instead of revisiting one (M, N) accumulator
across non-adjacent steps (silently losing partial sums on real TPU), every
(i, l, j) step owns a distinct block and the K-reduction is a plain XLA sum.

Axon's *fill-latency* insight maps to the pipeline prologue: Pallas
double-buffers block DMAs, so compute starts after one block fetch -- the
software analogue of feeding on the principal diagonal instead of walking
operands across the array.  The mapper (``repro.core.mapper``) picks the
loop order + block shape by modeled HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflows import Dataflow


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _streaming_kernel(a_ref, b_ref, o_ref):
    """WS/IS body: write this K slab's partial product to its own plane."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )[None]


def _pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, multiples)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def axon_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block: tuple[int, int, int] = (128, 128, 128),
    order: Dataflow = Dataflow.OS,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    """``a (M, K) @ b (K, N)`` with the requested loop order."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bk, bn = block
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    out_dtype = out_dtype or a.dtype

    a_p = _pad_to(a, (bm, bk))
    b_p = _pad_to(b, (bk, bn))
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    nm, nk, nn = Mp // bm, Kp // bk, Np // bn

    if order is Dataflow.OS:
        grid = (nm, nn, nk)
        out = pl.pallas_call(
            functools.partial(_os_kernel, nk=nk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(a_p, b_p)
    elif order is Dataflow.WS:
        # B-block resident across the innermost M sweep; each K slab owns a
        # distinct fp32 partial plane (see module docstring), reduced here.
        grid = (nn, nk, nm)
        out = pl.pallas_call(
            _streaming_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, l, i: (i, l)),
                pl.BlockSpec((bk, bn), lambda j, l, i: (l, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda j, l, i: (l, i, j)),
            out_shape=jax.ShapeDtypeStruct((nk, Mp, Np), jnp.float32),
            interpret=interpret,
        )(a_p, b_p).sum(axis=0).astype(out_dtype)
    elif order is Dataflow.IS:
        grid = (nm, nk, nn)
        out = pl.pallas_call(
            _streaming_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, l, j: (i, l)),
                pl.BlockSpec((bk, bn), lambda i, l, j: (l, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda i, l, j: (l, i, j)),
            out_shape=jax.ShapeDtypeStruct((nk, Mp, Np), jnp.float32),
            interpret=interpret,
        )(a_p, b_p).sum(axis=0).astype(out_dtype)
    else:
        raise ValueError(order)

    return out[:M, :N]
