"""Depthwise convolution Pallas kernel (VPU path).

Depthwise conv is one of the paper's memory-bound showcases (Fig. 14): there
is no C_in reduction, so arithmetic intensity is ~kh*kw MACs/element and the
op lives on the HBM roofline.  The kernel keeps the whole row-tile resident
in VMEM (same halo trick as ``im2col_conv``) and does the kh*kw
multiply-accumulates on the VPU -- no MXU detour, no im2col expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import conv_out_hw, normalize_padding, normalize_stride


def _dw_kernel(x_ref, halo_ref, w_ref, o_ref, *,
               kh: int, kw: int, sh: int, sw: int, th: int, w_out: int):
    tile = jnp.concatenate([x_ref[0], halo_ref[0]], axis=0)  # (2*th*sh, Wp, bc)
    acc = jnp.zeros((th, w_out, tile.shape[2]), jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            view = jax.lax.slice(
                tile,
                (dh, dw, 0),
                (dh + sh * (th - 1) + 1, dw + sw * (w_out - 1) + 1,
                 tile.shape[2]),
                (sh, sw, 1),
            )
            acc += view.astype(jnp.float32) * w_ref[dh, dw][None, None, :]
    o_ref[...] = acc[None].astype(o_ref.dtype)


def dwconv(
    x: jax.Array,            # (N, H, W, C)
    w: jax.Array,            # (kh, kw, C)
    *,
    stride=1,                # int or (sh, sw)
    padding=0,               # int, (ph, pw), or ((pt, pb), (pl, pr))
    block_rows: int = 8,
    block_c: int = 128,
    out_dtype: jnp.dtype | None = None,
    interpret: bool = False,
) -> jax.Array:
    N, H, W, C = x.shape
    kh, kw, C2 = w.shape
    assert C == C2
    sh, sw = normalize_stride(stride)
    (pt, pb), (pleft, pr) = normalize_padding(padding)
    H_out, W_out = conv_out_hw(H, W, kh, kw, (sh, sw), padding)
    if H_out < 1 or W_out < 1:
        raise ValueError(
            f"dwconv: zero-area output ({H_out}x{W_out}); use the XLA "
            "reference path (axon.depthwise_conv2d routes this automatically)")
    out_dtype = out_dtype or x.dtype

    th = min(block_rows, H_out)
    while (th - 1) * sh + kh > 2 * th * sh:
        th += 1
    bc = min(block_c, C)

    n_h = -(-H_out // th)
    h_span = (n_h + 1) * th * sh + kh
    w_span = (W_out - 1) * sw + kw
    x_p = jnp.pad(
        x,
        ((0, 0),
         (pt, max(0, h_span - (H + pt))),
         (pleft, max(0, w_span - (W + pleft))),
         (0, (-C) % bc)),
    )
    Wp = x_p.shape[2]
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, (-C) % bc)))
    n_c = w_p.shape[2] // bc

    grid = (N, n_h, n_c)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, sh=sh, sw=sw, th=th,
                          w_out=W_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, th * sh, Wp, bc), lambda b, h, c: (b, h, 0, c)),
            pl.BlockSpec((1, th * sh, Wp, bc), lambda b, h, c: (b, h + 1, 0, c)),
            pl.BlockSpec((kh, kw, bc), lambda b, h, c: (0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, th, W_out, bc), lambda b, h, c: (b, h, 0, c)),
        out_shape=jax.ShapeDtypeStruct((N, n_h * th, W_out, n_c * bc), out_dtype),
        interpret=interpret,
    )(x_p, x_p, w_p)
    return out[:, :H_out, :, :C]
