"""Axon reproduction: systolic-array-inspired Pallas kernels, mapper, and
model zoo behind the unified ``repro.axon`` operator API."""
