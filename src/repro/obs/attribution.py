"""Measured-vs-modeled attribution: join the op ring against wall scopes.

The op ring records the *modeled* side of every eager dispatch (FLOPs =
2*B*M*K*N, HBM operand traffic from the Fig. 11 model, DRAM energy); with
``optrace.configure(measure_dispatch=True)`` the dispatcher also times
each eager kernel call through ``jax.block_until_ready`` and records a
``dispatch:<kind>`` wall scope -- the *measured* side.  This module joins
the two per kernel kind per backend and reports:

  * achieved FLOP/s and achieved bytes/s (modeled volume / measured wall);
  * roofline placement against a :class:`~repro.core.hw.ChipSpec` (ridge
    point = peak_flops / hbm_bw; modeled time = max(compute, traffic));
  * modeled-vs-measured time error (``measured_wall_s / modeled_time_s``).

On this repo's CPU/interpret-mode CI the error ratios are enormous and
that is the point: they quantify exactly how far the execution substrate
sits from the paper's modeled ASIC/TPU, per kernel kind, instead of
leaving the analytic claims untethered.  The same join run on a real TPU
backend is the validation the ROADMAP's arena comparisons need.

Jitted steps never hit the ring (one dispatch per compilation), so their
modeled cost arrives via ``optrace.traced_costs()``; the serve/vision
engines difference those totals around each step call and surface an
aggregate achieved-intensity row in ``last_stats`` (see
:func:`engine_row`).
"""
from __future__ import annotations

import json
from typing import Any

from repro.core.hw import TPU_V5E, ChipSpec
from repro.obs import optrace

# wall scopes recorded by the dispatcher under measure_dispatch
WALL_PREFIX = "dispatch:"


def _chip_info(chip: ChipSpec) -> dict[str, Any]:
    return {"name": chip.name, "peak_flops": chip.peak_flops,
            "hbm_bw": chip.hbm_bw,
            "ridge_flops_per_byte": chip.peak_flops / chip.hbm_bw}


def _roofline(flops: float, nbytes: float, chip: ChipSpec
              ) -> tuple[float, str]:
    """(modeled seconds, placement) for a modeled (flops, bytes) volume."""
    t_compute = flops / chip.peak_flops if flops else 0.0
    t_traffic = nbytes / chip.hbm_bw if nbytes else 0.0
    placement = "compute-bound" if t_compute >= t_traffic else "memory-bound"
    return max(t_compute, t_traffic), placement


def measured_walls() -> dict[tuple[str, str], dict[str, float]]:
    """Summed ``dispatch:<kind>`` wall scopes keyed by (kind, backend)."""
    out: dict[tuple[str, str], dict[str, float]] = {}
    for s in optrace.spans():
        if s.cat != "wall" or not s.name.startswith(WALL_PREFIX):
            continue
        kind = s.name[len(WALL_PREFIX):]
        backend = str(s.args.get("backend", ""))
        row = out.setdefault((kind, backend), {"wall_s": 0.0, "calls": 0})
        row["wall_s"] += s.dur_s
        row["calls"] += 1
    return out


def kind_rows(chip: ChipSpec = TPU_V5E) -> list[dict[str, Any]]:
    """One attribution row per (kind, backend) seen in the op ring.

    Under ring sampling the modeled sums cover only the sampled events;
    ``sample_coverage`` reports the sampled fraction so consumers can
    scale (the wall scopes are *not* sampled -- they come from the
    measured side)."""
    groups: dict[tuple[str, str], dict[str, float]] = {}
    for ev in optrace.events():
        key = (ev.kind, ev.backend or "")
        g = groups.setdefault(key, {"count": 0, "flops": 0.0,
                                    "bytes": 0.0, "energy_j": 0.0})
        g["count"] += 1
        g["flops"] += ev.flops
        g["bytes"] += ev.bytes
        g["energy_j"] += ev.energy_j
    walls = measured_walls()
    # a measured kind whose ring events were sampled away still gets a row
    for key in walls:
        groups.setdefault(key, {"count": 0, "flops": 0.0,
                                "bytes": 0.0, "energy_j": 0.0})
    rows = []
    for (kind, backend), g in sorted(groups.items()):
        modeled_t, placement = _roofline(g["flops"], g["bytes"], chip)
        w = walls.get((kind, backend))
        row: dict[str, Any] = {
            "kind": kind,
            "backend": backend,
            "count": int(g["count"]),
            "modeled_flops": g["flops"],
            "modeled_bytes": g["bytes"],
            "modeled_energy_j": g["energy_j"],
            "modeled_time_s": modeled_t,
            "roofline": placement if g["bytes"] or g["flops"] else None,
            "intensity_flops_per_byte":
                g["flops"] / g["bytes"] if g["bytes"] else None,
            "measured_wall_s": w["wall_s"] if w else None,
            "measured_calls": w["calls"] if w else 0,
            "achieved_flops_per_s": None,
            "achieved_bytes_per_s": None,
            "time_error_ratio": None,
        }
        if w and w["wall_s"] > 0:
            row["achieved_flops_per_s"] = g["flops"] / w["wall_s"]
            row["achieved_bytes_per_s"] = g["bytes"] / w["wall_s"]
            if modeled_t > 0:
                row["time_error_ratio"] = w["wall_s"] / modeled_t
        rows.append(row)
    return rows


def report(chip: ChipSpec = TPU_V5E) -> dict[str, Any]:
    """The full attribution report (what ``attribution.json`` holds)."""
    rows = kind_rows(chip)
    total = _totals(rows)
    return {
        "chip": _chip_info(chip),
        "kinds": rows,
        "totals": total,
        "traced": {f"{op}:{kind}": cost for (op, kind), cost
                   in sorted(optrace.traced_costs().items())},
        "ring_events": len(optrace.events()),
        "dropped_ops": optrace.dropped_ops(),
        "sampled_out_ops": optrace.sampled_out_ops(),
        "sample_every": optrace.sample_every(),
    }


def _totals(rows: list[dict[str, Any]]) -> dict[str, Any]:
    tot = {"modeled_flops": 0.0, "modeled_bytes": 0.0,
           "modeled_energy_j": 0.0, "measured_wall_s": 0.0}
    for r in rows:
        tot["modeled_flops"] += r["modeled_flops"]
        tot["modeled_bytes"] += r["modeled_bytes"]
        tot["modeled_energy_j"] += r["modeled_energy_j"]
        tot["measured_wall_s"] += r["measured_wall_s"] or 0.0
    return tot


def write_json(path: str, chip: ChipSpec = TPU_V5E) -> dict[str, Any]:
    rep = report(chip)
    with open(path, "w") as f:
        json.dump(rep, f, indent=1, sort_keys=True)
    return rep


def engine_row(*, wall_s: float, modeled: dict[str, float], steps: int,
               covered_steps: int, chip: ChipSpec = TPU_V5E
               ) -> dict[str, Any]:
    """The achieved-intensity row engines put in ``last_stats``.

    ``modeled`` sums per-execution step cost reconstructed from the
    traced-cost ledger (see the module docstring); ``covered_steps`` is
    how many executed steps had a known per-trace cost -- steps whose
    signature was traced before telemetry was enabled contribute wall
    time but no modeled volume, and the coverage ratio says so.
    """
    flops = modeled.get("flops", 0.0)
    nbytes = modeled.get("bytes", 0.0)
    modeled_t, placement = _roofline(flops, nbytes, chip)
    row: dict[str, Any] = {
        "modeled_flops": flops,
        "modeled_bytes": nbytes,
        "modeled_energy_j": modeled.get("energy_j", 0.0),
        "modeled_time_s": modeled_t,
        "roofline": placement if (flops or nbytes) else None,
        "intensity_flops_per_byte": flops / nbytes if nbytes else None,
        "measured_wall_s": wall_s,
        "achieved_flops_per_s": flops / wall_s if wall_s > 0 else None,
        "achieved_bytes_per_s": nbytes / wall_s if wall_s > 0 else None,
        "time_error_ratio":
            wall_s / modeled_t if wall_s > 0 and modeled_t > 0 else None,
        "modeled_step_coverage":
            covered_steps / steps if steps else 0.0,
        "chip": chip.name,
    }
    return row


def paper_section(chip: ChipSpec = TPU_V5E) -> dict[str, Any]:
    """The ``paper_report["attribution"]`` section: measured kinds only.

    The analytic paper report stands on its own; this section tethers it
    to measurement when telemetry carries any, and says why not when it
    does not."""
    rows = [r for r in kind_rows(chip) if r["measured_wall_s"]]
    if not rows:
        return {"available": False,
                "reason": "no measured dispatch walls; enable repro.obs "
                          "and optrace.configure(measure_dispatch=True), "
                          "then run the workload eagerly"}
    return {"available": True, "chip": _chip_info(chip), "kinds": rows}
