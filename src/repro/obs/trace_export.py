"""Chrome-trace / Perfetto JSON export for recorded op events and spans.

Emits the JSON Object Format understood by both ``chrome://tracing`` and
https://ui.perfetto.dev: ``{"traceEvents": [...], "displayTimeUnit": "ms"}``
where each event carries ``name/cat/ph/pid/tid/ts`` (microseconds) plus
``dur`` for complete (``"X"``) slices and ``"s": "t"`` scope for instant
(``"i"``) events.  Process/thread names go out as ``"M"`` metadata events
so the lanes are labeled in the viewer.

:func:`validate_chrome_trace` is the schema check the tests (and the CLI)
run against every export -- field presence, types, phase legality,
non-negative timestamps -- so "Perfetto accepts it" is enforced by code,
not by loading the file by hand.
"""
from __future__ import annotations

import json
from typing import Any

from repro.obs import optrace

PID = 1
_THREAD_NAMES = {
    optrace.TID_OPS: "axon dispatch",
    optrace.TID_STEPS: "engine steps",
}
_VALID_PHASES = ("X", "i", "M", "B", "E", "C")


def _meta(name: str, tid: int | None, value: str) -> dict[str, Any]:
    ev: dict[str, Any] = {"name": name, "ph": "M", "pid": PID, "ts": 0,
                          "args": {"name": value}}
    ev["tid"] = 0 if tid is None else tid
    return ev


def chrome_trace(process_name: str = "repro") -> dict[str, Any]:
    """Build the trace dict from everything currently buffered in
    :mod:`repro.obs.optrace` (op ring + spans)."""
    events: list[dict[str, Any]] = [_meta("process_name", None, process_name)]
    tids_seen: set[int] = set()

    for ev in optrace.events():
        tids_seen.add(optrace.TID_OPS)
        events.append({
            "name": f"{ev.op}:{ev.kind}", "cat": "dispatch", "ph": "i",
            "s": "t", "pid": PID, "tid": optrace.TID_OPS,
            "ts": round(ev.ts_s * 1e6, 3), "args": ev.args()})

    for sp in optrace.spans():
        tids_seen.add(sp.tid)
        base: dict[str, Any] = {
            "name": sp.name, "cat": sp.cat, "pid": PID, "tid": sp.tid,
            "ts": round(sp.ts_s * 1e6, 3), "args": dict(sp.args)}
        if sp.instant:
            base.update(ph="i", s="t")
        else:
            base.update(ph="X", dur=round(sp.dur_s * 1e6, 3))
        events.append(base)

    for tid in sorted(tids_seen):
        if tid in _THREAD_NAMES:
            label = _THREAD_NAMES[tid]
        elif tid >= optrace.TID_REQUEST_BASE:
            label = f"request {tid - optrace.TID_REQUEST_BASE}"
        else:
            label = f"tid {tid}"
        events.append(_meta("thread_name", tid, label))

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(trace: dict[str, Any]) -> list[str]:
    """Return a list of schema violations (empty == valid)."""
    errs: list[str] = []
    if not isinstance(trace, dict):
        return ["trace is not a JSON object"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(evs):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            errs.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        for fld in ("pid", "tid"):
            if not isinstance(ev.get(fld), int):
                errs.append(f"{where}: {fld} must be an int")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: ts must be a non-negative number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X event needs non-negative dur")
        if ph == "i" and ev.get("s") not in (None, "t", "p", "g"):
            errs.append(f"{where}: instant scope must be t/p/g")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"{where}: args must be an object")
        if "args" in ev:
            try:
                json.dumps(ev["args"])
            except (TypeError, ValueError):
                errs.append(f"{where}: args not JSON-serializable")
    return errs


def write_chrome_trace(path: str, process_name: str = "repro"
                       ) -> dict[str, Any]:
    """Export the buffered events to ``path``; raises on schema violation
    so a broken trace never silently lands in an artifact."""
    trace = chrome_trace(process_name)
    errs = validate_chrome_trace(trace)
    if errs:
        raise ValueError("invalid chrome trace: " + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
    return trace
