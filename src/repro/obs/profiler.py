"""Opt-in ``jax.profiler`` capture and block-until-ready wall-time scopes.

Two layers, both no-ops unless explicitly started:

  * :func:`start` / :func:`stop` wrap ``jax.profiler.start_trace`` /
    ``stop_trace`` so a CLI flag can capture a device profile into a
    directory (view with TensorBoard or xprof).  Failures to start (e.g.
    a platform without profiler support) downgrade to a warning -- the
    modeled telemetry must never die because the measured layer can't
    attach.
  * :func:`wall` -- a context manager that times a host scope with
    ``block_until_ready`` on the values you hand back, records the wall
    time into the ``obs_wall_seconds{scope=...}`` histogram and an
    optrace span, so modeled FLOPs/bytes ratios can be paired with
    measured wall time at the same call sites.
"""
from __future__ import annotations

import contextlib
import time
import warnings
from typing import Any, Iterator

import jax

from repro.obs import annotate, metrics, optrace

_ACTIVE_DIR: str | None = None


def active() -> bool:
    return _ACTIVE_DIR is not None


def start(log_dir: str) -> bool:
    """Begin a ``jax.profiler`` trace into ``log_dir``.  Returns False
    (with a warning) when the profiler cannot start on this platform."""
    global _ACTIVE_DIR
    if _ACTIVE_DIR is not None:
        warnings.warn(f"profiler already active ({_ACTIVE_DIR})",
                      stacklevel=2)
        return True
    try:
        jax.profiler.start_trace(log_dir)
    except Exception as e:  # platform/profiler-support dependent
        warnings.warn(f"jax profiler unavailable: {e}", stacklevel=2)
        return False
    _ACTIVE_DIR = log_dir
    return True


def stop() -> str | None:
    """End the active trace; returns the log dir it wrote to (or None)."""
    global _ACTIVE_DIR
    if _ACTIVE_DIR is None:
        return None
    out, _ACTIVE_DIR = _ACTIVE_DIR, None
    try:
        jax.profiler.stop_trace()
    except Exception as e:
        warnings.warn(f"jax profiler stop failed: {e}", stacklevel=2)
        return None
    return out


class WallScope:
    """Mutable handle yielded by :func:`wall`; call :meth:`ready` on the
    computation's outputs so the timed interval includes device work."""

    __slots__ = ("name", "elapsed_s")

    def __init__(self, name: str):
        self.name = name
        self.elapsed_s = 0.0

    def ready(self, *values: Any) -> None:
        for v in values:
            jax.block_until_ready(v)


@contextlib.contextmanager
def wall(name: str, **args: Any) -> Iterator[WallScope]:
    """Time a host scope (caller blocks on device values via
    ``scope.ready(...)``); records ``obs_wall_seconds{scope=name}`` and an
    optrace span when telemetry is enabled."""
    scope = WallScope(name)
    t0 = time.perf_counter()
    try:
        # host-side TraceAnnotation: when a jax.profiler capture is
        # running, the wall scope shows up on the same timeline as the
        # named device scopes it encloses
        with annotate.host_scope(name, enabled=optrace.enabled()):
            yield scope
    finally:
        scope.elapsed_s = time.perf_counter() - t0
        if optrace.enabled():
            metrics.histogram(
                "obs_wall_seconds", "measured wall time by scope",
                labels=("scope",)).observe(scope.elapsed_s, scope=name)
            optrace.add_span(name, t0, scope.elapsed_s, cat="wall",
                             args=args)
